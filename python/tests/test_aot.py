"""AOT pipeline smoke tests: lowering, manifest integrity, HLO text format."""

import json
import os

import numpy as np
import pytest

from compile import aot


def test_quick_manifest_entries_have_unique_names():
    ents = aot.manifest_entries(quick=True)
    names = [e[0] for e in ents]
    assert len(names) == len(set(names))
    assert any("wlsh_hash" in n for n in names)
    assert any("wlsh_matvec" in n for n in names)
    assert any("rff_features" in n for n in names)
    assert any("exact_matvec_laplace" in n for n in names)


def test_full_manifest_covers_experiment_shapes():
    ents = aot.manifest_entries(quick=False)
    names = {e[0] for e in ents}
    # Table 1 / Table 2 shapes from DESIGN.md §6
    assert f"wlsh_hash__n{aot.HASH_CHUNK_N}_d32_m{aot.HASH_CHUNK_M}__smooth2" in names
    assert f"wlsh_hash__n{aot.HASH_CHUNK_N}_d16_m{aot.HASH_CHUNK_M}__rect" in names
    assert f"wlsh_hash__n{aot.HASH_CHUNK_N}_d384_m{aot.HASH_CHUNK_M}__rect" in names
    assert "exact_matvec_se__n3072_d32" in names
    assert "exact_matvec_matern52__n6144_d96" in names
    assert "wlsh_matvec__n4096_m64" in names


def test_lower_one_entry_produces_parsable_hlo_text():
    ents = aot.manifest_entries(quick=True)
    name, fn, specs = next(e for e in ents if e[0].startswith("wlsh_matvec"))
    import jax
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root computation must return a tuple
    assert "ROOT" in text


def test_export_bucketfns(tmp_path):
    aot.export_bucketfns(str(tmp_path))
    for name in ("rect", "smooth2", "smooth3", "smooth4"):
        p = tmp_path / f"bucketfn_{name}.json"
        assert p.exists()
        payload = json.loads(p.read_text())
        assert len(payload["breaks"]) == len(payload["coeffs"]) + 1
        assert payload["l2_norm"] == pytest.approx(1.0, abs=1e-8)
        ac = payload["autocorrelation"]
        assert len(ac["breaks"]) == len(ac["coeffs"]) + 1


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_matches_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    assert man["hash_chunk_n"] == aot.HASH_CHUNK_N
    for e in man["entries"]:
        path = os.path.join(root, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")
        assert e["inputs"] and e["outputs"]
