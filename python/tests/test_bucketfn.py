"""Tests for the piecewise-polynomial bucket-shaping functions (paper §3)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.bucketfn import (
    PiecewisePoly,
    bucket_by_name,
    paper_smooth_bucket,
    rect_bucket,
    smooth_bucket,
)


class TestRect:
    def test_support(self):
        r = rect_bucket()
        assert r(np.array([-0.49, 0.0, 0.49])).tolist() == [1.0, 1.0, 1.0]
        assert r(np.array([-0.6, 0.6, 1.0])).tolist() == [0.0, 0.0, 0.0]

    def test_l2_norm_is_one(self):
        assert rect_bucket().l2_norm() == pytest.approx(1.0)

    def test_autocorrelation_is_triangle(self):
        # (rect * rect)(t) = max(0, 1 - |t|): the Laplace-kernel profile.
        ac = rect_bucket().autocorrelation()
        ts = np.linspace(-0.99, 0.99, 41)
        np.testing.assert_allclose(ac(ts), np.maximum(0, 1 - np.abs(ts)),
                                   atol=1e-12)


class TestSmoothFamily:
    @pytest.mark.parametrize("q", [1, 2, 3, 4])
    def test_normalized(self, q):
        assert smooth_bucket(q).l2_norm() == pytest.approx(1.0, abs=1e-9)

    @pytest.mark.parametrize("q", [1, 2, 3, 4])
    def test_support_within_half(self, q):
        pp = smooth_bucket(q)
        assert pp.breaks[0] >= -0.5 and pp.breaks[-1] <= 0.5

    @pytest.mark.parametrize("q", [1, 2, 3, 4])
    def test_even(self, q):
        pp = smooth_bucket(q)
        xs = np.linspace(0.001, 0.45, 97)
        np.testing.assert_allclose(pp(xs), pp(-xs), atol=1e-9)

    def test_paper_bucket_matches_direct_convolution(self):
        """f = (rect * rect_{1/4} * rect_{1/4})(2x) normalized — brute force."""
        # numerical convolution on a fine grid
        h = 1e-4
        xs = np.arange(-1.0, 1.0, h)
        rect = ((xs >= -0.5) & (xs < 0.5)).astype(float)
        rect4 = ((xs >= -0.125) & (xs < 0.125)).astype(float)
        conv = np.convolve(np.convolve(rect, rect4, "same") * h, rect4,
                           "same") * h
        f_direct = np.interp(2 * np.linspace(-0.4, 0.4, 81), xs, conv)
        nrm = math.sqrt(np.sum(np.interp(
            2 * xs, xs, conv) ** 2) * h)
        f_direct /= nrm
        pp = paper_smooth_bucket()
        np.testing.assert_allclose(pp(np.linspace(-0.4, 0.4, 81)), f_direct,
                                   atol=3e-3)

    @pytest.mark.parametrize("q", [2, 3])
    def test_smoothness_order(self, q):
        """smooth_bucket(q) must be C^{q-1}: derivatives up to q-1 continuous."""
        pp = smooth_bucket(q)
        for order in range(q):
            eps = 1e-9
            for b in pp.breaks[1:-1]:
                lo, hi = pp(np.array([b - eps])), pp(np.array([b + eps]))
                np.testing.assert_allclose(lo, hi, atol=1e-5)
            pp = pp.derivative()

    def test_derivative_of_constant_piece_is_zero(self):
        pp = PiecewisePoly([-1.0, 1.0], [[3.0]])
        d = pp.derivative()
        assert d(np.array([0.0]))[0] == 0.0


class TestCalculus:
    @given(st.floats(-2, 2))
    @settings(max_examples=50, deadline=None)
    def test_antiderivative_monotone_for_nonneg(self, x):
        pp = smooth_bucket(2)
        a = pp.antiderivative_at(x)
        b = pp.antiderivative_at(x + 0.1)
        assert b >= a - 1e-12

    def test_box_convolve_preserves_mass(self):
        pp = rect_bucket()
        mass0 = pp.antiderivative_at(10.0)
        conv = pp.box_convolve(0.25)
        # rect_a has mass a, so mass multiplies by a
        assert conv.antiderivative_at(10.0) == pytest.approx(mass0 * 0.25)

    def test_scale_arg(self):
        pp = smooth_bucket(2)
        sc = pp.scale_arg(2.0)
        xs = np.linspace(-0.18, 0.18, 37)
        np.testing.assert_allclose(sc(xs), pp(2 * xs), atol=1e-12)

    def test_autocorrelation_peak_at_zero(self):
        for name in ("rect", "smooth2", "smooth3"):
            ac = bucket_by_name(name).autocorrelation()
            # (f*f)(0) = ||f||_2^2 = 1
            assert ac(np.array([0.0]))[0] == pytest.approx(1.0, abs=1e-8)
            ts = np.linspace(-0.9, 0.9, 61)
            assert np.all(ac(ts) <= 1.0 + 1e-8)

    @given(st.sampled_from(["rect", "smooth2", "smooth3", "smooth4"]))
    @settings(max_examples=8, deadline=None)
    def test_autocorrelation_even_psd_profile(self, name):
        ac = bucket_by_name(name).autocorrelation()
        ts = np.linspace(0.01, 1.4, 50)
        # polyfit reconstruction noise grows with the piece degree (smooth4
        # reaches degree ~10); 1e-6 absolute is far below any functional use
        np.testing.assert_allclose(ac(ts), ac(-ts), atol=1e-6)


def test_bucket_by_name_errors():
    with pytest.raises(ValueError):
        bucket_by_name("bogus")
    with pytest.raises(ValueError):
        smooth_bucket(0)
