"""Pallas WLSH hash kernel vs pure-numpy oracle (hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import wlsh_hash_weights_ref, wlsh_kernel_value_ref
from compile.kernels.wlsh import wlsh_hash_weights


def make_inputs(seed, n, d, m, masked=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) * 3.0
    w = rng.gamma(2.0, 1.0, size=(m, d)).astype(np.float32) + 1e-3
    z = (rng.uniform(size=(m, d)) * w).astype(np.float32)
    mix = (rng.integers(1, 2**31, size=(1, d), dtype=np.int64) | 1).astype(
        np.int32)
    mask = np.ones((1, d), np.float32)
    if masked:
        mask[0, d - masked:] = 0.0
        x[:, d - masked:] = 0.0
    return x, w, z, mix, mask


@pytest.mark.parametrize("bucket", ["rect", "smooth2"])
@pytest.mark.parametrize("n,d,m,bn", [(256, 4, 2, 64), (512, 8, 4, 256),
                                      (256, 16, 3, 128)])
def test_kernel_matches_ref(bucket, n, d, m, bn):
    x, w, z, mix, mask = make_inputs(0, n, d, m)
    ids, wts = wlsh_hash_weights(x, w, z, mix, mask, bucket=bucket,
                                 block_n=bn)
    rids, rwts = wlsh_hash_weights_ref(x, w, z, mix, mask, bucket=bucket)
    np.testing.assert_array_equal(np.asarray(ids), rids)
    np.testing.assert_allclose(np.asarray(wts), rwts, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1),
       n_blocks=st.integers(1, 4),
       d=st.integers(1, 24),
       m=st.integers(1, 6),
       masked=st.integers(0, 3),
       bucket=st.sampled_from(["rect", "smooth2", "smooth3"]))
@settings(max_examples=20, deadline=None)
def test_kernel_matches_ref_hypothesis(seed, n_blocks, d, m, masked, bucket):
    masked = min(masked, d - 1)
    n = 64 * n_blocks
    x, w, z, mix, mask = make_inputs(seed, n, d, m, masked)
    ids, wts = wlsh_hash_weights(x, w, z, mix, mask, bucket=bucket,
                                 block_n=64)
    rids, rwts = wlsh_hash_weights_ref(x, w, z, mix, mask, bucket=bucket)
    np.testing.assert_array_equal(np.asarray(ids), rids)
    np.testing.assert_allclose(np.asarray(wts), rwts, atol=1e-5)


def test_masked_dims_do_not_affect_ids_or_weights():
    """Padding contract: masked dims contribute id 0 and weight factor 1."""
    x, w, z, mix, mask = make_inputs(7, 256, 8, 3)
    full_mask = mask.copy()
    ids_a, wts_a = wlsh_hash_weights(x, w, z, mix, full_mask,
                                     bucket="smooth2")
    # now pad: extend to d=12 with junk features but mask them out
    pad = np.random.default_rng(8)
    x2 = np.concatenate([x, pad.normal(size=(256, 4)).astype(np.float32)], 1)
    w2 = np.concatenate([w, np.ones((3, 4), np.float32)], 1)
    z2 = np.concatenate([z, 0.3 * np.ones((3, 4), np.float32)], 1)
    mix2 = np.concatenate([mix, np.full((1, 4), 12345, np.int32)], 1)
    mask2 = np.concatenate([full_mask, np.zeros((1, 4), np.float32)], 1)
    ids_b, wts_b = wlsh_hash_weights(x2, w2, z2, mix2, mask2,
                                     bucket="smooth2")
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(wts_a), np.asarray(wts_b),
                               atol=1e-6)


def test_rect_weights_are_one():
    x, w, z, mix, mask = make_inputs(3, 128, 6, 2)
    _, wts = wlsh_hash_weights(x, w, z, mix, mask, bucket="rect",
                               block_n=128)
    np.testing.assert_array_equal(np.asarray(wts), np.ones((2, 128),
                                                           np.float32))


def test_collision_probability_is_laplace_kernel():
    """Rahimi-Recht: rect bucket + Gamma(2,1) widths ⇒ P[collision] = e^{-|Δ|_1}.

    Statistical test over many instances in 1-d (Monte Carlo ±4σ band).
    """
    rng = np.random.default_rng(11)
    m = 4000
    delta = 0.7
    x = np.array([[0.0], [delta]], np.float32)
    w = rng.gamma(2.0, 1.0, size=(m, 1)).astype(np.float32)
    z = (rng.uniform(size=(m, 1)) * w).astype(np.float32)
    mix = np.array([[1]], np.int32)
    mask = np.ones((1, 1), np.float32)
    ids, _ = wlsh_hash_weights(x, w, z, mix, mask, bucket="rect", block_n=2)
    ids = np.asarray(ids)
    p_hat = float(np.mean(ids[:, 0] == ids[:, 1]))
    p_true = np.exp(-delta)
    sigma = np.sqrt(p_true * (1 - p_true) / m)
    assert abs(p_hat - p_true) < 4 * sigma + 1e-9


def test_wlsh_estimator_is_unbiased_smooth():
    """Claim 22: E[w_x w_y 1{collide}] = k_{f,p}(x-y), smooth bucket, Gamma(7)."""
    rng = np.random.default_rng(13)
    m = 20000
    delta = 0.35
    x = np.array([[0.0], [delta]], np.float32)
    w = rng.gamma(7.0, 1.0, size=(m, 1)).astype(np.float32)
    z = (rng.uniform(size=(m, 1)) * w).astype(np.float32)
    mix = np.array([[1]], np.int32)
    mask = np.ones((1, 1), np.float32)
    ids, wts = wlsh_hash_weights(x, w, z, mix, mask, bucket="smooth2",
                                 block_n=2)
    ids, wts = np.asarray(ids), np.asarray(wts)
    est = np.where(ids[:, 0] == ids[:, 1], wts[:, 0] * wts[:, 1], 0.0)
    k_true = wlsh_kernel_value_ref(delta, "smooth2", 7.0)[0]
    stderr = est.std() / np.sqrt(m)
    assert abs(est.mean() - k_true) < 4.5 * stderr + 1e-4
