"""Pallas RFF + exact-kernel mat-vec kernels vs oracles (hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.exact import kernel_block_matvec
from compile.kernels.ref import (
    kernel_block_matvec_ref,
    kernel_matrix_ref,
    rff_features_ref,
)
from compile.kernels.rff import rff_features


class TestRff:
    @pytest.mark.parametrize("n,d,D,bn,bd", [
        (128, 4, 64, 64, 64), (256, 16, 128, 128, 128), (128, 32, 256, 64, 128)])
    def test_matches_ref(self, n, d, D, bn, bd):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, d)).astype(np.float32)
        om = rng.normal(size=(d, D)).astype(np.float32)
        b = rng.uniform(0, 2 * np.pi, size=(1, D)).astype(np.float32)
        sc = np.array([[np.sqrt(2.0 / D)]], np.float32)
        z = rff_features(x, om, b, sc, block_n=bn, block_d=bd)
        np.testing.assert_allclose(np.asarray(z),
                                   rff_features_ref(x, om, b, sc), atol=1e-5)

    @given(seed=st.integers(0, 2**31 - 1), nb=st.integers(1, 3),
           d=st.integers(1, 12), db=st.integers(1, 3))
    @settings(max_examples=15, deadline=None)
    def test_matches_ref_hypothesis(self, seed, nb, d, db):
        rng = np.random.default_rng(seed)
        n, D = 32 * nb, 32 * db
        x = rng.normal(size=(n, d)).astype(np.float32)
        om = rng.normal(size=(d, D)).astype(np.float32)
        b = rng.uniform(0, 2 * np.pi, size=(1, D)).astype(np.float32)
        sc = np.array([[np.sqrt(2.0 / D)]], np.float32)
        z = rff_features(x, om, b, sc, block_n=32, block_d=32)
        np.testing.assert_allclose(np.asarray(z),
                                   rff_features_ref(x, om, b, sc), atol=1e-5)

    def test_rff_approximates_se_kernel(self):
        """E[phi(x)ᵀphi(y)] = exp(-gamma ||x-y||²) — Monte Carlo check."""
        rng = np.random.default_rng(5)
        d, D, gamma = 3, 8192, 1.0
        x = rng.normal(size=(2, d)).astype(np.float32) * 0.4
        om = (rng.normal(size=(d, D)) * np.sqrt(2.0 * gamma)).astype(
            np.float32)
        b = rng.uniform(0, 2 * np.pi, size=(1, D)).astype(np.float32)
        sc = np.array([[np.sqrt(2.0 / D)]], np.float32)
        z = np.asarray(rff_features(x, om, b, sc, block_n=2, block_d=512))
        k_hat = float(z[0] @ z[1])
        k_true = float(kernel_matrix_ref(x[:1], x[1:], 1.0, "se")[0, 0])
        assert abs(k_hat - k_true) < 0.05


class TestExactMatvec:
    @pytest.mark.parametrize("kind", ["se", "matern52", "laplace"])
    @pytest.mark.parametrize("q,n,d", [(128, 128, 4), (128, 256, 40),
                                       (64, 192, 7)])
    def test_matches_ref(self, kind, q, n, d):
        rng = np.random.default_rng(1)
        xq = rng.normal(size=(q, d)).astype(np.float32)
        x = rng.normal(size=(n, d)).astype(np.float32)
        beta = rng.normal(size=(1, n)).astype(np.float32)
        s = 1.3
        y = kernel_block_matvec(xq, x, beta, np.array([[s]], np.float32),
                                kind=kind, block_q=64, block_n=64)
        yr = kernel_block_matvec_ref(xq, x, beta, s, kind)
        np.testing.assert_allclose(np.asarray(y).ravel(), yr, rtol=2e-4,
                                   atol=2e-4)

    @given(seed=st.integers(0, 2**31 - 1),
           kind=st.sampled_from(["se", "matern52", "laplace"]),
           qb=st.integers(1, 2), nb=st.integers(1, 3), d=st.integers(1, 36),
           scale=st.floats(0.3, 5.0))
    @settings(max_examples=15, deadline=None)
    def test_matches_ref_hypothesis(self, seed, kind, qb, nb, d, scale):
        rng = np.random.default_rng(seed)
        q, n = 32 * qb, 32 * nb
        xq = rng.normal(size=(q, d)).astype(np.float32)
        x = rng.normal(size=(n, d)).astype(np.float32)
        beta = rng.normal(size=(1, n)).astype(np.float32)
        y = kernel_block_matvec(xq, x, beta,
                                np.array([[scale]], np.float32), kind=kind,
                                block_q=32, block_n=32)
        yr = kernel_block_matvec_ref(xq, x, beta, scale, kind)
        np.testing.assert_allclose(np.asarray(y).ravel(), yr, rtol=3e-4,
                                   atol=3e-4)

    def test_self_matvec_is_symmetric_quadratic_form(self):
        """βᵀKβ computed two ways must agree (K symmetric for xq = x)."""
        rng = np.random.default_rng(2)
        n, d = 128, 6
        x = rng.normal(size=(n, d)).astype(np.float32)
        b1 = rng.normal(size=(1, n)).astype(np.float32)
        b2 = rng.normal(size=(1, n)).astype(np.float32)
        s = np.array([[1.0]], np.float32)
        for kind in ("se", "matern52", "laplace"):
            y1 = np.asarray(kernel_block_matvec(x, x, b1, s, kind=kind,
                                                block_q=64, block_n=64))
            y2 = np.asarray(kernel_block_matvec(x, x, b2, s, kind=kind,
                                                block_q=64, block_n=64))
            # b2ᵀ(K b1) == b1ᵀ(K b2)
            assert float(b2.ravel() @ y1.ravel()) == pytest.approx(
                float(b1.ravel() @ y2.ravel()), rel=1e-3)

    def test_padded_zero_rows_contribute_nothing(self):
        """Padding contract: rows with beta=0 never affect the product."""
        rng = np.random.default_rng(3)
        n, d, pad = 96, 5, 32
        x = rng.normal(size=(n, d)).astype(np.float32)
        xp = np.concatenate([x, rng.normal(size=(pad, d)).astype(np.float32)])
        beta = rng.normal(size=(1, n)).astype(np.float32)
        bp = np.concatenate([beta, np.zeros((1, pad), np.float32)], axis=1)
        s = np.array([[1.1]], np.float32)
        for kind in ("se", "matern52", "laplace"):
            y = np.asarray(kernel_block_matvec(x, x, beta, s, kind=kind,
                                               block_q=32, block_n=32))
            yp = np.asarray(kernel_block_matvec(x, xp, bp, s, kind=kind,
                                                block_q=32, block_n=32))
            np.testing.assert_allclose(y.ravel(), yp.ravel(), atol=1e-4)
