"""L2 graph tests: wlsh_matvec / fused / rff_matvec vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels.ref import (
    wlsh_hash_weights_ref,
    wlsh_matvec_ref,
)


def test_wlsh_matvec_matches_ref():
    rng = np.random.default_rng(0)
    m, n = 8, 512
    ids = rng.integers(0, 64, size=(m, n)).astype(np.int32)
    wts = rng.uniform(0.1, 2.0, size=(m, n)).astype(np.float32)
    beta = rng.normal(size=(1, n)).astype(np.float32)
    y = model.wlsh_matvec(jnp.asarray(ids), jnp.asarray(wts),
                          jnp.asarray(beta), jnp.asarray([[1.0 / m]],
                                                         dtype=jnp.float32))
    yr = wlsh_matvec_ref(ids, wts, beta, 1.0 / m)
    np.testing.assert_allclose(np.asarray(y).ravel(), yr, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 8),
       n=st.integers(4, 300), nb=st.integers(2, 80))
@settings(max_examples=25, deadline=None)
def test_wlsh_matvec_hypothesis(seed, m, n, nb):
    rng = np.random.default_rng(seed)
    nb = min(nb, n)
    ids = rng.integers(0, nb, size=(m, n)).astype(np.int32)
    wts = rng.uniform(0.0, 2.0, size=(m, n)).astype(np.float32)
    beta = rng.normal(size=(1, n)).astype(np.float32)
    y = model.wlsh_matvec(jnp.asarray(ids), jnp.asarray(wts),
                          jnp.asarray(beta),
                          jnp.asarray([[1.0 / m]], dtype=jnp.float32))
    yr = wlsh_matvec_ref(ids, wts, beta, 1.0 / m)
    np.testing.assert_allclose(np.asarray(y).ravel(), yr, atol=1e-3)


def test_wlsh_matvec_is_psd_quadratic_form():
    """Claim 10: βᵀK̃β ≥ 0 for any β and any single instance."""
    rng = np.random.default_rng(4)
    m, n = 1, 256
    ids = rng.integers(0, 32, size=(m, n)).astype(np.int32)
    wts = rng.uniform(-1.0, 2.0, size=(m, n)).astype(np.float32)
    for _ in range(20):
        beta = rng.normal(size=(1, n)).astype(np.float32)
        y = model.wlsh_matvec(jnp.asarray(ids), jnp.asarray(wts),
                              jnp.asarray(beta),
                              jnp.asarray([[1.0]], dtype=jnp.float32))
        q = float(beta.ravel() @ np.asarray(y).ravel())
        assert q >= -1e-3


def test_fused_hash_matvec_matches_two_step():
    rng = np.random.default_rng(6)
    n, d, m = 256, 5, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.gamma(2.0, 1.0, size=(m, d)).astype(np.float32)
    z = (rng.uniform(size=(m, d)) * w).astype(np.float32)
    mix = (rng.integers(1, 2**31, size=(1, d), dtype=np.int64) | 1).astype(
        np.int32)
    mask = np.ones((1, d), np.float32)
    beta = rng.normal(size=(1, n)).astype(np.float32)
    inv_m = jnp.asarray([[1.0 / m]], dtype=jnp.float32)
    yf = model.wlsh_hash_matvec_fused(x, w, z, mix, mask,
                                      jnp.asarray(beta), inv_m,
                                      bucket="smooth2")
    ids, wts = wlsh_hash_weights_ref(x, w, z, mix, mask, bucket="smooth2")
    yr = wlsh_matvec_ref(ids, wts, beta, 1.0 / m)
    np.testing.assert_allclose(np.asarray(yf).ravel(), yr, atol=1e-3)


def test_rff_matvec_never_forms_kernel_matrix():
    rng = np.random.default_rng(7)
    n, D = 128, 64
    z = rng.normal(size=(n, D)).astype(np.float32)
    beta = rng.normal(size=(1, n)).astype(np.float32)
    y = model.rff_matvec(jnp.asarray(z), jnp.asarray(beta))
    yr = (z @ (z.T @ beta.ravel())).astype(np.float32)
    np.testing.assert_allclose(np.asarray(y).ravel(), yr, rtol=1e-4,
                               atol=1e-4)


def test_padded_instances_with_zero_weights_are_noops():
    """Padding contract for the m axis: zero-weight instances contribute 0."""
    rng = np.random.default_rng(8)
    m, n = 4, 128
    ids = rng.integers(0, 16, size=(m, n)).astype(np.int32)
    wts = rng.uniform(0.1, 1.0, size=(m, n)).astype(np.float32)
    beta = rng.normal(size=(1, n)).astype(np.float32)
    y1 = model.wlsh_matvec(jnp.asarray(ids), jnp.asarray(wts),
                           jnp.asarray(beta),
                           jnp.asarray([[1.0 / m]], dtype=jnp.float32))
    ids_p = np.concatenate([ids, rng.integers(0, 16, size=(3, n)).astype(
        np.int32)])
    wts_p = np.concatenate([wts, np.zeros((3, n), np.float32)])
    y2 = model.wlsh_matvec(jnp.asarray(ids_p), jnp.asarray(wts_p),
                           jnp.asarray(beta),
                           jnp.asarray([[1.0 / m]], dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
