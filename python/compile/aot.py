"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime (rust/src/runtime/) loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and executes
them on the PJRT CPU client.  HLO text — NOT ``.serialize()`` — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Shape strategy (DESIGN.md §6): per-point-independent graphs (hashing, RFF
features, cross mat-vecs) are lowered once at a fixed chunk size and the Rust
runtime iterates chunks; whole-dataset graphs (wlsh_matvec, self mat-vecs)
are lowered per padded dataset size.  ``manifest.json`` records every
artifact's input/output signature; ``bucketfn_*.json`` exports the exact
piecewise-polynomial bucket functions so the Rust native backend evaluates
the same f bit-for-bit.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.bucketfn import bucket_by_name

F32 = jnp.float32
I32 = jnp.int32

# Chunk sizes shared with the Rust runtime (see rust/src/runtime/shapes.rs).
HASH_CHUNK_N = 2048
HASH_CHUNK_M = 64
CROSS_CHUNK_Q = 1024
RFF_CHUNK_N = 2048


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def manifest_entries(quick: bool = False):
    """Yield (name, fn, [arg specs]).  Names are stable Rust-side keys."""
    ents = []

    # ---- WLSH hashing: chunked over n, fixed m-chunk, one per (d, bucket).
    d_pads = [8, 16, 32] if quick else [8, 16, 32, 64, 96, 128, 384]
    n, m = (256, 4) if quick else (HASH_CHUNK_N, HASH_CHUNK_M)
    for d in d_pads:
        for bucket in ("rect", "smooth2"):
            ents.append((
                f"wlsh_hash__n{n}_d{d}_m{m}__{bucket}",
                functools.partial(model.wlsh_hash_batch, bucket=bucket),
                [spec((n, d)), spec((m, d)), spec((m, d)),
                 spec((1, d), I32), spec((1, d))],
            ))

    # ---- WLSH sketch mat-vec: whole-dataset, per padded n.
    mv_ns = [256] if quick else [1024, 4096, 6144]
    for nn in mv_ns:
        mm = 4 if quick else HASH_CHUNK_M
        ents.append((
            f"wlsh_matvec__n{nn}_m{mm}",
            model.wlsh_matvec,
            [spec((mm, nn), I32), spec((mm, nn)), spec((1, nn)),
             spec((1, 1))],
        ))

    # ---- RFF features: chunked over n, one per (d, D).
    rff_shapes = [(16, 128)] if quick else [
        (16, 7168), (96, 5120), (384, 3584), (64, 1536)]
    nrf = 256 if quick else RFF_CHUNK_N
    for d, dd in rff_shapes:
        ents.append((
            f"rff_features__n{nrf}_d{d}_D{dd}",
            model.rff_features_graph,
            [spec((nrf, d)), spec((d, dd)), spec((1, dd)), spec((1, 1))],
        ))

    # ---- RFF sketch mat-vec (demo/parity scale; large runs go native).
    rffmv = [(256, 128)] if quick else [(4096, 7168), (6144, 5120)]
    for nn, dd in rffmv:
        ents.append((
            f"rff_matvec__n{nn}_D{dd}",
            model.rff_matvec,
            [spec((nn, dd)), spec((1, nn))],
        ))

    # ---- Exact kernel mat-vecs: self (training) and cross (prediction).
    self_shapes = [(256, 8)] if quick else [(3072, 32), (4096, 32), (6144, 96)]
    cross_shapes = [(128, 256, 8)] if quick else [
        (CROSS_CHUNK_Q, 3072, 32), (CROSS_CHUNK_Q, 4096, 32),
        (CROSS_CHUNK_Q, 6144, 96)]
    for kind in ("se", "matern52", "laplace"):
        fn = functools.partial(model.exact_matvec, kind=kind)
        for nn, d in self_shapes:
            ents.append((
                f"exact_matvec_{kind}__n{nn}_d{d}",
                fn,
                [spec((nn, d)), spec((nn, d)), spec((1, nn)), spec((1, 1))],
            ))
        for q, nn, d in cross_shapes:
            ents.append((
                f"exact_cross_{kind}__q{q}_n{nn}_d{d}",
                fn,
                [spec((q, d)), spec((nn, d)), spec((1, nn)), spec((1, 1))],
            ))
    return ents


def export_bucketfns(out_dir: str):
    """Write the exact piecewise-poly pieces for the Rust native backend."""
    for name in ("rect", "smooth2", "smooth3", "smooth4"):
        pp = bucket_by_name(name)
        payload = pp.as_dict()
        payload["l2_norm"] = pp.l2_norm()
        payload["linf_norm"] = pp.linf_norm()
        ac = pp.autocorrelation()
        payload["autocorrelation"] = ac.as_dict()
        with open(os.path.join(out_dir, f"bucketfn_{name}.json"), "w") as f:
            json.dump(payload, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes for CI smoke")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    export_bucketfns(args.out_dir)

    manifest = {"hash_chunk_n": HASH_CHUNK_N, "hash_chunk_m": HASH_CHUNK_M,
                "cross_chunk_q": CROSS_CHUNK_Q, "rff_chunk_n": RFF_CHUNK_N,
                "entries": []}
    ents = manifest_entries(quick=args.quick)
    for name, fn, specs in ents:
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = lowered.out_info
        out_list = jax.tree_util.tree_leaves(outs)
        manifest["entries"].append({
            "name": name,
            "file": fname,
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in specs],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in out_list],
        })
        print(f"  lowered {name}  ({len(text)//1024} KiB)", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
