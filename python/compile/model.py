"""L2 — JAX compute graphs composing the L1 Pallas kernels.

Each public function here is a lowering target for ``aot.py``: it is jitted,
lowered to HLO *text* once at build time, and executed from the Rust runtime
via PJRT.  Python never runs on the request path.

Graphs:
  * ``wlsh_hash_batch``   — hash n points under m LSH instances (L1 kernel).
  * ``wlsh_matvec``       — the paper's O(n·m) sketch mat-vec (§4, Lemma 27):
                            bucket loads via segment_sum, then gather.
  * ``rff_features_graph``— RFF feature matrix (L1 kernel).
  * ``rff_matvec``        — K̃_rff β = Z (Zᵀ β) without forming Z Zᵀ.
  * ``exact_matvec_*``    — blockwise exact-kernel mat-vec (L1 kernel), both
                            the n×n training form and the q×n cross form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.exact import kernel_block_matvec
from .kernels.rff import rff_features
from .kernels.wlsh import wlsh_hash_weights


# --------------------------------------------------------------------------
# WLSH
# --------------------------------------------------------------------------

def wlsh_hash_batch(x, w, z, mix, mask, *, bucket: str = "rect"):
    """ids i32[m,n], weights f32[m,n] for all m LSH instances."""
    return wlsh_hash_weights(x, w, z, mix, mask, bucket=bucket)


def wlsh_matvec(ids, weights, beta, inv_m):
    """y = (1/m) Σ_s D_s a_s a_sᵀ D_s β  — the WLSH sketch mat-vec.

    ``ids`` must be *renumbered* per instance into [0, n) (the Rust bucket
    table does this once at preprocessing).  Per instance: the bucket load
    B_j(β) = Σ_{i: id_i=j} w_i β_i  is a segment-sum; each point then
    receives w_i · B_{id_i}(β)  (paper §4, Figure 1).

    Args:
      ids:     i32[m, n]  dense bucket ids in [0, n).
      weights: f32[m, n]  f^{⊗d} weights.
      beta:    f32[1, n]
      inv_m:   f32[1, 1]  1/m_effective (padded instances carry weight 0).

    Returns f32[1, n].
    """
    m, n = ids.shape
    b = beta.reshape(-1)

    def per_instance(id_s, w_s):
        contrib = w_s * b
        loads = jax.ops.segment_sum(contrib, id_s, num_segments=n)
        return w_s * loads[id_s]

    ys = jax.vmap(per_instance)(ids, weights)            # (m, n)
    return (jnp.sum(ys, axis=0) * inv_m.reshape(()))[None, :]


def wlsh_hash_matvec_fused(x, w, z, mix, mask, beta, inv_m, *,
                           bucket: str = "rect"):
    """Fused hash + mat-vec — one module for single-shot K̃β products.

    Avoids materializing (ids, weights) in HBM when the caller only needs
    one product (e.g. unbiasedness tests / one-off scoring).  Uses the raw
    i32 mix ids directly as segment ids is unsound (they are not dense), so
    this fused form sorts ids per instance instead — O(n log n) but fully
    in-graph.
    """
    ids, weights = wlsh_hash_weights(x, w, z, mix, mask, bucket=bucket)
    m, n = ids.shape
    b = beta.reshape(-1)

    def per_instance(id_s, w_s):
        order = jnp.argsort(id_s)
        sid = id_s[order]
        sw = w_s[order]
        sb = b[order]
        contrib = sw * sb
        # segment boundaries in the sorted order
        new_seg = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
        seg_idx = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
        loads = jax.ops.segment_sum(contrib, seg_idx, num_segments=n)
        y_sorted = sw * loads[seg_idx]
        inv = jnp.argsort(order)
        return y_sorted[inv]

    ys = jax.vmap(per_instance)(ids, weights)
    return (jnp.sum(ys, axis=0) * inv_m.reshape(()))[None, :]


# --------------------------------------------------------------------------
# RFF
# --------------------------------------------------------------------------

def rff_features_graph(x, omega, b, scale):
    """Z = sqrt(2/D) cos(X Ω + b)  (L1 kernel)."""
    return rff_features(x, omega, b, scale)


def rff_matvec(zfeat, beta):
    """K̃_rff β = Z (Zᵀ β): two MXU matmuls, never forms the n×n matrix."""
    theta = jnp.dot(zfeat.T, beta.reshape(-1),
                    preferred_element_type=jnp.float32)
    return jnp.dot(zfeat, theta, preferred_element_type=jnp.float32)[None, :]


# --------------------------------------------------------------------------
# Exact kernels
# --------------------------------------------------------------------------

def exact_matvec(xq, x, beta, scale, *, kind: str):
    """y = K(Xq, X) β for kind in {se, matern52, laplace} (L1 kernel)."""
    return kernel_block_matvec(xq, x, beta, scale, kind=kind)


exact_matvec_se = functools.partial(exact_matvec, kind="se")
exact_matvec_matern52 = functools.partial(exact_matvec, kind="matern52")
exact_matvec_laplace = functools.partial(exact_matvec, kind="laplace")
