"""Bucket-shaping functions f for the WLSH estimator (paper §3, Def. 6/8).

A bucket-shaping function is an even function f supported on [-1/2, 1/2] with
||f||_2 = 1.  The paper's two instantiations:

  * ``rect``   — f = rect (indicator of [-1/2,1/2]); WLSH degenerates to the
                 Rahimi-Recht random binning features (Table 2 experiments).
  * ``smooth`` — f(x) = (rect * rect_{1/4} * rect_{1/4})(2x), normalized
                 (Table 1 experiments; continuous derivative, bounded second
                 derivative -> Matern-5/2-like smoothness of the GP paths).

We represent these exactly as *piecewise polynomials* and build them
programmatically by repeated box convolution (the B-spline construction).
This module is the single source of truth for f: the Pallas/L1 kernel bakes
the pieces in as constants, the pure-jnp reference evaluates the same pieces,
and ``aot.py`` exports them to ``artifacts/bucketfn_*.json`` so the Rust
native backend provably evaluates the *same* function (integration-tested).

Generalization beyond the paper: ``smooth_bucket(q)`` convolves rect with a
width-1/(2q) box q times, yielding C^{q-1} bucket functions of any desired
smoothness order — the "any desired smoothness" family of §3.2.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

__all__ = [
    "PiecewisePoly",
    "rect_bucket",
    "smooth_bucket",
    "paper_smooth_bucket",
    "bucket_by_name",
]


def _poly_eval(coeffs: Sequence[float], x: float) -> float:
    """Horner evaluation; ``coeffs`` ascending (c0 + c1 x + ...)."""
    acc = 0.0
    for c in reversed(coeffs):
        acc = acc * x + c
    return acc


def _poly_shift(coeffs: Sequence[float], s: float) -> List[float]:
    """Coefficients of p(x + s) given coefficients of p(x) (ascending)."""
    n = len(coeffs)
    out = [0.0] * n
    for k, c in enumerate(coeffs):
        # c * (x + s)^k = c * sum_j C(k,j) s^(k-j) x^j
        for j in range(k + 1):
            out[j] += c * math.comb(k, j) * s ** (k - j)
    return out


def _poly_mul(a: Sequence[float], b: Sequence[float]) -> List[float]:
    out = [0.0] * (len(a) + len(b) - 1)
    for i, ai in enumerate(a):
        for j, bj in enumerate(b):
            out[i + j] += ai * bj
    return out


def _poly_int(coeffs: Sequence[float]) -> List[float]:
    """Antiderivative with zero constant term."""
    return [0.0] + [c / (k + 1) for k, c in enumerate(coeffs)]


@dataclass
class PiecewisePoly:
    """Piecewise polynomial on [breaks[0], breaks[-1]], zero outside.

    ``coeffs[i]`` (ascending) applies on [breaks[i], breaks[i+1]).
    """

    breaks: List[float]
    coeffs: List[List[float]]

    # -- evaluation ---------------------------------------------------------

    def __call__(self, x):
        x = np.asarray(x, dtype=np.float64)
        out = np.zeros_like(x)
        for lo, hi, c in self.pieces():
            sel = (x >= lo) & (x < hi)
            out = np.where(sel, np.polyval(list(reversed(c)), x), out)
        return out

    def pieces(self):
        for i, c in enumerate(self.coeffs):
            yield self.breaks[i], self.breaks[i + 1], c

    # -- calculus -----------------------------------------------------------

    def antiderivative_at(self, x: float) -> float:
        """∫_{-inf}^x p(t) dt (p is zero outside its support)."""
        total = 0.0
        for lo, hi, c in self.pieces():
            if x <= lo:
                break
            icoef = _poly_int(c)
            upper = min(x, hi)
            total += _poly_eval(icoef, upper) - _poly_eval(icoef, lo)
        return total

    def box_convolve(self, a: float) -> "PiecewisePoly":
        """Convolution with rect_a (indicator of [-a/2, a/2], height 1).

        (p * rect_a)(t) = P(t + a/2) - P(t - a/2)  with P the antiderivative.
        New breakpoints are {b ± a/2}; within each new interval both shifted
        antiderivative arguments stay inside a single old piece, so the
        result is again polynomial there.  This is exact (no sampling).
        """
        h = a / 2.0
        pts = sorted({round(b + s, 15) for b in self.breaks for s in (-h, h)})
        new_breaks: List[float] = pts
        new_coeffs: List[List[float]] = []
        # Precompute per-piece antiderivatives and the running constants so
        # that P is continuous and P(x)=0 left of the support.
        antis: List[List[float]] = []
        consts: List[float] = []
        run = 0.0
        for lo, hi, c in self.pieces():
            ic = _poly_int(c)
            consts.append(run - _poly_eval(ic, lo))
            antis.append(ic)
            run += _poly_eval(ic, hi) - _poly_eval(ic, lo)
        total_mass = run

        def P_piece(x_mid: float):
            """Antiderivative as polynomial valid near x_mid (as coeffs)."""
            if x_mid <= self.breaks[0]:
                return [0.0]
            if x_mid >= self.breaks[-1]:
                return [total_mass]
            for i in range(len(self.coeffs)):
                if self.breaks[i] <= x_mid < self.breaks[i + 1]:
                    c = list(antis[i])
                    c[0] += consts[i]
                    return c
            return [total_mass]

        for i in range(len(new_breaks) - 1):
            mid = 0.5 * (new_breaks[i] + new_breaks[i + 1])
            up = _poly_shift(P_piece(mid + h), h)      # P(t + h) as poly in t
            dn = _poly_shift(P_piece(mid - h), -h)     # P(t - h)
            n = max(len(up), len(dn))
            up += [0.0] * (n - len(up))
            dn += [0.0] * (n - len(dn))
            new_coeffs.append([u - d for u, d in zip(up, dn)])
        return PiecewisePoly(new_breaks, new_coeffs)

    def scale_arg(self, s: float) -> "PiecewisePoly":
        """Return q(x) = p(s·x)."""
        breaks = [b / s for b in self.breaks]
        coeffs = [[c * s**k for k, c in enumerate(piece)] for piece in self.coeffs]
        if s < 0:
            breaks = list(reversed(breaks))
            coeffs = list(reversed(coeffs))
        return PiecewisePoly(breaks, coeffs)

    def scale_val(self, s: float) -> "PiecewisePoly":
        return PiecewisePoly(
            list(self.breaks), [[c * s for c in piece] for piece in self.coeffs]
        )

    def derivative(self) -> "PiecewisePoly":
        return PiecewisePoly(
            list(self.breaks),
            [[c * k for k, c in enumerate(piece)][1:] or [0.0] for piece in self.coeffs],
        )

    def l2_norm(self) -> float:
        total = 0.0
        for lo, hi, c in self.pieces():
            sq = _poly_int(_poly_mul(c, c))
            total += _poly_eval(sq, hi) - _poly_eval(sq, lo)
        return math.sqrt(total)

    def linf_norm(self, grid: int = 4096) -> float:
        xs = np.linspace(self.breaks[0], self.breaks[-1], grid, endpoint=False)
        return float(np.max(np.abs(self(xs))))

    def autocorrelation(self) -> "PiecewisePoly":
        """(p * p)(t) for even p — used for the kernel profile E_w[(f*f)(x/w)]."""
        # (p*p)(t) = ∫ p(u) p(t-u) du.  For even p this equals the
        # autocorrelation.  Compute exactly piece-by-piece.
        breaks = sorted(
            {round(bi + bj, 15) for bi in self.breaks for bj in self.breaks}
        )
        coeffs = []
        for i in range(len(breaks) - 1):
            tm = 0.5 * (breaks[i] + breaks[i + 1])
            # Polynomial in t on this interval: sum over piece pairs of
            # ∫ p_a(u) p_b(t-u) du over the overlap — evaluate by expanding
            # p_b(t-u) in u with t symbolic.  To stay simple (and exact
            # enough), evaluate the convolution numerically at deg+1 points
            # within the interval and fit the unique interpolating poly.
            deg = 2 * max(len(c) for c in self.coeffs)  # generous bound
            ts = np.linspace(
                breaks[i], breaks[i + 1], deg + 1, endpoint=True
            )
            ts = ts * (1 - 1e-12) + tm * 1e-12  # keep strictly inside
            vals = [self._conv_at(float(t)) for t in ts]
            fit = np.polynomial.polynomial.polyfit(ts - tm, vals, deg)
            coeffs.append(list(_poly_shift(list(fit), -tm)))
        return PiecewisePoly(breaks, coeffs)

    def _conv_at(self, t: float) -> float:
        """Exact (p*p)(t) via per-piece-pair polynomial integration."""
        total = 0.0
        for lo_a, hi_a, ca in self.pieces():
            # overlap in u of [lo_a, hi_a] with [t - hi_b, t - lo_b]
            for lo_b, hi_b, cb in self.pieces():
                lo = max(lo_a, t - hi_b)
                hi = min(hi_a, t - lo_b)
                if hi <= lo:
                    continue
                # integrand: ca(u) * cb(t - u) as poly in u
                cb_t = _poly_shift([c * ((-1) ** k) for k, c in enumerate(cb)], -t)
                # cb(t-u) = sum_k cb_k (t-u)^k ; rewrite: q(u) = cb(t - u)
                # (t-u)^k = (-(u - t))^k -> coeffs of poly in (u - t) times
                # (-1)^k, then shift by +t:  handled above via sign+shift.
                prod = _poly_mul(ca, cb_t)
                ip = _poly_int(prod)
                total += _poly_eval(ip, hi) - _poly_eval(ip, lo)
        return total

    def as_dict(self) -> dict:
        return {"breaks": list(map(float, self.breaks)),
                "coeffs": [list(map(float, c)) for c in self.coeffs]}


def rect_bucket() -> PiecewisePoly:
    """f = rect: support [-1/2,1/2], ||f||_2 = 1 already."""
    return PiecewisePoly([-0.5, 0.5], [[1.0]])


def smooth_bucket(q: int) -> PiecewisePoly:
    """C^{q-1} bucket: (rect * rect_{1/(2q)}^{*q})(2x), normalized.

    Support of the inner convolution is 1 + q/(2q) = 3/2, so after the
    argument scaling by 2 the support is [-3/8, 3/8] ⊂ [-1/2, 1/2]. q=2
    recovers the paper's Table-1 function f = (rect*rect_{1/4}*rect_{1/4})(2x).
    """
    if q < 1:
        raise ValueError("q >= 1; use rect_bucket() for the unsmoothed case")
    pp = rect_bucket()
    for _ in range(q):
        pp = pp.box_convolve(1.0 / (2 * q))
    pp = pp.scale_arg(2.0)
    return pp.scale_val(1.0 / pp.l2_norm())


def paper_smooth_bucket() -> PiecewisePoly:
    """The exact Table-1 bucket function of the paper (q = 2)."""
    return smooth_bucket(2)


def bucket_by_name(name: str) -> PiecewisePoly:
    if name == "rect":
        return rect_bucket()
    if name.startswith("smooth"):
        q = int(name[6:]) if len(name) > 6 else 2
        return smooth_bucket(q)
    raise ValueError(f"unknown bucket function {name!r}")
