"""L1 Pallas kernel: WLSH hashing + bucket-shaping weights (paper Def. 5/6).

For each of ``m`` LSH instances (w^s, z^s) and each of ``n`` points x:

    t_l   = (x_l - z_l) / w_l
    c_l   = floor(t_l + 1/2)          -- the bucket coordinate round((x-z)/w)
    r_l   = c_l - t_l                 -- in-bucket residual in (-1/2, 1/2]
    id    = sum_l c_l * mix_l         -- i32 wrap-around mix to a scalar id
    wt    = prod_l f(r_l)             -- the f^{⊗d} weight of Def. 6

This is the O(n·d·m) hot spot of WLSH preprocessing. The kernel is tiled over
n (BLOCK_N rows of X per VMEM block, full d in-register product reduction)
with the m instances as the outer grid axis, expressing the HBM↔VMEM schedule
via BlockSpec. ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU perf is estimated in DESIGN.md §Perf.

Padding contract (DESIGN.md §6): ``mask`` zeroes padded feature dims (their
hash coordinate contributes 0, their weight factor contributes 1). Padded
points / padded instances are handled downstream (β=0 weights, divisor input).
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .bucketfn import PiecewisePoly, bucket_by_name

DEFAULT_BLOCK_N = 256


def eval_bucket_jnp(pp_pieces: Sequence[Tuple[float, float, List[float]]], r):
    """Evaluate a piecewise polynomial at ``r`` with pure jnp ops.

    The piece list is baked in as constants (it is tiny: ≤ ~10 pieces of
    degree ≤ q). Unrolled select+Horner — Pallas-safe, no gather/searchsorted.
    """
    out = jnp.zeros_like(r)
    for lo, hi, coeffs in pp_pieces:
        acc = jnp.zeros_like(r)
        for c in reversed(coeffs):
            acc = acc * r + c
        out = jnp.where((r >= lo) & (r < hi), acc, out)
    return out


def _pieces(pp: PiecewisePoly):
    return [(float(lo), float(hi), [float(c) for c in cs]) for lo, hi, cs in pp.pieces()]


def _hash_kernel(x_ref, w_ref, z_ref, mix_ref, mask_ref, ids_ref, wts_ref, *,
                 pieces, rect: bool):
    x = x_ref[...]                       # (BN, d)
    w = w_ref[...]                       # (1, d)
    z = z_ref[...]                       # (1, d)
    mix = mix_ref[...]                   # (1, d) int32
    mask = mask_ref[...]                 # (1, d) float32 in {0,1}
    t = (x - z) / w
    c = jnp.floor(t + 0.5)
    ci = c.astype(jnp.int32) * mask.astype(jnp.int32)
    ids = jnp.sum(ci * mix, axis=1, dtype=jnp.int32)          # i32 wrap mix
    if rect:
        # f = rect: the weight is identically 1 on the residual range.
        wts = jnp.ones((x.shape[0],), dtype=x.dtype)
    else:
        r = c - t
        fv = eval_bucket_jnp(pieces, r)
        wts = jnp.prod(jnp.where(mask > 0, fv, 1.0), axis=1)
    ids_ref[...] = ids[None, :]
    wts_ref[...] = wts[None, :].astype(jnp.float32)


def wlsh_hash_weights(x, w, z, mix, mask, *, bucket: str = "rect",
                      block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """Hash all points under all m LSH instances.

    Args:
      x:    f32[n, d]  data points (padded).
      w:    f32[m, d]  per-instance grid widths, iid from p(·).
      z:    f32[m, d]  per-instance shifts, uniform in [0, w].
      mix:  i32[1, d]  odd mixing multipliers collapsing the d-dim bucket
                       coordinate to a scalar id (shared across instances).
      mask: f32[1, d]  1 for real feature dims, 0 for padding.
      bucket: bucket-shaping function name ("rect", "smooth2", ...).

    Returns:
      ids i32[m, n], weights f32[m, n].
    """
    n, d = x.shape
    m = w.shape[0]
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    pp = bucket_by_name(bucket)
    kern = functools.partial(
        _hash_kernel, pieces=_pieces(pp), rect=(bucket == "rect"))
    grid = (m, n // block_n)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),   # X tile
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),         # w^s
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),         # z^s
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),         # mix
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),         # mask
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, z, mix, mask)
