"""L1 Pallas kernel: Random Fourier Features (baseline of Table 2).

phi(x) = sqrt(2/D) * cos(x @ Omega + b),  Omega ~ N(0, 2*gamma I) columns,
b ~ U[0, 2pi) — the Rahimi-Recht estimator of the squared-exponential kernel
k(x,y) = exp(-gamma ||x-y||_2^2).

MXU-shaped: tiled (BLOCK_N x d) @ (d x BLOCK_D) matmul with the full feature
dimension d kept resident (d_pad <= 512 fits VMEM comfortably), cos applied
to the accumulator tile before writeback. interpret=True per the environment
contract (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_D = 512


def _rff_kernel(x_ref, omega_ref, b_ref, scale_ref, z_ref):
    x = x_ref[...]             # (BN, d)
    om = omega_ref[...]        # (d, BD)
    b = b_ref[...]             # (1, BD)
    s = scale_ref[...]         # (1, 1) = sqrt(2/D)
    acc = jnp.dot(x, om, preferred_element_type=jnp.float32)
    z_ref[...] = s * jnp.cos(acc + b)


def rff_features(x, omega, b, scale, *, block_n: int = DEFAULT_BLOCK_N,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """Compute the RFF feature matrix Z = sqrt(2/D) cos(X Omega + b).

    Args:
      x:     f32[n, d]
      omega: f32[d, D]   frequency matrix (columns ~ N(0, 2 gamma I)).
      b:     f32[1, D]   phase offsets.
      scale: f32[1, 1]   sqrt(2/D) (input so D-padding can adjust it).

    Returns: f32[n, D].
    """
    n, d = x.shape
    dd = omega.shape[1]
    bn = min(block_n, n)
    bd = min(block_d, dd)
    if n % bn or dd % bd:
        raise ValueError(f"n={n} % {bn} or D={dd} % {bd} != 0")
    return pl.pallas_call(
        _rff_kernel,
        grid=(n // bn, dd // bd),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bd), lambda i, j: (0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, dd), jnp.float32),
        interpret=interpret,
    )(x, omega, b, scale)
