"""Pure-numpy correctness oracles for every L1 Pallas kernel.

These are the ground truth the Pallas kernels are pytest-verified against
(``python/tests/``).  They intentionally use the most direct formulation —
no tiling, no accumulation tricks — so a disagreement always implicates the
kernel, not the oracle.
"""

from __future__ import annotations

import numpy as np

from .bucketfn import bucket_by_name


def wlsh_hash_weights_ref(x, w, z, mix, mask, bucket: str = "rect"):
    """Reference for kernels.wlsh.wlsh_hash_weights (float32 semantics).

    Args match the kernel: x f32[n,d], w f32[m,d], z f32[m,d], mix i32[1,d],
    mask f32[1,d].  Returns (ids i32[m,n], weights f32[m,n]).
    """
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    z = np.asarray(z, np.float32)
    mix = np.asarray(mix, np.int32).reshape(-1)
    mask = np.asarray(mask, np.float32).reshape(-1)
    m, d = w.shape
    n = x.shape[0]
    pp = bucket_by_name(bucket)
    ids = np.zeros((m, n), np.int32)
    wts = np.zeros((m, n), np.float32)
    for s in range(m):
        t = (x - z[s][None, :]) / w[s][None, :]          # (n, d) f32
        c = np.floor(t + np.float32(0.5)).astype(np.float32)
        ci = c.astype(np.int32) * mask.astype(np.int32)[None, :]
        # i32 wrap-around mix (numpy wraps on int32 mult/add like XLA)
        with np.errstate(over="ignore"):
            ids[s] = np.sum(ci * mix[None, :], axis=1, dtype=np.int32)
        if bucket == "rect":
            wts[s] = 1.0
        else:
            r = (c - t).astype(np.float64)
            fv = pp(r)                                    # (n, d)
            fv = np.where(mask[None, :] > 0, fv, 1.0)
            wts[s] = np.prod(fv, axis=1).astype(np.float32)
    return ids, wts


def rff_features_ref(x, omega, b, scale):
    """Reference for kernels.rff.rff_features."""
    x = np.asarray(x, np.float32)
    omega = np.asarray(omega, np.float32)
    b = np.asarray(b, np.float32).reshape(-1)
    s = float(np.asarray(scale).reshape(()))
    return (s * np.cos(x @ omega + b[None, :])).astype(np.float32)


def kernel_matrix_ref(xq, x, scale, kind: str):
    """Dense exact kernel matrix K(xq, x) — oracle for the block mat-vec."""
    xq = np.asarray(xq, np.float64)
    x = np.asarray(x, np.float64)
    s = float(scale)
    if kind == "laplace":
        dist = np.abs(xq[:, None, :] - x[None, :, :]).sum(axis=2)
        return np.exp(-dist / s)
    d2 = ((xq[:, None, :] - x[None, :, :]) ** 2).sum(axis=2)
    if kind == "se":
        return np.exp(-d2 / (s * s))
    if kind == "matern52":
        r = np.sqrt(d2) / s
        return (1.0 + r + r * r / 3.0) * np.exp(-r)
    raise ValueError(f"unknown kernel kind {kind!r}")


def kernel_block_matvec_ref(xq, x, beta, scale, kind: str):
    """Reference for kernels.exact.kernel_block_matvec."""
    K = kernel_matrix_ref(xq, x, scale, kind)
    return (K @ np.asarray(beta, np.float64).reshape(-1)).astype(np.float32)


def wlsh_matvec_ref(ids, weights, beta, inv_m):
    """Reference for model.wlsh_matvec: y = inv_m * sum_s D_s A_s A_s^T D_s b.

    Done the slow, obviously-correct way: for each instance, for each bucket,
    the load is sum of weight*beta over members (paper §4), and each member
    receives weight * load.
    """
    ids = np.asarray(ids)
    weights = np.asarray(weights, np.float64)
    beta = np.asarray(beta, np.float64).reshape(-1)
    m, n = ids.shape
    y = np.zeros(n, np.float64)
    for s in range(m):
        for b in np.unique(ids[s]):
            sel = ids[s] == b
            load = np.sum(weights[s][sel] * beta[sel])
            y[sel] += weights[s][sel] * load
    return (y * float(inv_m)).astype(np.float32)


def wlsh_kernel_value_ref(delta, bucket: str, p_shape: float,
                          n_quad: int = 20000, w_max: float = 80.0):
    """Numerical oracle for the WLSH kernel k_{f,p} (Def. 8), per coordinate.

    k_1d(delta) = E_{w ~ Gamma(p_shape, 1)}[(f*f)(delta / w)], computed by
    trapezoid quadrature over w — used to cross-check the Rust quadrature
    implementation and the estimator's unbiasedness.
    """
    from math import gamma as gamma_fn

    pp = bucket_by_name(bucket)
    ff = pp.autocorrelation()
    ws = np.linspace(1e-9, w_max, n_quad)
    pdf = ws ** (p_shape - 1.0) * np.exp(-ws) / gamma_fn(p_shape)
    delta = np.atleast_1d(np.asarray(delta, np.float64))
    out = np.empty_like(delta)
    for i, dl in enumerate(delta):
        vals = ff(dl / ws)
        out[i] = np.trapezoid(vals * pdf, ws)
    return out
