"""L1 Pallas kernel: blockwise exact-kernel mat-vec (Table 1/2 baselines).

Computes  y = K(Xq, X) @ beta  for the exact shift-invariant kernels the paper
benchmarks against (squared exponential, Matérn-5/2, Laplace), without ever
materializing the q×n kernel matrix: the grid walks (row-block i, col-block j)
tiles, evaluates the kernel on a (BQ, BN) tile and accumulates the partial
mat-vec into the output row block.  This is the O(n^2 d) hot spot of exact
KRR (footnote 2 of the paper).

SE / Matérn tiles are MXU-shaped (pairwise squared distances via a
(BQ,d)@(d,BN) matmul); the Laplace tile needs an L1 distance, which has no
matmul form — it accumulates |x_i - x_j| over d in chunks (VMEM-bounded).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_N = 512
L1_CHUNK = 32

KINDS = ("se", "matern52", "laplace")


def _tile_dist2(xq, x):
    """Pairwise squared L2 distances via the matmul trick (MXU-shaped)."""
    q2 = jnp.sum(xq * xq, axis=1, keepdims=True)          # (BQ, 1)
    n2 = jnp.sum(x * x, axis=1, keepdims=True).T          # (1, BN)
    cross = jnp.dot(xq, x.T, preferred_element_type=jnp.float32)
    return jnp.maximum(q2 + n2 - 2.0 * cross, 0.0)


def _tile_dist1(xq, x):
    """Pairwise L1 distances, accumulated over d in VMEM-sized chunks."""
    d = xq.shape[1]
    acc = jnp.zeros((xq.shape[0], x.shape[0]), dtype=jnp.float32)
    for lo in range(0, d, L1_CHUNK):
        hi = min(lo + L1_CHUNK, d)
        diff = xq[:, None, lo:hi] - x[None, :, lo:hi]
        acc = acc + jnp.sum(jnp.abs(diff), axis=2)
    return acc


def _kernel_tile(kind: str, xq, x, inv_scale):
    if kind == "se":
        return jnp.exp(-_tile_dist2(xq, x) * inv_scale * inv_scale)
    if kind == "matern52":
        r = jnp.sqrt(_tile_dist2(xq, x)) * inv_scale
        return (1.0 + r + r * r / 3.0) * jnp.exp(-r)
    if kind == "laplace":
        return jnp.exp(-_tile_dist1(xq, x) * inv_scale)
    raise ValueError(f"unknown kernel kind {kind!r}")


def _matvec_kernel(xq_ref, x_ref, beta_ref, s_ref, y_ref, *, kind: str):
    j = pl.program_id(1)
    xq = xq_ref[...]               # (BQ, d)
    x = x_ref[...]                 # (BN, d)
    beta = beta_ref[...]           # (1, BN)
    inv_scale = 1.0 / s_ref[0, 0]
    tile = _kernel_tile(kind, xq, x, inv_scale)           # (BQ, BN)
    part = jnp.sum(tile * beta, axis=1)                   # (BQ,)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += part[None, :]


def kernel_block_matvec(xq, x, beta, scale, *, kind: str,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = True):
    """y[i] = sum_j k(xq_i, x_j) * beta_j  (no K materialization).

    Args:
      xq:    f32[q, d]   query rows (xq = x for the training mat-vec).
      x:     f32[n, d]   support points.
      beta:  f32[1, n]   coefficient vector.
      scale: f32[1, 1]   kernel bandwidth s (k uses distances divided by s).
      kind:  "se" | "matern52" | "laplace".

    Returns: f32[1, q].
    """
    q, d = xq.shape
    n = x.shape[0]
    bq = min(block_q, q)
    bn = min(block_n, n)
    if q % bq or n % bn:
        raise ValueError(f"q={q} % {bq} or n={n} % {bn} != 0")
    kern = functools.partial(_matvec_kernel, kind=kind)
    return pl.pallas_call(
        kern,
        grid=(q // bq, n // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, q), jnp.float32),
        interpret=interpret,
    )(xq, x, beta, scale)
