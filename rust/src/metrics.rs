//! Serving/training metrics: counters, wall-clock timers, and a latency
//! histogram with exact percentiles (sample-bounded reservoir).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter (shared across worker threads).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Quantiles of one latency population, in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Latency recorder: keeps up to `cap` most recent samples (ring) and
/// aggregate sums for mean/throughput.
pub struct LatencyHistogram {
    samples: Mutex<Vec<f64>>,
    cap: usize,
    pub count: Counter,
    sum_secs: Mutex<f64>,
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> Self {
        LatencyHistogram {
            samples: Mutex::new(Vec::with_capacity(cap)),
            cap,
            count: Counter::default(),
            sum_secs: Mutex::new(0.0),
        }
    }

    pub fn record(&self, secs: f64) {
        self.count.add(1);
        *self.sum_secs.lock().unwrap() += secs;
        let mut s = self.samples.lock().unwrap();
        if s.len() == self.cap {
            // overwrite pseudo-randomly to stay representative
            let idx = (self.count.get() as usize * 2654435761) % self.cap;
            s[idx] = secs;
        } else {
            s.push(secs);
        }
    }

    /// Percentile summary over retained samples (one sort for all four
    /// quantiles — the serving `stats` command reads them together).
    pub fn summary(&self) -> LatencySummary {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return LatencySummary::default();
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let at = |p: f64| s[((s.len() as f64 * p) as usize).min(s.len() - 1)];
        LatencySummary { p50: at(0.50), p90: at(0.90), p95: at(0.95), p99: at(0.99) }
    }

    /// (p50, p90, p99) over retained samples.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        let s = self.summary();
        (s.p50, s.p90, s.p99)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count.get();
        if c == 0 {
            0.0
        } else {
            *self.sum_secs.lock().unwrap() / c as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(2);
        c.add(3);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=100 {
            h.record(i as f64);
        }
        let (p50, p90, p99) = h.percentiles();
        assert!((p50 - 51.0).abs() <= 1.0);
        assert!((p90 - 91.0).abs() <= 1.0);
        assert!((p99 - 100.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert_eq!(h.count.get(), 100);
    }

    #[test]
    fn summary_quantiles_are_ordered_and_include_p95() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=200 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert!((s.p95 - 191.0).abs() <= 1.0, "p95 {}", s.p95);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
        // tuple view stays consistent with the summary
        assert_eq!(h.percentiles(), (s.p50, s.p90, s.p99));
        // empty histogram: all zeros, no panic
        assert_eq!(LatencyHistogram::new(8).summary(), LatencySummary::default());
    }

    #[test]
    fn histogram_bounded_memory() {
        let h = LatencyHistogram::new(16);
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.samples.lock().unwrap().len(), 16);
        assert_eq!(h.count.get(), 10_000);
    }
}
