//! Serving/training metrics: counters, wall-clock timers, and a latency
//! histogram with exact percentiles (sample-bounded reservoir).

use crate::util::stats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counter (shared across worker threads).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, v: u64) {
        self.add_fetch(v);
    }

    /// Adds `v` and returns the counter value from *before* the addition —
    /// a unique per-call sequence number under concurrent use.
    pub fn add_fetch(&self, v: u64) -> u64 {
        self.0.fetch_add(v, Ordering::Relaxed)
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Quantiles of one latency population, in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Latency recorder: keeps up to `cap` most recent samples (ring) and
/// aggregate sums for mean/throughput.
pub struct LatencyHistogram {
    samples: Mutex<Vec<f64>>,
    cap: usize,
    pub count: Counter,
    sum_secs: Mutex<f64>,
}

/// Reservoir slot for sequence number `seq`: multiply by a 64-bit odd
/// constant, keep the *high* 32 bits, then reduce mod `cap`. The previous
/// `seq * 2654435761 % cap` kept the low bits of the product — but a
/// Fibonacci-style multiply mixes upward, so the low bits are the biased
/// half: with a power-of-two `cap`, any stride-2^k request pattern
/// collapsed every overwrite into a single slot (the odd-constant product
/// of a multiple of 16 is still a multiple of 16).
fn slot(seq: u64, cap: usize) -> usize {
    ((seq.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % cap
}

impl LatencyHistogram {
    pub fn new(cap: usize) -> Self {
        LatencyHistogram {
            samples: Mutex::new(Vec::with_capacity(cap)),
            cap,
            count: Counter::default(),
            sum_secs: Mutex::new(0.0),
        }
    }

    pub fn record(&self, secs: f64) {
        // Pre-increment value: unique per call even when threads race, unlike
        // re-reading the counter after the add.
        let seq = self.count.add_fetch(1);
        *self.sum_secs.lock().unwrap() += secs;
        let mut s = self.samples.lock().unwrap();
        if s.len() == self.cap {
            s[slot(seq, self.cap)] = secs;
        } else {
            s.push(secs);
        }
    }

    /// Percentile summary over retained samples (one sort for all four
    /// quantiles — the serving `stats` command reads them together).
    pub fn summary(&self) -> LatencySummary {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return LatencySummary::default();
        }
        // Total-order sort + nearest-rank percentiles (util::stats): a NaN
        // sample sorts past the finite values instead of panicking the
        // serving stats path, and the rank rule matches util::timer's.
        stats::sort_samples(&mut s);
        let at = |p: f64| stats::percentile(&s, p);
        LatencySummary { p50: at(0.50), p90: at(0.90), p95: at(0.95), p99: at(0.99) }
    }

    /// (p50, p90, p99) over retained samples.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        let s = self.summary();
        (s.p50, s.p90, s.p99)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count.get();
        if c == 0 {
            0.0
        } else {
            *self.sum_secs.lock().unwrap() / c as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(2);
        c.add(3);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=100 {
            h.record(i as f64);
        }
        let (p50, p90, p99) = h.percentiles();
        // Nearest-rank is exact on 1..=100: rank ceil(p * 100).
        assert_eq!(p50, 50.0);
        assert_eq!(p90, 90.0);
        assert_eq!(p99, 99.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert_eq!(h.count.get(), 100);
    }

    #[test]
    fn percentiles_do_not_over_report_at_small_counts() {
        let h = LatencyHistogram::new(8);
        h.record(1.0);
        h.record(2.0);
        // p50 of two samples is the lower one under nearest-rank; the old
        // truncating index `(n * p) as usize` returned the upper.
        assert_eq!(h.summary().p50, 1.0);
        let one = LatencyHistogram::new(8);
        one.record(3.0);
        assert_eq!(one.summary(), LatencySummary { p50: 3.0, p90: 3.0, p95: 3.0, p99: 3.0 });
    }

    #[test]
    fn histogram_survives_huge_counter_values() {
        let h = LatencyHistogram::new(8);
        // Seed the request counter far past the range where the old slot
        // computation (`count * 2654435761` without wrapping) overflowed and
        // panicked in debug builds.
        h.count.add(u64::MAX - 1_000);
        for i in 0..64 {
            h.record(i as f64);
        }
        assert_eq!(h.samples.lock().unwrap().len(), 8);
        assert_eq!(h.count.add_fetch(0), (u64::MAX - 1_000).wrapping_add(64));
    }

    #[test]
    fn summary_quantiles_are_ordered_and_include_p95() {
        let h = LatencyHistogram::new(1000);
        for i in 1..=200 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.p95, 190.0, "nearest-rank p95 of 1..=200 is rank 190");
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
        // tuple view stays consistent with the summary
        assert_eq!(h.percentiles(), (s.p50, s.p90, s.p99));
        // empty histogram: all zeros, no panic
        assert_eq!(LatencyHistogram::new(8).summary(), LatencySummary::default());
    }

    #[test]
    fn summary_stays_finite_when_a_nan_is_recorded() {
        // One poisoned sample must not abort the stats path (the old
        // partial_cmp().unwrap() comparator panicked) and must not leak
        // into the quantiles: +NaN sorts after every finite value.
        let h = LatencyHistogram::new(1000);
        for i in 1..=99 {
            h.record(i as f64);
        }
        h.record(f64::NAN);
        let s = h.summary();
        assert!(s.p50.is_finite() && s.p90.is_finite() && s.p95.is_finite());
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0, "p99 rank 99 of 100 lands on the last finite sample");
    }

    #[test]
    fn reservoir_slots_cover_the_ring_under_strided_sequences() {
        use std::collections::HashSet;
        let cap = 16;
        // Stride-16 sequence numbers: the old low-bits hash mapped every one
        // of these to slot 0 (odd · 16k is still ≡ 0 mod 16); the high-bits
        // hash must spread them over the whole ring.
        let strided: HashSet<usize> = (0..256u64).map(|k| slot(k * 16, cap)).collect();
        assert_eq!(strided.len(), cap, "stride-16 seqs must reach every slot");
        // Consecutive sequences must also cover the ring quickly.
        let consecutive: HashSet<usize> = (0..64u64).map(|k| slot(k, cap)).collect();
        assert_eq!(consecutive.len(), cap);
        // And occupancy should be roughly balanced over a long run.
        let mut counts = vec![0usize; cap];
        for seq in 0..1600u64 {
            counts[slot(seq, cap)] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min >= 50 && *max <= 200, "slot occupancy skewed: min {min}, max {max}");
    }

    #[test]
    fn histogram_bounded_memory() {
        let h = LatencyHistogram::new(16);
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert_eq!(h.samples.lock().unwrap().len(), 16);
        assert_eq!(h.count.get(), 10_000);
    }
}
