//! Online-learning subsystem: incremental sketch updates, warm-started
//! re-solves, and uncertainty-aware serving.
//!
//! Three pieces turn a trained model into a continuously-updating,
//! uncertainty-reporting service:
//!
//! * [`OnlineTrainer`] — owns the growable sketch and the target vector.
//!   [`append`](OnlineTrainer::append) hashes new rows into the existing
//!   per-instance bucket tables (bit-identical to a from-scratch build on
//!   the concatenated data — `tests/online_equivalence.rs`), re-solves the
//!   ridge system, and hands back a fresh [`TrainedModel`] the caller
//!   swaps into the [`ModelRegistry`](crate::coordinator::ModelRegistry)
//!   without dropping a connection.
//! * [`VarianceEstimator`] — sketched KRR posterior variance
//!   σ²(q) = k̃(q,q) − k̃_qᵀ(K̃+λI)⁻¹k̃_q, with the quadratic form
//!   approximated by rank-r Gauss–Lanczos quadrature
//!   ([`lanczos_quadform_inv`]) and cross-checked against an exact dense
//!   solve at small n ([`variance_exact`](VarianceEstimator::variance_exact)).
//! * [`UncertainPredictor`] — wraps any serving
//!   [`Predictor`] and implements
//!   [`predict_with_var`](Predictor::predict_with_var), the surface the
//!   protocol's `"var":true` flag routes to.
//!
//! # Warm starts vs bit-identity
//!
//! A warm-started CG run takes a different iterate path than a cold one,
//! so its β agrees with the cold solution only to the solver tolerance —
//! never bit for bit. [`ResolveMode`] makes the trade explicit:
//! [`ColdExact`](ResolveMode::ColdExact) (the default) *publishes* the
//! cold re-solve (bit-identical to retraining from scratch on the
//! concatenated data) while still running the warm solve to report the
//! iterations it saves; [`Warm`](ResolveMode::Warm) publishes the
//! warm-started β directly and skips the cold solve.
//!
//! # Determinism of the variance path
//!
//! The Lanczos quadrature draws no random probes: its start vector is the
//! cross-kernel vector k̃_q itself, so the estimate is a deterministic
//! function of (sketch, λ, rank, query) — no seed is involved, and
//! repeated `{"var":true}` queries return bit-identical variances. By the
//! Gauss quadrature lower-bound property on the convex integrand 1/μ, the
//! truncated quadratic-form estimate understates k̃_qᵀ(K̃+λI)⁻¹k̃_q, so the
//! reported variance overstates (never understates) the model's
//! uncertainty; the final `.max(0.0)` only guards rounding at
//! machine precision.

use std::sync::Arc;
use std::time::Instant;

use crate::api::{KrrError, MethodSpec, PrecondSpec};
use crate::config::KrrConfig;
use crate::coordinator::{ShardedOperator, TrainReport, TrainedModel};
use crate::data::{Dataset, MatrixSource};
use crate::linalg::{axpy, dot, lanczos_quadform_inv, Matrix};
use crate::sketch::{KrrOperator, Predictor, RffSketch, WlshBuildParams, WlshSketch};
use crate::solver::{
    solve_krr, solve_krr_direct, solve_krr_pcg, CgOptions, CgResult, Preconditioner,
};
use crate::util::mem;

/// Default Lanczos rank for the serving-path variance estimate (clamped
/// to n). Rank-32 quadrature resolves 1/μ over the ridge-regularized
/// spectrum to well under serving tolerance on every bundled dataset.
pub const DEFAULT_VARIANCE_RANK: usize = 32;

/// Which β an [`OnlineTrainer::append`] publishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolveMode {
    /// Publish the cold re-solve (bit-identical to a from-scratch train
    /// on the concatenated data), and *also* run the warm-started solve
    /// so the report can state the iterations a warm start saves.
    ColdExact,
    /// Publish the warm-started re-solve (previous β padded with zeros as
    /// the CG initial iterate). Equal to the cold solution only to the CG
    /// tolerance; `cold_iters` is not measured.
    Warm,
}

/// Diagnostics from one [`OnlineTrainer::append`].
#[derive(Clone, Debug)]
pub struct AppendReport {
    /// Rows appended by this call.
    pub appended: usize,
    /// Training rows after the append.
    pub n: usize,
    /// CG iterations of the warm-started re-solve.
    pub warm_iters: usize,
    /// CG iterations of the cold re-solve ([`ResolveMode::ColdExact`]
    /// only).
    pub cold_iters: Option<usize>,
    /// Relative residual of the published solve.
    pub rel_residual: f64,
    pub converged: bool,
    /// Wall-clock seconds for the append + re-solve(s).
    pub update_secs: f64,
}

/// The growable operator behind an [`OnlineTrainer`]. In-process sketches
/// are held behind `Arc` and appended copy-on-write (`Arc::make_mut`):
/// models already serving the old sketch keep it untouched. The sharded
/// operator's state lives in the shard worker processes, so appends there
/// mutate in place (every shard appends the same rows to its own
/// instance range).
enum OnlineOp {
    Wlsh(Arc<WlshSketch>),
    Rff(Arc<RffSketch>),
    Sharded(Arc<ShardedOperator>),
}

impl OnlineOp {
    fn as_dyn(&self) -> Arc<dyn KrrOperator> {
        match self {
            OnlineOp::Wlsh(s) => Arc::clone(s) as Arc<dyn KrrOperator>,
            OnlineOp::Rff(s) => Arc::clone(s) as Arc<dyn KrrOperator>,
            OnlineOp::Sharded(s) => Arc::clone(s) as Arc<dyn KrrOperator>,
        }
    }
}

/// Incremental trainer: fit once, then [`append`](Self::append) chunks of
/// rows as they arrive. Each append extends the sketch in place of a
/// rebuild (new rows are hashed under the *existing* per-instance hash
/// functions, so the updated sketch is bit-identical to one built from
/// scratch on the concatenated data), re-solves the ridge system per the
/// configured [`ResolveMode`], and returns a fresh servable model.
///
/// Supported methods: `wlsh` and `rff` (including the sharded `wlsh`
/// topology). The exact and Nyström operators have no incremental
/// formulation (landmarks/pairwise state would need re-sampling), and the
/// Nyström *preconditioner* would need the raw training rows at every
/// re-solve — all three are rejected at [`fit`](Self::fit) with
/// [`KrrError::BadParam`].
pub struct OnlineTrainer {
    config: KrrConfig,
    op: OnlineOp,
    d: usize,
    y: Vec<f64>,
    beta: Vec<f64>,
    mode: ResolveMode,
    model: Arc<TrainedModel>,
}

impl OnlineTrainer {
    /// Initial fit, replicating the
    /// [`Trainer`](crate::coordinator::Trainer) build/solve path exactly
    /// (same operator constructor arguments, same solver options), so the
    /// starting model is bit-identical to `Trainer::train` on the same
    /// dataset.
    pub fn fit(config: KrrConfig, ds: &Dataset) -> Result<OnlineTrainer, KrrError> {
        config.validate()?;
        if let PrecondSpec::Nystrom { .. } = config.precond {
            return Err(KrrError::BadParam(
                "online updates cannot use the nystrom preconditioner: \
                 it must be re-sampled from the raw training rows at every \
                 re-solve; use `jacobi` or `none`"
                    .into(),
            ));
        }
        let op = if config.topology.is_distributed() {
            OnlineOp::Sharded(ShardedOperator::build(&config, &ds.x, ds.n, ds.d)?)
        } else {
            match config.method {
                // Importance-sampled sketches append naturally: the kept
                // instances' hash functions and iweights are frozen at fit
                // time, and appended rows hash into those same instances
                // (the selection is NOT re-scored on append — documented
                // freeze-at-fit policy).
                MethodSpec::Wlsh => OnlineOp::Wlsh(Arc::new(WlshSketch::build(
                    &WlshBuildParams::from_config(&config, ds.n, ds.d),
                    ds,
                )?)),
                MethodSpec::Rff => OnlineOp::Rff(Arc::new(RffSketch::build_source(
                    ds,
                    config.budget,
                    config.scale,
                    config.seed,
                    config.chunk_rows,
                    config.workers,
                )?)),
                MethodSpec::Exact(_) | MethodSpec::Nystrom => {
                    return Err(KrrError::BadParam(format!(
                        "online updates support wlsh and rff; {} has no \
                         incremental formulation",
                        config.method
                    )));
                }
            }
        };
        let t0 = Instant::now();
        let mut tr = OnlineTrainer {
            d: ds.d,
            y: ds.y.clone(),
            beta: Vec::new(),
            mode: ResolveMode::ColdExact,
            // placeholder; replaced right below once the solve lands
            model: Arc::new(TrainedModel::assemble(
                op.as_dyn(),
                vec![0.0; ds.n],
                config.clone(),
                TrainReport {
                    build_secs: 0.0,
                    solve_secs: 0.0,
                    cg_iters: 0,
                    cg_rel_residual: 0.0,
                    converged: false,
                    operator: String::new(),
                    precond: String::new(),
                    memory_bytes: 0,
                    rows_per_sec: 0.0,
                    peak_rss_bytes: 0,
                },
            )),
            config,
            op,
        };
        let build_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let cg = tr.solve(None);
        let solve_secs = t1.elapsed().as_secs_f64();
        tr.beta = cg.beta.clone();
        tr.model = Arc::new(tr.assemble(cg, build_secs, solve_secs));
        if let Some(e) = tr.shard_failure() {
            return Err(e);
        }
        Ok(tr)
    }

    /// Choose which β future appends publish (default
    /// [`ResolveMode::ColdExact`]).
    pub fn set_mode(&mut self, mode: ResolveMode) {
        self.mode = mode;
    }

    /// The most recently published servable model.
    pub fn model(&self) -> Arc<TrainedModel> {
        Arc::clone(&self.model)
    }

    /// Training rows currently in the sketch.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Feature count per row.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Append `y_new.len()` rows (row-major `x_new`, `d` features each)
    /// and re-solve. Returns the diagnostics and the fresh model; the
    /// caller swaps the model into its registry (the trainer deliberately
    /// holds no registry handle).
    pub fn append(
        &mut self,
        x_new: &[f32],
        y_new: &[f64],
    ) -> Result<(AppendReport, Arc<TrainedModel>), KrrError> {
        let k = y_new.len();
        if k == 0 {
            return Err(KrrError::BadParam("append of zero rows".into()));
        }
        if x_new.len() != k * self.d {
            return Err(KrrError::BadParam(format!(
                "append expects {} features per row: {} rows need {} values, got {}",
                self.d,
                k,
                k * self.d,
                x_new.len()
            )));
        }
        let t0 = Instant::now();
        let chunk = self.config.chunk_rows.max(1);
        let workers = self.config.workers.max(1);
        let src = MatrixSource::new("online-append", x_new, self.d);
        let appended = match &mut self.op {
            // copy-on-write: serving models holding the old Arc keep the
            // pre-append sketch; only the trainer's copy grows
            OnlineOp::Wlsh(s) => Arc::make_mut(s).append_source(&src, chunk, workers)?,
            OnlineOp::Rff(s) => Arc::make_mut(s).append_source(&src, chunk, workers)?,
            OnlineOp::Sharded(s) => s.append(x_new)?,
        };
        self.y.extend_from_slice(y_new);
        let n = self.y.len();
        // warm start: previous β padded with zeros for the new rows
        let mut x0 = self.beta.clone();
        x0.resize(n, 0.0);
        let warm = self.solve(Some(x0));
        let warm_iters = warm.iters;
        let (published, cold_iters) = match self.mode {
            ResolveMode::ColdExact => {
                let cold = self.solve(None);
                let iters = cold.iters;
                (cold, Some(iters))
            }
            ResolveMode::Warm => (warm, None),
        };
        let update_secs = t0.elapsed().as_secs_f64();
        let report = AppendReport {
            appended,
            n,
            warm_iters,
            cold_iters,
            rel_residual: published.rel_residual,
            converged: published.converged,
            update_secs,
        };
        self.beta = published.beta.clone();
        let model = Arc::new(self.assemble(published, 0.0, update_secs));
        if let Some(e) = self.shard_failure() {
            return Err(e);
        }
        self.model = Arc::clone(&model);
        Ok((report, model))
    }

    /// One (P)CG solve over the current operator/targets, replicating the
    /// `Trainer` solver selection (plain CG when unpreconditioned, PCG
    /// otherwise) so a cold solve is bit-identical to `Trainer::train`.
    fn solve(&self, x0: Option<Vec<f64>>) -> CgResult {
        let c = &self.config;
        let opts = CgOptions {
            max_iters: c.cg_max_iters,
            tol: c.cg_tol,
            verbose: c.cg_verbose,
            x0,
        };
        let op = self.op.as_dyn();
        let precond = match c.precond {
            PrecondSpec::None => Preconditioner::Identity,
            PrecondSpec::Jacobi => match op.diag() {
                Some(diag) => Preconditioner::jacobi(&diag, c.lambda),
                None => Preconditioner::Identity,
            },
            // rejected in fit()
            PrecondSpec::Nystrom { .. } => Preconditioner::Identity,
        };
        match &precond {
            Preconditioner::Identity => solve_krr(op.as_ref(), &self.y, c.lambda, &opts),
            m => solve_krr_pcg(op.as_ref(), &self.y, c.lambda, &opts, m),
        }
    }

    /// Package a solve into a servable model (same report fields the
    /// offline trainer fills).
    fn assemble(&self, cg: CgResult, build_secs: f64, solve_secs: f64) -> TrainedModel {
        let op = self.op.as_dyn();
        let report = TrainReport {
            build_secs,
            solve_secs,
            cg_iters: cg.iters,
            cg_rel_residual: cg.rel_residual,
            converged: cg.converged,
            operator: op.name(),
            precond: match self.config.precond {
                PrecondSpec::Jacobi => "jacobi",
                _ => "none",
            }
            .to_string(),
            memory_bytes: op.memory_bytes(),
            rows_per_sec: 0.0,
            peak_rss_bytes: mem::peak_rss_bytes().unwrap_or(0),
        };
        TrainedModel::assemble(op, cg.beta, self.config.clone(), report)
    }

    /// Latched shard failure, when the operator is sharded (matvec is
    /// infallible by trait contract, so shard deaths latch inside the
    /// operator and must be surfaced after each solve).
    fn shard_failure(&self) -> Option<KrrError> {
        match &self.op {
            OnlineOp::Sharded(s) => s.failure(),
            _ => None,
        }
    }
}

/// Sketched KRR posterior variance
/// σ²(q) = k̃(q,q) − k̃_qᵀ(K̃+λI)⁻¹k̃_q, the quadratic form approximated by
/// rank-`rank` Gauss–Lanczos quadrature seeded at k̃_q itself (no random
/// probe — see the module docs on determinism).
pub struct VarianceEstimator {
    op: Arc<dyn KrrOperator>,
    lambda: f64,
    rank: usize,
}

impl VarianceEstimator {
    /// Estimator at [`DEFAULT_VARIANCE_RANK`] (clamped to n at query
    /// time).
    pub fn new(op: Arc<dyn KrrOperator>, lambda: f64) -> VarianceEstimator {
        VarianceEstimator { op, lambda, rank: DEFAULT_VARIANCE_RANK }
    }

    /// Override the Lanczos rank (higher = tighter estimate, linearly
    /// more mat-vecs per query).
    pub fn with_rank(mut self, rank: usize) -> VarianceEstimator {
        self.rank = rank.max(1);
        self
    }

    /// Posterior variance at one query row, or `None` when the operator
    /// exposes no cross-kernel vector (`KrrOperator::cross_vector`).
    /// Deterministic; non-negative; an *over*-estimate of the sketched
    /// posterior variance at truncated rank (Gauss lower bound on the
    /// quadratic form).
    pub fn variance(&self, query: &[f32]) -> Option<f64> {
        let (kxx, kx) = self.op.cross_vector(query)?;
        let n = self.op.n();
        debug_assert_eq!(kx.len(), n);
        let lambda = self.lambda;
        let op = &self.op;
        let quad = lanczos_quadform_inv(n, self.rank.min(n), &kx, |v| {
            let mut out = op.matvec(v);
            axpy(lambda, v, &mut out);
            out
        });
        Some((kxx - quad.value).max(0.0))
    }

    /// Exact-solve cross-check (O(n²) memory, O(n³) time — tests and
    /// small n only): materializes K̃ column by column and solves
    /// (K̃+λI)α = k̃_q by dense Cholesky.
    pub fn variance_exact(&self, query: &[f32]) -> Result<f64, KrrError> {
        let (kxx, kx) = self.op.cross_vector(query).ok_or_else(|| {
            KrrError::BadParam(format!(
                "{} exposes no cross-kernel vector",
                self.op.name()
            ))
        })?;
        let n = self.op.n();
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.op.matvec(&e);
            for i in 0..n {
                k[(i, j)] = col[i];
            }
        }
        let alpha = solve_krr_direct(&k, &kx, self.lambda)?;
        Ok((kxx - dot(&kx, &alpha)).max(0.0))
    }
}

/// Serving predictor that carries a [`VarianceEstimator`] beside the base
/// point-prediction handle: plain predictions delegate untouched, and
/// [`predict_with_var`](Predictor::predict_with_var) answers the
/// protocol's `"var":true` queries.
pub struct UncertainPredictor {
    base: Box<dyn Predictor>,
    var: VarianceEstimator,
}

impl UncertainPredictor {
    pub fn new(base: Box<dyn Predictor>, var: VarianceEstimator) -> UncertainPredictor {
        UncertainPredictor { base, var }
    }
}

impl Predictor for UncertainPredictor {
    fn dim(&self) -> usize {
        self.base.dim()
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.base.predict_into(queries, out)
    }

    fn predict_sparse_into(&self, queries: &crate::data::SparseChunk<'_>, out: &mut [f64]) {
        self.base.predict_sparse_into(queries, out)
    }

    fn predict_with_var(&self, queries: &[f32], out: &mut [f64], var: &mut [f64]) -> Option<()> {
        let d = self.base.dim();
        assert_eq!(queries.len() % d.max(1), 0, "query rows must have d features");
        assert_eq!(out.len(), var.len());
        self.base.predict_into(queries, out);
        for (i, v) in var.iter_mut().enumerate() {
            *v = self.var.variance(&queries[i * d..(i + 1) * d])?;
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::MethodSpec;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;

    fn small_ds(n: usize) -> Dataset {
        let mut ds = synthetic_by_name("wine", Some(n), 1).unwrap();
        ds.standardize();
        ds
    }

    fn cfg(method: MethodSpec) -> KrrConfig {
        KrrConfig {
            method,
            budget: 24,
            scale: 3.0,
            lambda: 0.4,
            cg_max_iters: 400,
            cg_tol: 1e-8,
            chunk_rows: 64,
            ..Default::default()
        }
    }

    /// Order-preserving head/tail cut (`Dataset::split` shuffles, which
    /// would break append-vs-retrain bit-identity: the sketch build is
    /// row-order-dependent).
    fn cut(ds: &Dataset, at: usize) -> (Dataset, Dataset) {
        let head = Dataset::new(
            "head",
            ds.x[..at * ds.d].to_vec(),
            ds.y[..at].to_vec(),
            ds.d,
        );
        let tail = Dataset::new(
            "tail",
            ds.x[at * ds.d..].to_vec(),
            ds.y[at..].to_vec(),
            ds.d,
        );
        (head, tail)
    }

    #[test]
    fn fit_matches_offline_trainer_bitwise() {
        let ds = small_ds(160);
        for method in [MethodSpec::Wlsh, MethodSpec::Rff] {
            let c = cfg(method);
            let offline = Trainer::new(c.clone()).train(&ds).unwrap();
            let online = OnlineTrainer::fit(c, &ds).unwrap();
            assert_eq!(offline.beta, online.model().beta, "{method:?}");
        }
    }

    #[test]
    fn append_then_cold_resolve_is_bitwise_retraining() {
        let ds = small_ds(200);
        let (head, tail) = cut(&ds, 160);
        for method in [MethodSpec::Wlsh, MethodSpec::Rff] {
            let c = cfg(method);
            let mut online = OnlineTrainer::fit(c.clone(), &head).unwrap();
            let (report, model) = online.append(&tail.x, &tail.y).unwrap();
            assert_eq!(report.appended, tail.n);
            assert_eq!(report.n, ds.n);
            assert!(report.cold_iters.is_some(), "ColdExact must measure both solves");
            let scratch = Trainer::new(c).train(&ds).unwrap();
            assert_eq!(scratch.beta, model.beta, "{method:?}");
        }
    }

    #[test]
    fn warm_mode_matches_cold_to_solver_tolerance() {
        let ds = small_ds(200);
        let (head, tail) = cut(&ds, 150);
        let c = cfg(MethodSpec::Wlsh);
        let mut online = OnlineTrainer::fit(c.clone(), &head).unwrap();
        online.set_mode(ResolveMode::Warm);
        let (report, model) = online.append(&tail.x, &tail.y).unwrap();
        assert!(report.cold_iters.is_none());
        assert!(report.converged);
        let scratch = Trainer::new(c).train(&ds).unwrap();
        for (a, b) in model.beta.iter().zip(&scratch.beta) {
            assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn unsupported_methods_are_rejected() {
        let ds = small_ds(60);
        for method in ["exact-se", "nystrom"] {
            let c = cfg(method.parse().unwrap());
            assert!(matches!(
                OnlineTrainer::fit(c, &ds),
                Err(KrrError::BadParam(_))
            ));
        }
        let c = KrrConfig {
            precond: crate::api::PrecondSpec::Nystrom { rank: 8 },
            ..cfg(MethodSpec::Wlsh)
        };
        assert!(matches!(OnlineTrainer::fit(c, &ds), Err(KrrError::BadParam(_))));
    }

    #[test]
    fn append_input_validation() {
        let ds = small_ds(80);
        let mut online = OnlineTrainer::fit(cfg(MethodSpec::Wlsh), &ds).unwrap();
        assert!(matches!(online.append(&[], &[]), Err(KrrError::BadParam(_))));
        assert!(matches!(
            online.append(&[1.0, 2.0], &[0.5]),
            Err(KrrError::BadParam(_))
        ));
    }

    #[test]
    fn variance_agrees_with_exact_solve_at_small_n() {
        let ds = small_ds(90);
        let model = Trainer::new(cfg(MethodSpec::Wlsh)).train(&ds).unwrap();
        let est = VarianceEstimator::new(Arc::clone(&model.op), 0.4).with_rank(90);
        for qi in [0usize, 7, 33] {
            let q = &ds.x[qi * ds.d..(qi + 1) * ds.d];
            let fast = est.variance(q).unwrap();
            let exact = est.variance_exact(q).unwrap();
            assert!(fast >= 0.0);
            assert!(
                (fast - exact).abs() <= 1e-6 * (1.0 + exact.abs()),
                "query {qi}: lanczos {fast} vs exact {exact}"
            );
        }
    }

    #[test]
    fn truncated_rank_overestimates_but_stays_close() {
        let ds = small_ds(120);
        let model = Trainer::new(cfg(MethodSpec::Rff)).train(&ds).unwrap();
        let est32 = VarianceEstimator::new(Arc::clone(&model.op), 0.4);
        let q = &ds.x[..ds.d];
        let fast = est32.variance(q).unwrap();
        let exact = est32.variance_exact(q).unwrap();
        // Gauss quadrature under-integrates 1/μ ⇒ variance over-estimates
        assert!(fast >= exact - 1e-9, "lanczos {fast} under exact {exact}");
        assert!((fast - exact).abs() <= 0.05 * (1.0 + exact.abs()));
    }

    #[test]
    fn predict_with_var_flows_through_the_model() {
        let ds = small_ds(100);
        let model = Trainer::new(cfg(MethodSpec::Wlsh)).train(&ds).unwrap();
        let q = &ds.x[..3 * ds.d];
        let mut out = vec![0.0; 3];
        let mut var = vec![0.0; 3];
        model
            .predictor()
            .predict_with_var(q, &mut out, &mut var)
            .expect("wlsh models support variance");
        assert_eq!(out, model.predict(q));
        assert!(var.iter().all(|v| v.is_finite() && *v >= 0.0), "{var:?}");
    }
}
