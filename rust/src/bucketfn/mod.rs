//! Bucket-shaping functions f (paper Def. 6/8) as exact piecewise
//! polynomials — the Rust mirror of `python/compile/kernels/bucketfn.py`.
//!
//! Construction is programmatic: repeated box convolution of `rect` yields
//! the C^{q-1} family `smooth(q)`; `smooth(2)` is the paper's Table-1
//! function f = (rect * rect_{1/4} * rect_{1/4})(2x), normalized. The
//! Python exporter writes the same pieces to `artifacts/bucketfn_*.json`,
//! and an integration test asserts both constructions agree to 1e-12 — so
//! the native backend and the HLO artifacts evaluate the same f.

mod poly;

pub use poly::PiecewisePoly;

use crate::util::json::Json;

/// f = rect: support [-1/2, 1/2], ||f||_2 = 1.
pub fn rect_bucket() -> PiecewisePoly {
    PiecewisePoly::new(vec![-0.5, 0.5], vec![vec![1.0]])
}

/// C^{q-1} bucket: (rect * rect_{1/(2q)}^{*q})(2x), normalized.
///
/// The inner convolution has support 3/2, so after the argument scaling by
/// 2 the support is [-3/8, 3/8] ⊂ [-1/2, 1/2]. `q = 2` is the paper's
/// Table-1 function.
pub fn smooth_bucket(q: usize) -> PiecewisePoly {
    assert!(q >= 1, "q >= 1; use rect_bucket() for the unsmoothed case");
    let mut pp = rect_bucket();
    for _ in 0..q {
        pp = pp.box_convolve(1.0 / (2.0 * q as f64));
    }
    let pp = pp.scale_arg(2.0);
    let nrm = pp.l2_norm();
    pp.scale_val(1.0 / nrm)
}

/// Resolve a bucket function by its stable name ("rect", "smooth2", ...).
pub fn bucket_by_name(name: &str) -> Option<PiecewisePoly> {
    if name == "rect" {
        return Some(rect_bucket());
    }
    if let Some(qs) = name.strip_prefix("smooth") {
        let q: usize = if qs.is_empty() { 2 } else { qs.parse().ok()? };
        if q >= 1 {
            return Some(smooth_bucket(q));
        }
    }
    None
}

/// Load a piecewise polynomial from the `aot.py` JSON export.
pub fn load_from_json(json: &Json) -> Result<PiecewisePoly, String> {
    let breaks = json
        .get("breaks")
        .and_then(Json::as_f64_vec)
        .ok_or("missing breaks")?;
    let coeffs = json
        .get("coeffs")
        .and_then(Json::as_arr)
        .ok_or("missing coeffs")?
        .iter()
        .map(|c| c.as_f64_vec().ok_or("bad coeff row"))
        .collect::<Result<Vec<_>, _>>()?;
    if breaks.len() != coeffs.len() + 1 {
        return Err("breaks/coeffs length mismatch".into());
    }
    Ok(PiecewisePoly::new(breaks, coeffs))
}

/// Compiled f32 evaluator for the hashing hot loop.
///
/// `eval` mirrors the HLO kernel bit-for-bit-ish: f32 breakpoint compares
/// and f32 Horner with f64-constants-rounded-to-f32 coefficients, in the
/// same order as `kernels/wlsh.py::eval_bucket_jnp`.
#[derive(Clone, Debug)]
pub struct BucketEval {
    /// (lo, hi, ascending coeffs) per piece, f32.
    pieces: Vec<(f32, f32, Vec<f32>)>,
    /// rect shortcut: weight is identically 1 on the residual range.
    pub is_rect: bool,
    pub linf: f32,
}

impl BucketEval {
    pub fn from_poly(pp: &PiecewisePoly, is_rect: bool) -> Self {
        let pieces = pp
            .pieces()
            .map(|(lo, hi, c)| {
                (lo as f32, hi as f32, c.iter().map(|&x| x as f32).collect())
            })
            .collect();
        BucketEval { pieces, is_rect, linf: pp.linf_norm(4096) as f32 }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        let pp = bucket_by_name(name)?;
        Some(Self::from_poly(&pp, name == "rect"))
    }

    /// Evaluate f at a residual r (f32 semantics matching the HLO kernel).
    #[inline]
    pub fn eval(&self, r: f32) -> f32 {
        if self.is_rect {
            return 1.0;
        }
        for (lo, hi, c) in &self.pieces {
            if r >= *lo && r < *hi {
                let mut acc = 0.0f32;
                for &ck in c.iter().rev() {
                    acc = acc * r + ck;
                }
                return acc;
            }
        }
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_properties() {
        let r = rect_bucket();
        assert!((r.l2_norm() - 1.0).abs() < 1e-12);
        assert_eq!(r.eval(0.0), 1.0);
        assert_eq!(r.eval(0.6), 0.0);
    }

    #[test]
    fn smooth_family_normalized_and_supported() {
        for q in 1..=4 {
            let pp = smooth_bucket(q);
            assert!(
                (pp.l2_norm() - 1.0).abs() < 1e-9,
                "q={q} norm {}",
                pp.l2_norm()
            );
            assert!(pp.support().0 >= -0.5 && pp.support().1 <= 0.5);
        }
    }

    #[test]
    fn smooth2_matches_python_values() {
        // Values produced by the Python construction (same algorithm):
        // breaks [-0.375,-0.25,-0.125,0.125,0.25,0.375], f(0)=1.50470958...
        let pp = smooth_bucket(2);
        let b = pp.breaks();
        assert_eq!(b.len(), 6);
        assert!((b[0] + 0.375).abs() < 1e-12);
        assert!((pp.eval(0.0) - 1.5047095877265524).abs() < 1e-9);
        assert!((pp.eval(0.2) - 1.2338618640400354).abs() < 1e-6);
    }

    #[test]
    fn smooth_is_even() {
        for q in [1, 2, 3] {
            let pp = smooth_bucket(q);
            for i in 0..40 {
                let x = 0.01 + 0.011 * i as f64;
                assert!(
                    (pp.eval(x) - pp.eval(-x)).abs() < 1e-9,
                    "q={q} x={x}"
                );
            }
        }
    }

    #[test]
    fn smoothness_order_continuity() {
        // smooth(q) must have q-1 continuous derivatives at breakpoints.
        for q in [2usize, 3] {
            let mut pp = smooth_bucket(q);
            for _order in 0..q {
                for &b in &pp.breaks()[1..pp.breaks().len() - 1] {
                    let lo = pp.eval(b - 1e-9);
                    let hi = pp.eval(b + 1e-9);
                    assert!((lo - hi).abs() < 1e-5, "q={q} b={b}");
                }
                pp = pp.derivative();
            }
        }
    }

    #[test]
    fn autocorrelation_rect_is_triangle() {
        let ac = rect_bucket().autocorrelation();
        for i in 0..20 {
            let t = -0.95 + 0.1 * i as f64;
            let expect = (1.0 - t.abs()).max(0.0);
            assert!((ac.eval(t) - expect).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn autocorrelation_peak_is_unit() {
        for name in ["rect", "smooth2", "smooth3"] {
            let ac = bucket_by_name(name).unwrap().autocorrelation();
            assert!((ac.eval(0.0) - 1.0).abs() < 1e-7, "{name}");
        }
    }

    #[test]
    fn bucket_eval_matches_poly_f32() {
        let pp = smooth_bucket(2);
        let be = BucketEval::from_poly(&pp, false);
        for i in 0..100 {
            let r = -0.5 + 0.01 * i as f64;
            let want = pp.eval(r) as f32;
            assert!((be.eval(r as f32) - want).abs() < 1e-5, "r={r}");
        }
    }

    #[test]
    fn bucket_eval_rect_is_one() {
        let be = BucketEval::by_name("rect").unwrap();
        assert_eq!(be.eval(0.49), 1.0);
        assert_eq!(be.eval(-0.49), 1.0);
    }

    #[test]
    fn by_name_resolution() {
        assert!(bucket_by_name("rect").is_some());
        assert!(bucket_by_name("smooth").is_some());
        assert!(bucket_by_name("smooth3").is_some());
        assert!(bucket_by_name("smooth0").is_none());
        assert!(bucket_by_name("bogus").is_none());
    }

    #[test]
    fn load_from_json_roundtrip() {
        let j = Json::parse(
            r#"{"breaks": [-0.5, 0.0, 0.5], "coeffs": [[1.0], [2.0, 1.0]]}"#,
        )
        .unwrap();
        let pp = load_from_json(&j).unwrap();
        assert_eq!(pp.eval(-0.25), 1.0);
        assert!((pp.eval(0.25) - 2.25).abs() < 1e-12);
    }
}
