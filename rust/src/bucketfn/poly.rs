//! Exact piecewise-polynomial arithmetic: evaluation, calculus, box
//! convolution, argument scaling, autocorrelation. Mirrors
//! `python/compile/kernels/bucketfn.py` operation-for-operation so both
//! languages construct bit-identical bucket functions.

/// Evaluate an ascending-coefficient polynomial at x (Horner).
fn poly_eval(coeffs: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &c in coeffs.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

/// Coefficients of p(x + s) given those of p(x).
fn poly_shift(coeffs: &[f64], s: f64) -> Vec<f64> {
    let n = coeffs.len();
    let mut out = vec![0.0; n];
    for (k, &c) in coeffs.iter().enumerate() {
        // binomial expansion of c (x+s)^k
        let mut binom = 1.0f64;
        for j in (0..=k).rev() {
            // C(k, j) iterated from j=k down: C(k,k)=1, C(k,j-1)=C(k,j)*j/(k-j+1)
            out[j] += c * binom * s.powi((k - j) as i32);
            if j > 0 {
                binom = binom * j as f64 / (k - j + 1) as f64;
            }
        }
    }
    out
}

fn poly_mul(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len() + b.len() - 1];
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            out[i + j] += ai * bj;
        }
    }
    out
}

/// Antiderivative with zero constant term.
fn poly_int(coeffs: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0];
    out.extend(coeffs.iter().enumerate().map(|(k, &c)| c / (k + 1) as f64));
    out
}

/// Solve a small dense linear system (Vandermonde fits); partial pivoting.
fn solve_small(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-300, "singular fit system");
        for row in col + 1..n {
            let f = a[row][col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = b[row];
        for k in row + 1..n {
            s -= a[row][k] * x[k];
        }
        x[row] = s / a[row][row];
    }
    x
}

/// Piecewise polynomial on [breaks[0], breaks[-1]], zero outside.
/// `coeffs[i]` (ascending) applies on [breaks[i], breaks[i+1]).
#[derive(Clone, Debug, PartialEq)]
pub struct PiecewisePoly {
    breaks: Vec<f64>,
    coeffs: Vec<Vec<f64>>,
}

impl PiecewisePoly {
    pub fn new(breaks: Vec<f64>, coeffs: Vec<Vec<f64>>) -> Self {
        assert_eq!(breaks.len(), coeffs.len() + 1, "breaks/coeffs mismatch");
        assert!(breaks.windows(2).all(|w| w[0] < w[1]), "breaks not sorted");
        PiecewisePoly { breaks, coeffs }
    }

    pub fn breaks(&self) -> &[f64] {
        &self.breaks
    }

    pub fn support(&self) -> (f64, f64) {
        (self.breaks[0], *self.breaks.last().unwrap())
    }

    pub fn pieces(&self) -> impl Iterator<Item = (f64, f64, &Vec<f64>)> {
        self.coeffs
            .iter()
            .enumerate()
            .map(move |(i, c)| (self.breaks[i], self.breaks[i + 1], c))
    }

    pub fn eval(&self, x: f64) -> f64 {
        for (lo, hi, c) in self.pieces() {
            if x >= lo && x < hi {
                return poly_eval(c, x);
            }
        }
        0.0
    }

    /// ∫_{-inf}^x p(t) dt.
    pub fn antiderivative_at(&self, x: f64) -> f64 {
        let mut total = 0.0;
        for (lo, hi, c) in self.pieces() {
            if x <= lo {
                break;
            }
            let ic = poly_int(c);
            let upper = x.min(hi);
            total += poly_eval(&ic, upper) - poly_eval(&ic, lo);
        }
        total
    }

    /// Convolution with rect_a (indicator of [-a/2, a/2], height 1) — exact.
    pub fn box_convolve(&self, a: f64) -> PiecewisePoly {
        let h = a / 2.0;
        let mut pts: Vec<f64> = self
            .breaks
            .iter()
            .flat_map(|&b| [round15(b - h), round15(b + h)])
            .collect();
        pts.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pts.dedup();
        // Continuous antiderivative P with P = 0 left of the support.
        let mut antis: Vec<Vec<f64>> = Vec::new();
        let mut run = 0.0;
        for (lo, hi, c) in self.pieces() {
            let mut ic = poly_int(c);
            ic[0] += run - poly_eval(&ic, lo);
            run = poly_eval(&ic, hi);
            antis.push(ic);
        }
        let total_mass = run;
        let p_piece = |x_mid: f64| -> Vec<f64> {
            if x_mid <= self.breaks[0] {
                return vec![0.0];
            }
            if x_mid >= *self.breaks.last().unwrap() {
                return vec![total_mass];
            }
            for i in 0..self.coeffs.len() {
                if self.breaks[i] <= x_mid && x_mid < self.breaks[i + 1] {
                    return antis[i].clone();
                }
            }
            vec![total_mass]
        };
        let mut new_coeffs = Vec::with_capacity(pts.len() - 1);
        for w in pts.windows(2) {
            let mid = 0.5 * (w[0] + w[1]);
            let up = poly_shift(&p_piece(mid + h), h);
            let dn = poly_shift(&p_piece(mid - h), -h);
            let n = up.len().max(dn.len());
            let mut c = vec![0.0; n];
            for (k, item) in c.iter_mut().enumerate() {
                *item = up.get(k).copied().unwrap_or(0.0)
                    - dn.get(k).copied().unwrap_or(0.0);
            }
            new_coeffs.push(c);
        }
        PiecewisePoly::new(pts, new_coeffs)
    }

    /// q(x) = p(s·x) for s > 0.
    pub fn scale_arg(&self, s: f64) -> PiecewisePoly {
        assert!(s > 0.0);
        let breaks = self.breaks.iter().map(|b| b / s).collect();
        let coeffs = self
            .coeffs
            .iter()
            .map(|piece| {
                piece
                    .iter()
                    .enumerate()
                    .map(|(k, &c)| c * s.powi(k as i32))
                    .collect()
            })
            .collect();
        PiecewisePoly::new(breaks, coeffs)
    }

    pub fn scale_val(&self, s: f64) -> PiecewisePoly {
        PiecewisePoly::new(
            self.breaks.clone(),
            self.coeffs
                .iter()
                .map(|p| p.iter().map(|&c| c * s).collect())
                .collect(),
        )
    }

    pub fn derivative(&self) -> PiecewisePoly {
        PiecewisePoly::new(
            self.breaks.clone(),
            self.coeffs
                .iter()
                .map(|p| {
                    if p.len() <= 1 {
                        vec![0.0]
                    } else {
                        p.iter()
                            .enumerate()
                            .skip(1)
                            .map(|(k, &c)| c * k as f64)
                            .collect()
                    }
                })
                .collect(),
        )
    }

    pub fn l2_norm(&self) -> f64 {
        let mut total = 0.0;
        for (lo, hi, c) in self.pieces() {
            let sq = poly_int(&poly_mul(c, c));
            total += poly_eval(&sq, hi) - poly_eval(&sq, lo);
        }
        total.sqrt()
    }

    pub fn linf_norm(&self, grid: usize) -> f64 {
        let (lo, hi) = self.support();
        (0..grid)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / grid as f64;
                self.eval(x).abs()
            })
            .fold(0.0, f64::max)
    }

    /// (p * p)(t) for even p — the kernel profile of Def. 8.
    ///
    /// Each interval's polynomial is reconstructed by interpolating the
    /// exact pointwise convolution (`conv_at`) at deg+1 centered nodes.
    pub fn autocorrelation(&self) -> PiecewisePoly {
        let mut pts: Vec<f64> = self
            .breaks
            .iter()
            .flat_map(|&bi| self.breaks.iter().map(move |&bj| round15(bi + bj)))
            .collect();
        pts.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pts.dedup();
        let deg = 2 * self.coeffs.iter().map(Vec::len).max().unwrap();
        let mut coeffs = Vec::with_capacity(pts.len() - 1);
        for w in pts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let tm = 0.5 * (lo + hi);
            let half = 0.5 * (hi - lo) * (1.0 - 1e-12);
            // Chebyshev-ish symmetric nodes centered at tm
            let nodes: Vec<f64> = (0..=deg)
                .map(|i| tm + half * (-1.0 + 2.0 * i as f64 / deg as f64))
                .collect();
            let vals: Vec<f64> = nodes.iter().map(|&t| self.conv_at(t)).collect();
            // Vandermonde fit in the centered variable u = t - tm
            let a: Vec<Vec<f64>> = nodes
                .iter()
                .map(|&t| (0..=deg).map(|k| (t - tm).powi(k as i32)).collect())
                .collect();
            let centered = solve_small(a, vals);
            coeffs.push(poly_shift(&centered, -tm));
        }
        PiecewisePoly::new(pts, coeffs)
    }

    /// Exact (p*p)(t) via per-piece-pair polynomial integration.
    pub fn conv_at(&self, t: f64) -> f64 {
        let mut total = 0.0;
        for (lo_a, hi_a, ca) in self.pieces() {
            for (lo_b, hi_b, cb) in self.pieces() {
                let lo = lo_a.max(t - hi_b);
                let hi = hi_a.min(t - lo_b);
                if hi <= lo {
                    continue;
                }
                // cb(t - u) as poly in u: coeffs cb_k (-1)^k in (u - t), shift
                let signed: Vec<f64> = cb
                    .iter()
                    .enumerate()
                    .map(|(k, &c)| if k % 2 == 1 { -c } else { c })
                    .collect();
                let cb_t = poly_shift(&signed, -t);
                let prod = poly_mul(ca, &cb_t);
                let ip = poly_int(&prod);
                total += poly_eval(&ip, hi) - poly_eval(&ip, lo);
            }
        }
        total
    }
}

/// Round to 15 decimals to merge float-identical breakpoints (mirrors the
/// Python `round(b, 15)`).
fn round15(x: f64) -> f64 {
    (x * 1e15).round() / 1e15
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poly_shift_expands_binomially() {
        // p(x) = x^2 -> p(x+1) = x^2 + 2x + 1
        assert_eq!(poly_shift(&[0.0, 0.0, 1.0], 1.0), vec![1.0, 2.0, 1.0]);
        // p(x) = 2 + 3x -> p(x-2) = -4 + 3x
        assert_eq!(poly_shift(&[2.0, 3.0], -2.0), vec![-4.0, 3.0]);
    }

    #[test]
    fn poly_mul_and_int() {
        // (1 + x)^2 = 1 + 2x + x^2
        assert_eq!(poly_mul(&[1.0, 1.0], &[1.0, 1.0]), vec![1.0, 2.0, 1.0]);
        // ∫ (1 + 2x) = x + x^2
        assert_eq!(poly_int(&[1.0, 2.0]), vec![0.0, 1.0, 1.0]);
    }

    #[test]
    fn box_convolve_of_rect_is_trapezoid() {
        let r = PiecewisePoly::new(vec![-0.5, 0.5], vec![vec![1.0]]);
        let t = r.box_convolve(0.25);
        // plateau value = width of small box = 0.25
        assert!((t.eval(0.0) - 0.25).abs() < 1e-12);
        assert!((t.eval(0.3) - 0.25).abs() < 1e-12);
        // linear ramp between 3/8 and 5/8
        assert!((t.eval(0.5) - 0.125).abs() < 1e-12);
        assert!(t.eval(0.7) == 0.0);
        assert_eq!(t.support(), (-0.625, 0.625));
    }

    #[test]
    fn mass_preserved_times_box_mass() {
        let r = PiecewisePoly::new(vec![-0.5, 0.5], vec![vec![1.0]]);
        let c = r.box_convolve(0.25);
        assert!((c.antiderivative_at(10.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn conv_at_matches_rect_triangle() {
        let r = PiecewisePoly::new(vec![-0.5, 0.5], vec![vec![1.0]]);
        for i in 0..20 {
            let t = -1.1 + 0.11 * i as f64;
            let expect = (1.0 - t.abs()).max(0.0);
            assert!((r.conv_at(t) - expect).abs() < 1e-12, "t={t}");
        }
    }

    #[test]
    fn solve_small_identity() {
        let a = vec![vec![2.0, 0.0], vec![0.0, 4.0]];
        let x = solve_small(a, vec![2.0, 8.0]);
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn derivative_drops_degree() {
        let p = PiecewisePoly::new(vec![0.0, 1.0], vec![vec![1.0, 2.0, 3.0]]);
        let d = p.derivative();
        // d/dx (1 + 2x + 3x^2) = 2 + 6x
        assert!((d.eval(0.5) - 5.0).abs() < 1e-12);
    }
}
