//! Synthetic stand-ins for the paper's UCI datasets (Table 2).
//!
//! No network access in this environment, so each generator reproduces the
//! *shape* of its UCI counterpart — same n, d, train/test split, and a
//! feature/teacher structure chosen to exercise the same regime (see
//! DESIGN.md §5):
//!
//! | name        | UCI counterpart     | n      | d   | structure            |
//! |-------------|---------------------|--------|-----|----------------------|
//! | `wine`      | Wine Quality        | 6497   | 11  | dense low-d, ordinal target |
//! | `insurance` | Insurance (COIL2000)| 9822   | 85  | mostly one-hot/binary, weak signal |
//! | `ctslices`  | CT Slices Location  | 53500  | 384 | high-d, low intrinsic dim (redundant) |
//! | `covtype`   | Forest Cover        | 581012 | 54  | mixed continuous + binary |
//!
//! The teacher is a spectral GP-style random function (smooth but not
//! band-limited) plus heteroscedastic noise; targets are left unstandardized
//! so the pipeline's standardization path is exercised like on real data.

use super::Dataset;
use crate::gp::SpectralGp;
use crate::kernels::Kernel;
use crate::util::rng::Pcg64;

/// Generator parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    /// Number of latent factors (intrinsic dimension).
    pub latent: usize,
    /// Fraction of feature dims that are binarized (one-hot-ish).
    pub binary_frac: f64,
    /// Observation noise standard deviation (relative to signal ≈ 1).
    pub noise: f64,
    /// Paper's train split size.
    pub n_train: usize,
    /// Teacher smoothness: bandwidth of the latent GP teacher.
    pub teacher_scale: f64,
    /// Rough (Laplace-GP) teacher — calibrates the CT/covtype stand-ins,
    /// whose real counterparts visibly favor Laplace-family kernels in the
    /// paper's own Table 2 (DESIGN.md §5).
    pub rough_teacher: bool,
}

/// The four Table-2 dataset stand-ins.
pub const SPECS: [SyntheticSpec; 4] = [
    SyntheticSpec {
        name: "wine",
        n: 6497,
        d: 11,
        latent: 8,
        binary_frac: 0.0,
        noise: 0.7,
        n_train: 4000,
        teacher_scale: 3.2,
        rough_teacher: false,
    },
    SyntheticSpec {
        name: "insurance",
        n: 9822,
        d: 85,
        latent: 10,
        binary_frac: 0.8,
        noise: 0.95,
        n_train: 5822,
        teacher_scale: 4.0,
        rough_teacher: false,
    },
    SyntheticSpec {
        name: "ctslices",
        n: 53500,
        d: 384,
        latent: 6,
        binary_frac: 0.0,
        noise: 0.15,
        n_train: 35000,
        teacher_scale: 3.5,
        rough_teacher: true,
    },
    SyntheticSpec {
        name: "covtype",
        n: 581012,
        d: 54,
        latent: 10,
        binary_frac: 0.74, // 44 of 54 covtype dims are binary
        noise: 0.35,
        n_train: 500000,
        teacher_scale: 11.0,
        rough_teacher: true,
    },
];

/// Build a synthetic dataset by spec (optionally capped to `n_max` rows
/// while keeping the train fraction — used to scale benches to this box).
pub fn generate(spec: &SyntheticSpec, n_max: Option<usize>, seed: u64) -> Dataset {
    let n = n_max.map(|m| m.min(spec.n)).unwrap_or(spec.n);
    let d = spec.d;
    let mut rng = Pcg64::new(seed ^ name_seed(spec.name), 0);
    // latent factors u ~ N(0, I_latent); features = random linear mixing of
    // latent + per-dim noise, a fraction binarized by thresholding
    let mixing: Vec<f64> = (0..d * spec.latent)
        .map(|_| rng.normal() / (spec.latent as f64).sqrt())
        .collect();
    let n_binary = (d as f64 * spec.binary_frac) as usize;
    // teacher: smooth random function of the *latent* coordinates
    let teacher_kernel = if spec.rough_teacher {
        Kernel::laplace(spec.teacher_scale)
    } else {
        Kernel::squared_exp(spec.teacher_scale)
    };
    let mut trng = rng.fork(1);
    let teacher = SpectralGp::new(&teacher_kernel, spec.latent, 2048, &mut trng);
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f64; n];
    let mut u = vec![0.0f32; spec.latent];
    for i in 0..n {
        for ul in u.iter_mut() {
            *ul = rng.normal() as f32;
        }
        for j in 0..d {
            let mut v = 0.0;
            for (l, ul) in u.iter().enumerate() {
                v += mixing[j * spec.latent + l] * *ul as f64;
            }
            v += 0.4 * rng.normal(); // idiosyncratic feature noise
            x[i * d + j] = if j < n_binary {
                // binarize with a per-dim random threshold — one-hot-ish
                let thr = ((j * 2654435761) % 97) as f64 / 97.0 * 1.2 - 0.6;
                if v > thr {
                    1.0
                } else {
                    0.0
                }
            } else {
                v as f32
            };
        }
        let mut signal = teacher.eval(&u);
        if spec.rough_teacher {
            // Axis-aligned kinks on the continuous *feature* coordinates:
            // an additive piecewise-linear term per dim. This is the
            // structure that makes the real CT/covtype targets favor
            // product-Laplace kernels (and per-coordinate LSH bins) over
            // isotropic SE/RFF — visible in the paper's own Table 2.
            let row = &x[i * d..(i + 1) * d];
            let mut kink = 0.0;
            let n_kink = (d - n_binary).min(16).max(1);
            for (k, &xv) in row[n_binary..n_binary + n_kink].iter().enumerate() {
                let t = kink_knot(spec.name, k);
                let v = xv as f64;
                kink += (v - t).abs() - (v - t - 0.9).abs();
            }
            signal = 0.35 * signal + 0.75 * kink / (n_kink as f64).sqrt();
        }
        // heteroscedastic noise: scales mildly with |signal|
        let noise = spec.noise * (1.0 + 0.3 * signal.abs()) * rng.normal();
        y[i] = 3.0 + 2.0 * signal + noise; // unstandardized targets
    }
    Dataset::new(spec.name, x, y, d)
}

/// Deterministic kink knot for coordinate `k` of a named dataset.
fn kink_knot(name: &str, k: usize) -> f64 {
    let h = name_seed(name)
        .wrapping_add(k as u64)
        .wrapping_mul(0x9e3779b97f4a7c15);
    (h >> 40) as f64 / (1u64 << 24) as f64 * 1.6 - 0.8
}

/// Hash a dataset name into a seed component (stable across runs; FNV-1a).
fn name_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Look up a spec by name and generate it.
pub fn synthetic_by_name(name: &str, n_max: Option<usize>, seed: u64) -> Option<Dataset> {
    SPECS
        .iter()
        .find(|s| s.name == name)
        .map(|s| generate(s, n_max, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper_shapes() {
        let by = |n: &str| SPECS.iter().find(|s| s.name == n).unwrap();
        assert_eq!((by("wine").n, by("wine").d, by("wine").n_train), (6497, 11, 4000));
        assert_eq!((by("insurance").n, by("insurance").d), (9822, 85));
        assert_eq!((by("ctslices").n, by("ctslices").d), (53500, 384));
        assert_eq!((by("covtype").n, by("covtype").d), (581012, 54));
    }

    #[test]
    fn generate_is_deterministic() {
        let a = synthetic_by_name("wine", Some(200), 1).unwrap();
        let b = synthetic_by_name("wine", Some(200), 1).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synthetic_by_name("wine", Some(200), 2).unwrap();
        assert!(a.x != c.x);
    }

    #[test]
    fn binary_dims_are_binary() {
        let ds = synthetic_by_name("insurance", Some(300), 3).unwrap();
        let n_binary = (85.0 * 0.8) as usize;
        for i in 0..ds.n {
            for j in 0..n_binary {
                let v = ds.x[i * ds.d + j];
                assert!(v == 0.0 || v == 1.0, "dim {j} value {v}");
            }
        }
        // continuous dims are not all binary
        let some_cont = (0..ds.n).any(|i| {
            let v = ds.x[i * ds.d + 84];
            v != 0.0 && v != 1.0
        });
        assert!(some_cont);
    }

    #[test]
    fn signal_is_learnable() {
        // k-NN averaging in the latent-driven features must beat the mean
        // predictor — sanity that the teacher leaves structure in X.
        let ds = synthetic_by_name("wine", Some(1200), 4).unwrap();
        let mut train = ds.clone();
        let (ym, ys) = train.standardize();
        assert!(ys > 0.0 && ym.is_finite());
        let (tr, te) = train.split(1000, 5);
        let k = 15usize;
        let mut se_knn = 0.0;
        let mut se_mean = 0.0;
        for i in 0..te.n {
            let xi = te.row(i);
            let mut dists: Vec<(f64, usize)> = (0..tr.n)
                .map(|j| {
                    let dist: f64 = xi
                        .iter()
                        .zip(tr.row(j))
                        .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                        .sum();
                    (dist, j)
                })
                .collect();
            dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let pred: f64 =
                dists[..k].iter().map(|&(_, j)| tr.y[j]).sum::<f64>() / k as f64;
            se_knn += (te.y[i] - pred).powi(2);
            se_mean += te.y[i].powi(2);
        }
        assert!(
            se_knn < 0.95 * se_mean,
            "{k}-NN {se_knn} vs mean {se_mean}"
        );
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(synthetic_by_name("nope", None, 0).is_none());
    }
}
