//! Synthetic stand-ins for the paper's UCI datasets (Table 2).
//!
//! No network access in this environment, so each generator reproduces the
//! *shape* of its UCI counterpart — same n, d, train/test split, and a
//! feature/teacher structure chosen to exercise the same regime (see
//! DESIGN.md §5):
//!
//! | name        | UCI counterpart     | n      | d   | structure            |
//! |-------------|---------------------|--------|-----|----------------------|
//! | `wine`      | Wine Quality        | 6497   | 11  | dense low-d, ordinal target |
//! | `insurance` | Insurance (COIL2000)| 9822   | 85  | mostly one-hot/binary, weak signal |
//! | `ctslices`  | CT Slices Location  | 53500  | 384 | high-d, low intrinsic dim (redundant) |
//! | `covtype`   | Forest Cover        | 581012 | 54  | mixed continuous + binary |
//!
//! The teacher is a spectral GP-style random function (smooth but not
//! band-limited) plus heteroscedastic noise; targets are left unstandardized
//! so the pipeline's standardization path is exercised like on real data.

use super::Dataset;
use crate::gp::SpectralGp;
use crate::kernels::Kernel;
use crate::util::rng::Pcg64;

/// Generator parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    /// Number of latent factors (intrinsic dimension).
    pub latent: usize,
    /// Fraction of feature dims that are binarized (one-hot-ish).
    pub binary_frac: f64,
    /// Observation noise standard deviation (relative to signal ≈ 1).
    pub noise: f64,
    /// Paper's train split size.
    pub n_train: usize,
    /// Teacher smoothness: bandwidth of the latent GP teacher.
    pub teacher_scale: f64,
    /// Rough (Laplace-GP) teacher — calibrates the CT/covtype stand-ins,
    /// whose real counterparts visibly favor Laplace-family kernels in the
    /// paper's own Table 2 (DESIGN.md §5).
    pub rough_teacher: bool,
}

/// The four Table-2 dataset stand-ins.
pub const SPECS: [SyntheticSpec; 4] = [
    SyntheticSpec {
        name: "wine",
        n: 6497,
        d: 11,
        latent: 8,
        binary_frac: 0.0,
        noise: 0.7,
        n_train: 4000,
        teacher_scale: 3.2,
        rough_teacher: false,
    },
    SyntheticSpec {
        name: "insurance",
        n: 9822,
        d: 85,
        latent: 10,
        binary_frac: 0.8,
        noise: 0.95,
        n_train: 5822,
        teacher_scale: 4.0,
        rough_teacher: false,
    },
    SyntheticSpec {
        name: "ctslices",
        n: 53500,
        d: 384,
        latent: 6,
        binary_frac: 0.0,
        noise: 0.15,
        n_train: 35000,
        teacher_scale: 3.5,
        rough_teacher: true,
    },
    SyntheticSpec {
        name: "covtype",
        n: 581012,
        d: 54,
        latent: 10,
        binary_frac: 0.74, // 44 of 54 covtype dims are binary
        noise: 0.35,
        n_train: 500000,
        teacher_scale: 11.0,
        rough_teacher: true,
    },
];

/// Frozen row-independent generator state: the mixing matrix and teacher
/// are drawn once from the base RNG; individual rows then only need a
/// per-row RNG stream. Shared by the in-memory [`generate`] and the
/// streaming [`SyntheticSource`].
struct TeacherModel {
    d: usize,
    latent: usize,
    n_binary: usize,
    noise: f64,
    rough: bool,
    name: &'static str,
    mixing: Vec<f64>,
    teacher: SpectralGp,
}

impl TeacherModel {
    fn new(spec: &SyntheticSpec, rng: &mut Pcg64) -> TeacherModel {
        // latent factors u ~ N(0, I_latent); features = random linear
        // mixing of latent + per-dim noise, a fraction binarized by
        // thresholding
        let mixing: Vec<f64> = (0..spec.d * spec.latent)
            .map(|_| rng.normal() / (spec.latent as f64).sqrt())
            .collect();
        // teacher: smooth random function of the *latent* coordinates
        let teacher_kernel = if spec.rough_teacher {
            Kernel::laplace(spec.teacher_scale)
        } else {
            Kernel::squared_exp(spec.teacher_scale)
        };
        let mut trng = rng.fork(1);
        let teacher = SpectralGp::new(&teacher_kernel, spec.latent, 2048, &mut trng);
        TeacherModel {
            d: spec.d,
            latent: spec.latent,
            n_binary: (spec.d as f64 * spec.binary_frac) as usize,
            noise: spec.noise,
            rough: spec.rough_teacher,
            name: spec.name,
            mixing,
            teacher,
        }
    }

    /// Generate one row into `row` (length d) from `rng`, returning its
    /// target. `u` is a reused latent scratch buffer (length `latent`).
    fn gen_row(&self, rng: &mut Pcg64, u: &mut [f32], row: &mut [f32]) -> f64 {
        let (d, n_binary) = (self.d, self.n_binary);
        for ul in u.iter_mut() {
            *ul = rng.normal() as f32;
        }
        for (j, xv) in row.iter_mut().enumerate() {
            let mut v = 0.0;
            for (l, ul) in u.iter().enumerate() {
                v += self.mixing[j * self.latent + l] * *ul as f64;
            }
            v += 0.4 * rng.normal(); // idiosyncratic feature noise
            *xv = if j < n_binary {
                // binarize with a per-dim random threshold — one-hot-ish
                let thr = ((j * 2654435761) % 97) as f64 / 97.0 * 1.2 - 0.6;
                if v > thr {
                    1.0
                } else {
                    0.0
                }
            } else {
                v as f32
            };
        }
        let mut signal = self.teacher.eval(u);
        if self.rough {
            // Axis-aligned kinks on the continuous *feature* coordinates:
            // an additive piecewise-linear term per dim. This is the
            // structure that makes the real CT/covtype targets favor
            // product-Laplace kernels (and per-coordinate LSH bins) over
            // isotropic SE/RFF — visible in the paper's own Table 2.
            let mut kink = 0.0;
            let n_kink = (d - n_binary).min(16).max(1);
            for (k, &xv) in row[n_binary..n_binary + n_kink].iter().enumerate() {
                let t = kink_knot(self.name, k);
                let v = xv as f64;
                kink += (v - t).abs() - (v - t - 0.9).abs();
            }
            signal = 0.35 * signal + 0.75 * kink / (n_kink as f64).sqrt();
        }
        // heteroscedastic noise: scales mildly with |signal|
        let noise = self.noise * (1.0 + 0.3 * signal.abs()) * rng.normal();
        3.0 + 2.0 * signal + noise // unstandardized targets
    }
}

/// Build a synthetic dataset by spec (optionally capped to `n_max` rows
/// while keeping the train fraction — used to scale benches to this box).
pub fn generate(spec: &SyntheticSpec, n_max: Option<usize>, seed: u64) -> Dataset {
    let n = n_max.map(|m| m.min(spec.n)).unwrap_or(spec.n);
    let d = spec.d;
    let mut rng = Pcg64::new(seed ^ name_seed(spec.name), 0);
    let model = TeacherModel::new(spec, &mut rng);
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f64; n];
    let mut u = vec![0.0f32; spec.latent];
    for i in 0..n {
        y[i] = model.gen_row(&mut rng, &mut u, &mut x[i * d..(i + 1) * d]);
    }
    Dataset::new(spec.name, x, y, d)
}

/// On-the-fly streaming generator for a synthetic spec: rows are produced
/// chunk by chunk from per-row RNG streams, so the sequence is
/// deterministic in `(name, n, seed)` and independent of the chunk size —
/// arbitrarily large training sets without an O(n·d) materialization.
///
/// The row stream is its own RNG discipline (per-row forks rather than
/// [`generate`]'s single sequential stream), so a `SyntheticSource` is
/// *not* row-for-row equal to `generate` with the same seed; it is equal
/// to its own [`materialize`](crate::data::DataSource::materialize) at
/// every chunk size, which is what the stream-vs-memory equivalence suite
/// relies on.
pub struct SyntheticSource {
    spec: SyntheticSpec,
    model: TeacherModel,
    n: usize,
    seed: u64,
    name: String,
}

impl SyntheticSource {
    /// Look up `name` among the Table-2 specs and stream `n` rows from
    /// `seed`. Returns `None` for an unknown dataset name.
    pub fn by_name(name: &str, n: usize, seed: u64) -> Option<SyntheticSource> {
        let spec = SPECS.iter().find(|s| s.name == name)?.clone();
        let mut rng = Pcg64::new(seed ^ name_seed(spec.name), 0);
        let model = TeacherModel::new(&spec, &mut rng);
        Some(SyntheticSource {
            n,
            seed,
            name: format!("{name}-stream"),
            model,
            spec,
        })
    }

    /// Per-row RNG stream: depends only on (seed, row), never on chunking.
    fn row_rng(&self, row: usize) -> Pcg64 {
        Pcg64::new(self.seed ^ name_seed(self.spec.name) ^ 0x5eed_5eed, row as u64 + 1)
    }
}

impl crate::data::DataSource for SyntheticSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.spec.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn for_each_chunk(
        &self,
        chunk_rows: usize,
        f: crate::data::ChunkFn,
    ) -> Result<(), crate::api::KrrError> {
        let chunk = chunk_rows.max(1);
        let d = self.spec.d;
        let mut u = vec![0.0f32; self.spec.latent];
        let mut rows = vec![0.0f32; chunk.min(self.n.max(1)) * d];
        let mut ys = vec![0.0f64; chunk.min(self.n.max(1))];
        let mut start = 0usize;
        while start < self.n {
            let end = (start + chunk).min(self.n);
            let take = end - start;
            for (k, i) in (start..end).enumerate() {
                let mut rng = self.row_rng(i);
                ys[k] = self
                    .model
                    .gen_row(&mut rng, &mut u, &mut rows[k * d..(k + 1) * d]);
            }
            f(&rows[..take * d], &ys[..take])?;
            start = end;
        }
        Ok(())
    }
}

/// Deterministic kink knot for coordinate `k` of a named dataset.
fn kink_knot(name: &str, k: usize) -> f64 {
    let h = name_seed(name)
        .wrapping_add(k as u64)
        .wrapping_mul(0x9e3779b97f4a7c15);
    (h >> 40) as f64 / (1u64 << 24) as f64 * 1.6 - 0.8
}

/// Hash a dataset name into a seed component (stable across runs; FNV-1a).
fn name_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Look up a spec by name and generate it.
pub fn synthetic_by_name(name: &str, n_max: Option<usize>, seed: u64) -> Option<Dataset> {
    SPECS
        .iter()
        .find(|s| s.name == name)
        .map(|s| generate(s, n_max, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper_shapes() {
        let by = |n: &str| SPECS.iter().find(|s| s.name == n).unwrap();
        assert_eq!((by("wine").n, by("wine").d, by("wine").n_train), (6497, 11, 4000));
        assert_eq!((by("insurance").n, by("insurance").d), (9822, 85));
        assert_eq!((by("ctslices").n, by("ctslices").d), (53500, 384));
        assert_eq!((by("covtype").n, by("covtype").d), (581012, 54));
    }

    #[test]
    fn generate_is_deterministic() {
        let a = synthetic_by_name("wine", Some(200), 1).unwrap();
        let b = synthetic_by_name("wine", Some(200), 1).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = synthetic_by_name("wine", Some(200), 2).unwrap();
        assert!(a.x != c.x);
    }

    #[test]
    fn binary_dims_are_binary() {
        let ds = synthetic_by_name("insurance", Some(300), 3).unwrap();
        let n_binary = (85.0 * 0.8) as usize;
        for i in 0..ds.n {
            for j in 0..n_binary {
                let v = ds.x[i * ds.d + j];
                assert!(v == 0.0 || v == 1.0, "dim {j} value {v}");
            }
        }
        // continuous dims are not all binary
        let some_cont = (0..ds.n).any(|i| {
            let v = ds.x[i * ds.d + 84];
            v != 0.0 && v != 1.0
        });
        assert!(some_cont);
    }

    #[test]
    fn signal_is_learnable() {
        // k-NN averaging in the latent-driven features must beat the mean
        // predictor — sanity that the teacher leaves structure in X.
        let ds = synthetic_by_name("wine", Some(1200), 4).unwrap();
        let mut train = ds.clone();
        let (ym, ys) = train.standardize();
        assert!(ys > 0.0 && ym.is_finite());
        let (tr, te) = train.split(1000, 5);
        let k = 15usize;
        let mut se_knn = 0.0;
        let mut se_mean = 0.0;
        for i in 0..te.n {
            let xi = te.row(i);
            let mut dists: Vec<(f64, usize)> = (0..tr.n)
                .map(|j| {
                    let dist: f64 = xi
                        .iter()
                        .zip(tr.row(j))
                        .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                        .sum();
                    (dist, j)
                })
                .collect();
            dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let pred: f64 =
                dists[..k].iter().map(|&(_, j)| tr.y[j]).sum::<f64>() / k as f64;
            se_knn += (te.y[i] - pred).powi(2);
            se_mean += te.y[i].powi(2);
        }
        assert!(
            se_knn < 0.95 * se_mean,
            "{k}-NN {se_knn} vs mean {se_mean}"
        );
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(synthetic_by_name("nope", None, 0).is_none());
    }

    #[test]
    fn synthetic_source_is_chunk_invariant_and_seeded() {
        use crate::data::DataSource;
        let src = SyntheticSource::by_name("wine", 150, 4).unwrap();
        assert_eq!(src.dim(), 11);
        assert_eq!(src.len_hint(), Some(150));
        let want = src.materialize(150).unwrap();
        for chunk in [1usize, 7, 64] {
            let got = src.materialize(chunk).unwrap();
            assert_eq!(got.x, want.x, "chunk={chunk}");
            assert_eq!(got.y, want.y, "chunk={chunk}");
        }
        // a different seed streams different rows
        let other = SyntheticSource::by_name("wine", 150, 5).unwrap().materialize(64).unwrap();
        assert!(other.x != want.x);
        // the row teacher still leaves learnable structure: targets vary
        let y_var = {
            let m = want.y.iter().sum::<f64>() / want.y.len() as f64;
            want.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / want.y.len() as f64
        };
        assert!(y_var > 0.1, "target variance {y_var}");
        assert!(SyntheticSource::by_name("nope", 10, 0).is_none());
    }
}
