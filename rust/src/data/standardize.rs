//! Streaming standardization: a single-pass Welford accumulator that
//! produces a reusable [`Standardizer`] — fit once on the training
//! source, then apply the same affine map to training chunks, held-out
//! test sets, and serving-time queries. This replaces the pattern of
//! calling [`Dataset::standardize`](crate::data::Dataset::standardize) on
//! each split independently (which leaks test statistics into the test
//! transform and cannot be applied to single query rows at all).
//!
//! Statistics match the two-pass population formulas of
//! `Dataset::standardize` to floating-point accumulation error (≤1e-10
//! relative on realistic data — asserted in the unit tests), and the
//! degenerate-feature handling is identical: a variance at or below 1e-24
//! maps the feature to 0 rather than dividing by ~0.
//!
//! ## Sparse semantics
//!
//! A sparse source ([`DataSource::is_sparse`]) is fitted in one sparse
//! pass (per-feature sum/sum-of-squares over the stored entries; absent
//! coordinates contribute exactly 0) and transformed by **scaling only**:
//! features map to `x · inv_std` with *no mean subtraction*, so zeros
//! stay zeros and CSR blocks keep their sparsity pattern. Centering a
//! sparse matrix would densify it — every absent coordinate would become
//! `-mean/std` — defeating the entire memory argument; for the
//! kernel-approximation operators the lost centering is a benign
//! translation of the input space. Targets are dense and are centered
//! and scaled exactly as in the dense path.

use super::source::{Chunk, ChunkAnyFn, ChunkFn, DataSource, SparseChunk};
use super::Dataset;
use crate::api::KrrError;

/// A fitted affine standardization: features map to
/// `(x - mean) · inv_std`, targets to `(y - y_mean) / y_std`.
#[derive(Clone, Debug)]
pub struct Standardizer {
    /// Per-feature mean.
    pub mean: Vec<f64>,
    /// Per-feature 1/std (0 for degenerate features, matching
    /// `Dataset::standardize`).
    pub inv_std: Vec<f64>,
    /// Target mean.
    pub y_mean: f64,
    /// Target standard deviation (floored at 1e-12).
    pub y_std: f64,
    /// Rows the statistics were fitted on.
    pub n: usize,
}

impl Standardizer {
    /// Fit on a source in one streaming pass (Welford's algorithm per
    /// feature and for the target; O(d) state, any chunk size). Sparse
    /// sources are fitted from their CSR stream without densifying (see
    /// the module docs for the sparse transform semantics).
    pub fn fit(src: &dyn DataSource, chunk_rows: usize) -> Result<Standardizer, KrrError> {
        if src.is_sparse() {
            return Self::fit_sparse(src, chunk_rows);
        }
        let d = src.dim();
        let mut count = 0usize;
        let mut mean = vec![0.0f64; d];
        let mut m2 = vec![0.0f64; d];
        let mut y_mean = 0.0f64;
        let mut y_m2 = 0.0f64;
        src.for_each_chunk(chunk_rows, &mut |rows, ys| {
            for (i, &yv) in ys.iter().enumerate() {
                count += 1;
                let c = count as f64;
                let row = &rows[i * d..(i + 1) * d];
                for ((&v, m), s) in row.iter().zip(mean.iter_mut()).zip(m2.iter_mut()) {
                    let v = v as f64;
                    let delta = v - *m;
                    *m += delta / c;
                    *s += delta * (v - *m);
                }
                let delta = yv - y_mean;
                y_mean += delta / c;
                y_m2 += delta * (yv - y_mean);
            }
            Ok(())
        })?;
        if count == 0 {
            return Err(KrrError::Dataset(format!(
                "{}: cannot standardize an empty source",
                src.name()
            )));
        }
        let n = count as f64;
        let inv_std = m2
            .iter()
            .map(|&s| {
                let var = s / n;
                if var > 1e-24 {
                    1.0 / var.sqrt()
                } else {
                    0.0
                }
            })
            .collect();
        let y_std = (y_m2 / n).sqrt().max(1e-12);
        Ok(Standardizer { mean, inv_std, y_mean, y_std, n: count })
    }

    /// One sparse pass: per-feature sum and sum-of-squares over the
    /// stored entries (absent coordinates contribute exactly 0, so
    /// `mean = Σx/n` and `var = Σx²/n − mean²` are the full-data
    /// moments), Welford for the dense targets.
    fn fit_sparse(src: &dyn DataSource, chunk_rows: usize) -> Result<Standardizer, KrrError> {
        let d = src.dim();
        let mut count = 0usize;
        let mut sum = vec![0.0f64; d];
        let mut sumsq = vec![0.0f64; d];
        let mut y_mean = 0.0f64;
        let mut y_m2 = 0.0f64;
        let mut dense_buf: Vec<f32> = Vec::new();
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            match chunk {
                Chunk::Sparse(sp) => {
                    for (&j, &v) in sp.indices.iter().zip(sp.values) {
                        let v = v as f64;
                        sum[j as usize] += v;
                        sumsq[j as usize] += v * v;
                    }
                }
                Chunk::Dense(rows) => {
                    // a mixed stream is possible through adapters; fold
                    // dense blocks into the same moment accumulators
                    dense_buf.clear();
                    dense_buf.extend_from_slice(rows);
                    for row in dense_buf.chunks(d) {
                        for (j, &v) in row.iter().enumerate() {
                            let v = v as f64;
                            sum[j] += v;
                            sumsq[j] += v * v;
                        }
                    }
                }
            }
            for &yv in ys {
                count += 1;
                let delta = yv - y_mean;
                y_mean += delta / count as f64;
                y_m2 += delta * (yv - y_mean);
            }
            Ok(())
        })?;
        if count == 0 {
            return Err(KrrError::Dataset(format!(
                "{}: cannot standardize an empty source",
                src.name()
            )));
        }
        let n = count as f64;
        let mut mean = vec![0.0f64; d];
        let mut inv_std = vec![0.0f64; d];
        for j in 0..d {
            let m = sum[j] / n;
            let var = sumsq[j] / n - m * m;
            mean[j] = m;
            inv_std[j] = if var > 1e-24 { 1.0 / var.sqrt() } else { 0.0 };
        }
        let y_std = (y_m2 / n).sqrt().max(1e-12);
        Ok(Standardizer { mean, inv_std, y_mean, y_std, n: count })
    }

    /// Features per row this standardizer was fitted for.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Standardize a row-major block of feature rows in place — the same
    /// map for training chunks and held-out queries.
    pub fn transform_rows(&self, rows: &mut [f32]) {
        let d = self.dim();
        assert_eq!(rows.len() % d.max(1), 0, "row block shape mismatch");
        for row in rows.chunks_mut(d.max(1)) {
            for ((v, &m), &s) in row.iter_mut().zip(&self.mean).zip(&self.inv_std) {
                *v = ((*v as f64 - m) * s) as f32;
            }
        }
    }

    /// The sparse feature map on a *dense* row block: scale by `inv_std`
    /// without subtracting the mean, so zeros map to zeros. This is the
    /// densified equivalent the sparse bit-identity tests compare against
    /// — the same per-value arithmetic as
    /// [`transform_sparse_values`](Self::transform_sparse_values).
    pub fn scale_rows(&self, rows: &mut [f32]) {
        let d = self.dim();
        assert_eq!(rows.len() % d.max(1), 0, "row block shape mismatch");
        for row in rows.chunks_mut(d.max(1)) {
            for (v, &s) in row.iter_mut().zip(&self.inv_std) {
                *v = ((*v as f64) * s) as f32;
            }
        }
    }

    /// The sparse feature map on a CSR block's stored values: each value
    /// scales by its feature's `inv_std` (no centering — see the module
    /// docs). Zeros are preserved, stored or absent alike.
    pub fn transform_sparse_values(&self, indices: &[u32], values: &mut [f32]) {
        assert_eq!(indices.len(), values.len(), "CSR index/value length mismatch");
        for (&j, v) in indices.iter().zip(values.iter_mut()) {
            *v = ((*v as f64) * self.inv_std[j as usize]) as f32;
        }
    }

    /// Center and scale targets in place.
    pub fn transform_targets(&self, ys: &mut [f64]) {
        for y in ys.iter_mut() {
            *y = (*y - self.y_mean) / self.y_std;
        }
    }

    /// Standardize a whole dataset in place; returns the target
    /// `(mean, std)` like [`Dataset::standardize`].
    pub fn apply(&self, ds: &mut Dataset) -> (f64, f64) {
        assert_eq!(ds.d, self.dim(), "dataset dimensionality mismatch");
        self.transform_rows(&mut ds.x);
        self.transform_targets(&mut ds.y);
        (self.y_mean, self.y_std)
    }

    /// Map a standardized prediction back to the original target scale.
    pub fn unscale_target(&self, y: f64) -> f64 {
        y * self.y_std + self.y_mean
    }

    /// View `inner` through this standardizer: every chunk is transformed
    /// on the fly, so a streamed training run standardizes without ever
    /// materializing the data.
    pub fn source<'a>(&'a self, inner: &'a dyn DataSource) -> StandardizedSource<'a> {
        assert_eq!(inner.dim(), self.dim(), "source dimensionality mismatch");
        StandardizedSource { std: self, inner }
    }
}

/// A [`DataSource`] adapter applying a fitted [`Standardizer`] chunk by
/// chunk (O(chunk) scratch).
pub struct StandardizedSource<'a> {
    std: &'a Standardizer,
    inner: &'a dyn DataSource,
}

impl DataSource for StandardizedSource<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        // The feature map is a property of the *source*, not of the
        // visitor API: a sparse source gets the scale-only sparse map even
        // when a consumer asks for densified rows, so every path through
        // this adapter (operator build, preconditioner, head sample) sees
        // one consistent transform.
        let sparse = self.inner.is_sparse();
        let mut x_buf: Vec<f32> = Vec::new();
        let mut y_buf: Vec<f64> = Vec::new();
        self.inner.for_each_chunk(chunk_rows, &mut |rows, ys| {
            x_buf.clear();
            x_buf.extend_from_slice(rows);
            y_buf.clear();
            y_buf.extend_from_slice(ys);
            if sparse {
                self.std.scale_rows(&mut x_buf);
            } else {
                self.std.transform_rows(&mut x_buf);
            }
            self.std.transform_targets(&mut y_buf);
            f(&x_buf, &y_buf)
        })
    }

    fn is_sparse(&self) -> bool {
        self.inner.is_sparse()
    }

    fn for_each_chunk_any(&self, chunk_rows: usize, f: ChunkAnyFn) -> Result<(), KrrError> {
        let mut v_buf: Vec<f32> = Vec::new();
        let mut x_buf: Vec<f32> = Vec::new();
        let mut y_buf: Vec<f64> = Vec::new();
        self.inner.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            y_buf.clear();
            y_buf.extend_from_slice(ys);
            self.std.transform_targets(&mut y_buf);
            match chunk {
                Chunk::Sparse(sp) => {
                    // scale-only map: the sparsity pattern passes through
                    v_buf.clear();
                    v_buf.extend_from_slice(sp.values);
                    self.std.transform_sparse_values(sp.indices, &mut v_buf);
                    let out = SparseChunk {
                        indptr: sp.indptr,
                        indices: sp.indices,
                        values: &v_buf,
                    };
                    f(Chunk::Sparse(out), &y_buf)
                }
                Chunk::Dense(rows) => {
                    x_buf.clear();
                    x_buf.extend_from_slice(rows);
                    self.std.transform_rows(&mut x_buf);
                    f(Chunk::Dense(&x_buf), &y_buf)
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_by_name;

    #[test]
    fn welford_matches_two_pass_standardize() {
        // The fitted statistics agree with the two-pass population
        // formulas of Dataset::standardize to ≤1e-10 relative (both f64),
        // and the transformed values match to f32 rounding (the casts can
        // land one ulp apart when the f64 stats differ in the last bits).
        let ds = synthetic_by_name("wine", Some(500), 7).unwrap();
        let std = Standardizer::fit(&ds, 64).unwrap();
        assert_eq!(std.n, ds.n);
        for j in 0..ds.d {
            let mean: f64 =
                (0..ds.n).map(|i| ds.x[i * ds.d + j] as f64).sum::<f64>() / ds.n as f64;
            let var: f64 = (0..ds.n)
                .map(|i| (ds.x[i * ds.d + j] as f64 - mean).powi(2))
                .sum::<f64>()
                / ds.n as f64;
            let inv = 1.0 / var.sqrt();
            assert!(
                (std.mean[j] - mean).abs() <= 1e-10 * (1.0 + mean.abs()),
                "mean[{j}]: {} vs {mean}",
                std.mean[j]
            );
            assert!(
                (std.inv_std[j] - inv).abs() <= 1e-10 * inv,
                "inv_std[{j}]: {} vs {inv}",
                std.inv_std[j]
            );
        }
        let mut two_pass = ds.clone();
        let (ym, ys) = two_pass.standardize();
        assert!((std.y_mean - ym).abs() <= 1e-10 * (1.0 + ym.abs()), "y mean");
        assert!((std.y_std - ys).abs() <= 1e-10 * ys, "y std");
        let mut streamed = ds.clone();
        std.apply(&mut streamed);
        for i in 0..ds.n {
            for j in 0..ds.d {
                let a = two_pass.x[i * ds.d + j] as f64;
                let b = streamed.x[i * ds.d + j] as f64;
                assert!((a - b).abs() <= 2e-6 * (1.0 + a.abs()), "x[{i},{j}]: {a} vs {b}");
            }
            let (a, b) = (two_pass.y[i], streamed.y[i]);
            assert!((a - b).abs() <= 1e-10 * (1.0 + a.abs()), "y[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn fit_is_chunk_size_invariant() {
        let ds = synthetic_by_name("wine", Some(300), 3).unwrap();
        let want = Standardizer::fit(&ds, ds.n).unwrap();
        for chunk in [1usize, 7, 64] {
            let got = Standardizer::fit(&ds, chunk).unwrap();
            assert_eq!(got.n, want.n);
            for j in 0..ds.d {
                assert!(
                    (got.mean[j] - want.mean[j]).abs() <= 1e-12 * (1.0 + want.mean[j].abs()),
                    "chunk={chunk} mean[{j}]"
                );
                assert!(
                    (got.inv_std[j] - want.inv_std[j]).abs() <= 1e-10 * want.inv_std[j].abs(),
                    "chunk={chunk} inv_std[{j}]"
                );
            }
        }
    }

    #[test]
    fn fitted_standardizer_applies_train_statistics_to_held_out_queries() {
        // Fit on train only; the test transform must use *train* moments
        // (the leak Dataset::standardize forces when called per split).
        let ds = synthetic_by_name("wine", Some(400), 5).unwrap();
        let (tr, te) = ds.split(300, 2);
        let std = Standardizer::fit(&tr, 32).unwrap();
        let mut q = te.x.clone();
        std.transform_rows(&mut q);
        for i in 0..te.n.min(20) {
            for j in 0..te.d {
                let want = ((te.x[i * te.d + j] as f64 - std.mean[j]) * std.inv_std[j]) as f32;
                assert_eq!(q[i * te.d + j], want, "query {i} dim {j}");
            }
        }
        // train rows through the same map have ~zero mean / unit variance
        let mut trx = tr.x.clone();
        std.transform_rows(&mut trx);
        for j in 0..tr.d {
            let mean: f64 =
                (0..tr.n).map(|i| trx[i * tr.d + j] as f64).sum::<f64>() / tr.n as f64;
            assert!(mean.abs() < 1e-6, "dim {j} mean {mean}");
        }
    }

    #[test]
    fn standardized_source_streams_the_transformed_values() {
        let ds = synthetic_by_name("wine", Some(200), 9).unwrap();
        let std = Standardizer::fit(&ds, 50).unwrap();
        let mut want = ds.clone();
        std.apply(&mut want);
        let view = std.source(&ds);
        assert_eq!(view.len_hint(), Some(ds.n));
        for chunk in [1usize, 33, 200] {
            let got = view.materialize(chunk).unwrap();
            assert_eq!(got.x, want.x, "chunk={chunk}");
            assert_eq!(got.y, want.y, "chunk={chunk}");
        }
    }

    #[test]
    fn sparse_fit_matches_two_pass_moments_and_scales_without_centering() {
        use crate::data::{write_libsvm, Chunk, LibsvmSource};
        // sparsify wine: zero out a deterministic third of the entries
        let mut ds = synthetic_by_name("wine", Some(120), 13).unwrap();
        for (i, v) in ds.x.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
        }
        let path = std::env::temp_dir().join("wlsh_std_sparse.libsvm");
        write_libsvm(&ds, path.to_str().unwrap(), false).unwrap();
        let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
        assert!(src.is_sparse());
        let std = Standardizer::fit(&src, 17).unwrap();
        assert_eq!(std.n, ds.n);
        // moments are the full-data moments (zeros included)
        for j in 0..ds.d {
            let mean: f64 =
                (0..ds.n).map(|i| ds.x[i * ds.d + j] as f64).sum::<f64>() / ds.n as f64;
            let var: f64 = (0..ds.n)
                .map(|i| (ds.x[i * ds.d + j] as f64 - mean).powi(2))
                .sum::<f64>()
                / ds.n as f64;
            assert!(
                (std.mean[j] - mean).abs() <= 1e-9 * (1.0 + mean.abs()),
                "mean[{j}]: {} vs {mean}",
                std.mean[j]
            );
            assert!(
                (std.inv_std[j] - 1.0 / var.sqrt()).abs() <= 1e-8 * std.inv_std[j].abs(),
                "inv_std[{j}]"
            );
        }
        // the streamed sparse transform equals scale_rows on the
        // densified rows, bit for bit — and zeros stay zeros
        let view = std.source(&src);
        assert!(view.is_sparse());
        let mut want = ds.x.clone();
        std.scale_rows(&mut want);
        let mut got = vec![0.0f32; ds.n * ds.d];
        let mut at = 0usize;
        view.for_each_chunk_any(7, &mut |chunk, ys| {
            let sp = match chunk {
                Chunk::Sparse(sp) => sp,
                Chunk::Dense(_) => panic!("expected sparse"),
            };
            for i in 0..sp.nrows() {
                let (idx, vals) = sp.row(i);
                for (&j, &v) in idx.iter().zip(vals) {
                    got[at * ds.d + j as usize] = v;
                }
                at += 1;
            }
            // targets are centered exactly as in the dense path
            for (k, y) in ys.iter().enumerate() {
                let orig = ds.y[at - ys.len() + k];
                assert_eq!(*y, (orig - std.y_mean) / std.y_std);
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(got, want);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unscale_inverts_target_transform() {
        let ds = synthetic_by_name("wine", Some(100), 1).unwrap();
        let std = Standardizer::fit(&ds, 10).unwrap();
        let mut y = ds.y.clone();
        std.transform_targets(&mut y);
        for (orig, scaled) in ds.y.iter().zip(&y) {
            let back = std.unscale_target(*scaled);
            assert!((back - orig).abs() < 1e-9 * (1.0 + orig.abs()));
        }
    }
}
