//! Datasets: the in-memory container, chunked/streaming ingestion
//! ([`DataSource`] with CSV, LIBSVM, matrix, and synthetic
//! implementations), streaming standardization ([`Standardizer`]),
//! train/test splits, and the synthetic generators substituting for the
//! paper's UCI datasets (no network access in this environment —
//! DESIGN.md §5).
//!
//! Every loader reports malformed content as
//! [`KrrError::Dataset`](crate::api::KrrError) and filesystem failures as
//! `KrrError::Io` — one fallible surface, never a panic.

mod source;
mod standardize;
mod synthetic;

pub use source::{
    head_sample, head_sample_sparse, write_csv, write_libsvm, Chunk, ChunkAnyFn, ChunkFn,
    CsvSource, DataSource, DensifySource, LibsvmSource, MatrixSource, SparseBlock,
    SparseChunk,
};
pub use standardize::{StandardizedSource, Standardizer};
pub use synthetic::{synthetic_by_name, SyntheticSource, SyntheticSpec, SPECS};

use crate::api::KrrError;
use crate::util::rng::Pcg64;

/// A regression dataset: row-major f32 features + f64 targets.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<f64>,
    pub n: usize,
    pub d: usize,
    pub name: String,
}

impl Dataset {
    pub fn new(name: &str, x: Vec<f32>, y: Vec<f64>, d: usize) -> Dataset {
        let n = y.len();
        assert_eq!(x.len(), n * d, "feature matrix shape mismatch");
        Dataset { x, y, n, d, name: name.to_string() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Standardize features to zero mean / unit variance in place, and
    /// center+scale targets. Returns the target (mean, std) for unscaling.
    ///
    /// This two-pass form can only rescale a whole in-memory dataset by
    /// its *own* statistics. To fit on a training stream and re-apply the
    /// same map to held-out data or single queries, use
    /// [`Standardizer::fit`] + [`Standardizer::source`] /
    /// [`Standardizer::transform_rows`].
    pub fn standardize(&mut self) -> (f64, f64) {
        for j in 0..self.d {
            let mut mean = 0.0f64;
            for i in 0..self.n {
                mean += self.x[i * self.d + j] as f64;
            }
            mean /= self.n as f64;
            let mut var = 0.0f64;
            for i in 0..self.n {
                let v = self.x[i * self.d + j] as f64 - mean;
                var += v * v;
            }
            var /= self.n as f64;
            let inv_std = if var > 1e-24 { 1.0 / var.sqrt() } else { 0.0 };
            for i in 0..self.n {
                let v = &mut self.x[i * self.d + j];
                *v = ((*v as f64 - mean) * inv_std) as f32;
            }
        }
        let ym = self.y.iter().sum::<f64>() / self.n as f64;
        let yv = self.y.iter().map(|v| (v - ym) * (v - ym)).sum::<f64>() / self.n as f64;
        let ys = yv.sqrt().max(1e-12);
        for v in self.y.iter_mut() {
            *v = (*v - ym) / ys;
        }
        (ym, ys)
    }

    /// Deterministic shuffled split into (train, test) with `n_train` rows.
    pub fn split(&self, n_train: usize, seed: u64) -> (Dataset, Dataset) {
        assert!(n_train <= self.n);
        let mut idx: Vec<usize> = (0..self.n).collect();
        let mut rng = Pcg64::new(seed, 99);
        // Fisher–Yates
        for i in (1..idx.len()).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            idx.swap(i, j);
        }
        let take = |ids: &[usize], tag: &str| {
            let mut x = Vec::with_capacity(ids.len() * self.d);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset::new(&format!("{}-{}", self.name, tag), x, y, self.d)
        };
        (take(&idx[..n_train], "train"), take(&idx[n_train..], "test"))
    }

    /// Subsample to at most `n_max` rows (deterministic).
    pub fn subsample(&self, n_max: usize, seed: u64) -> Dataset {
        if self.n <= n_max {
            return self.clone();
        }
        let (head, _) = self.split(n_max, seed);
        Dataset { name: self.name.clone(), ..head }
    }
}

/// Parse a numeric CSV (optional header) into a Dataset; the target is the
/// given column index (negative = from the end). Content problems are
/// [`KrrError::Dataset`], filesystem problems [`KrrError::Io`] — the same
/// fallible surface as [`CsvSource`]/[`LibsvmSource`].
pub fn load_csv(path: &str, target_col: i64, name: &str) -> Result<Dataset, KrrError> {
    let text =
        std::fs::read_to_string(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match source::parse_csv_fields(line) {
            Ok(v) => rows.push(v),
            Err(_) if lineno == 0 => continue, // header
            Err(e) => {
                return Err(KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))
            }
        }
    }
    if rows.is_empty() {
        return Err(KrrError::Dataset(format!("{path}: no data rows")));
    }
    let width = rows[0].len();
    if rows.iter().any(|r| r.len() != width) {
        return Err(KrrError::Dataset(format!("{path}: ragged rows")));
    }
    let t = if target_col < 0 {
        (width as i64 + target_col) as usize
    } else {
        target_col as usize
    };
    if t >= width {
        return Err(KrrError::Dataset(format!("{path}: target column {t} out of range")));
    }
    let d = width - 1;
    let mut x = Vec::with_capacity(rows.len() * d);
    let mut y = Vec::with_capacity(rows.len());
    for r in rows {
        for (j, v) in r.iter().enumerate() {
            if j == t {
                y.push(*v);
            } else {
                x.push(*v as f32);
            }
        }
    }
    Ok(Dataset::new(name, x, y, d))
}

/// Median pairwise distance over a random pair sample — the classic
/// bandwidth ("median") heuristic. `l1` selects L1 vs L2 distance.
pub fn median_distance(ds: &Dataset, l1: bool, pairs: usize, seed: u64) -> f64 {
    assert!(ds.n >= 2);
    let mut rng = Pcg64::new(seed, 3);
    let mut dists: Vec<f64> = (0..pairs)
        .map(|_| {
            let i = rng.below(ds.n as u64) as usize;
            let mut j = rng.below(ds.n as u64) as usize;
            if j == i {
                j = (j + 1) % ds.n;
            }
            let (a, b) = (ds.row(i), ds.row(j));
            if l1 {
                a.iter()
                    .zip(b)
                    .map(|(x, y)| (*x as f64 - *y as f64).abs())
                    .sum()
            } else {
                a.iter()
                    .zip(b)
                    .map(|(x, y)| {
                        let d = *x as f64 - *y as f64;
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt()
            }
        })
        .collect();
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    dists[dists.len() / 2]
}

/// Root-mean-square error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (s / pred.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let y = vec![1.0, 2.0, 3.0, 4.0];
        Dataset::new("toy", x, y, 2)
    }

    #[test]
    fn row_access() {
        let ds = toy();
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.n, 4);
        assert_eq!(ds.d, 2);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = toy();
        let (ym, ys) = ds.standardize();
        assert!((ym - 2.5).abs() < 1e-12);
        assert!(ys > 0.0);
        for j in 0..ds.d {
            let mean: f64 = (0..ds.n).map(|i| ds.x[i * ds.d + j] as f64).sum::<f64>() / ds.n as f64;
            let var: f64 = (0..ds.n)
                .map(|i| (ds.x[i * ds.d + j] as f64 - mean).powi(2))
                .sum::<f64>()
                / ds.n as f64;
            assert!(mean.abs() < 1e-6);
            assert!((var - 1.0).abs() < 1e-5);
        }
        let ymean: f64 = ds.y.iter().sum::<f64>() / ds.n as f64;
        assert!(ymean.abs() < 1e-12);
    }

    #[test]
    fn split_partitions() {
        let ds = toy();
        let (tr, te) = ds.split(3, 1);
        assert_eq!(tr.n, 3);
        assert_eq!(te.n, 1);
        // all targets accounted for
        let mut all: Vec<f64> = tr.y.iter().chain(te.y.iter()).copied().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn split_is_deterministic() {
        let ds = toy();
        let (a, _) = ds.split(2, 5);
        let (b, _) = ds.split(2, 5);
        assert_eq!(a.y, b.y);
        let (c, _) = ds.split(2, 6);
        assert!(a.y != c.y || a.x != c.x);
    }

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("wlsh_test.csv");
        std::fs::write(&path, "a,b,label\n1.0,2.0,3.0\n4.0,5.0,6.0\n").unwrap();
        let ds = load_csv(path.to_str().unwrap(), -1, "csv").unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.y, vec![3.0, 6.0]);
        assert_eq!(ds.row(1), &[4.0, 5.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let path = std::env::temp_dir().join("wlsh_ragged.csv");
        std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(path.to_str().unwrap(), -1, "bad").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn median_heuristic_is_sane() {
        let mut ds = synthetic_by_name("wine", Some(400), 1).unwrap();
        ds.standardize();
        let m1 = median_distance(&ds, true, 300, 2);
        let m2 = median_distance(&ds, false, 300, 2);
        // standardized 11-dim data: E‖Δ‖₁ ≈ 1.13·d, E‖Δ‖₂ ≈ √(2d)
        assert!(m1 > 4.0 && m1 < 30.0, "L1 median {m1}");
        assert!(m2 > 2.0 && m2 < 10.0, "L2 median {m2}");
        assert!(m1 > m2);
    }

    #[test]
    fn rmse_known() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 4.0]) - 2.0f64.sqrt()).abs() < 1e-12);
    }
}
