//! Chunked data ingestion — the [`DataSource`] abstraction every operator
//! build consumes.
//!
//! A source streams its rows in order as `(rows, targets)` blocks of a
//! caller-chosen size, with the feature count `d` known up front and the
//! row count available as a hint. Sources are **re-iterable**: every call
//! to [`DataSource::for_each_chunk`] replays the identical row sequence
//! from the start (file readers re-open the file), which is what lets the
//! sketch builders run multi-pass algorithms — fit a
//! [`Standardizer`](crate::data::Standardizer), collect Nyström landmarks,
//! then assemble CSR tables — without ever holding the n×d matrix in
//! memory.
//!
//! Implementations here:
//!
//! * [`Dataset`] — the in-memory matrix, chunked by row slicing (no copy).
//! * [`CsvSource`] — buffered numeric-CSV reader (same grammar as
//!   [`load_csv`](crate::data::load_csv): `,`/`;` separators, optional
//!   header, target column by index with negative-from-the-end).
//! * [`LibsvmSource`] — sparse `label idx:val ...` text reader; index
//!   base (0- vs 1-based) is auto-detected on the open scan.
//! * [`MatrixSource`] — a borrowed row-major `&[f32]` with zero targets
//!   (the adapter the in-memory sketch constructors wrap their slice
//!   arguments in, funnelling every build through the one chunked path).
//! * [`SyntheticSource`](crate::data::SyntheticSource) — on-the-fly
//!   generation of the Table-2 stand-ins (see `data/synthetic.rs`).
//!
//! Chunking is an execution detail, never a semantic one: all consumers in
//! this crate are bit-identical across chunk sizes (asserted end-to-end by
//! `tests/stream_equivalence.rs`).
//!
//! ## Sparse chunks
//!
//! Sources whose rows are naturally sparse ([`LibsvmSource`]) can stream
//! CSR blocks instead of densified ones through
//! [`for_each_chunk_any`](DataSource::for_each_chunk_any): a
//! [`SparseChunk`] carries `indptr`/`indices`/`values` for a block of rows
//! with absent coordinates meaning exactly 0. Consumers that opt into
//! `for_each_chunk_any` receive whichever representation the source emits
//! natively ([`is_sparse`](DataSource::is_sparse) says which, so callers
//! can size buffers); everything else keeps calling
//! [`for_each_chunk`](DataSource::for_each_chunk) and sees dense rows as
//! before. Within a row, indices are ascending and unique — the loader
//! sorts and deduplicates (last value wins, matching the dense scatter's
//! overwrite), so per-row walks are mergeable against a dense dimension
//! sweep.

use std::fs::File;
use std::io::{BufRead, BufReader};

use super::Dataset;
use crate::api::KrrError;

/// Visitor for one `(rows, targets)` block: `rows` is row-major with
/// `rows.len() == targets.len() * d`. Returning `Err` aborts the pass.
pub type ChunkFn<'a> = &'a mut dyn FnMut(&[f32], &[f64]) -> Result<(), KrrError>;

/// Visitor for one representation-tagged block (dense or sparse CSR) with
/// its targets. Returning `Err` aborts the pass.
pub type ChunkAnyFn<'a> = &'a mut dyn FnMut(Chunk<'_>, &[f64]) -> Result<(), KrrError>;

/// One block of rows in its native representation.
pub enum Chunk<'a> {
    /// Row-major dense rows, `rows.len() == nrows * d`.
    Dense(&'a [f32]),
    /// CSR rows; absent coordinates are exactly 0.
    Sparse(SparseChunk<'a>),
}

/// A borrowed CSR view of one block of sparse rows: row `i`'s nonzeros
/// are `indices[indptr[i]..indptr[i+1]]` (ascending, unique within a row)
/// with the matching `values`. A listed value may still be 0.0 (an
/// explicit `idx:0` in the file); consumers that skip zeros must skip it
/// the same way the dense path does.
#[derive(Clone, Copy)]
pub struct SparseChunk<'a> {
    /// Row offsets, `len == nrows + 1`, `indptr[0] == 0`.
    pub indptr: &'a [usize],
    /// Column indices per row, ascending and unique within each row.
    pub indices: &'a [u32],
    /// Values at those indices.
    pub values: &'a [f32],
}

impl<'a> SparseChunk<'a> {
    /// Rows in this block.
    pub fn nrows(&self) -> usize {
        self.indptr.len().saturating_sub(1)
    }

    /// Stored entries in this block.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row `i`'s `(indices, values)` pair.
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f32]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Scatter the block into a freshly-zeroed row-major dense buffer of
    /// `nrows * d` — the densified equivalent the bit-identity tests
    /// compare against.
    pub fn densify_into(&self, d: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.nrows() * d, 0.0);
        for i in 0..self.nrows() {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                out[i * d + j as usize] = v;
            }
        }
    }
}

/// An owned CSR block of rows plus targets — the sparse analogue of a
/// small [`Dataset`], returned by [`head_sample_sparse`] so streamed
/// evaluation never allocates `k × d` dense floats.
pub struct SparseBlock {
    /// Features per row.
    pub d: usize,
    /// Row offsets (`len == n + 1`).
    pub indptr: Vec<usize>,
    /// Column indices (ascending, unique within each row).
    pub indices: Vec<u32>,
    /// Values at those indices.
    pub values: Vec<f32>,
    /// Targets.
    pub y: Vec<f64>,
}

impl SparseBlock {
    /// Rows in the block.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Borrow the rows as a [`SparseChunk`].
    pub fn view(&self) -> SparseChunk<'_> {
        SparseChunk { indptr: &self.indptr, indices: &self.indices, values: &self.values }
    }
}

/// A re-iterable, chunked stream of `(rows, targets)` training data.
pub trait DataSource: Send + Sync {
    /// Human-readable source name (reports, errors).
    fn name(&self) -> &str;

    /// Features per row, known before any chunk is produced.
    fn dim(&self) -> usize;

    /// Total row count, when the source knows it without a full pass.
    fn len_hint(&self) -> Option<usize>;

    /// Stream every row in order as blocks of at most `chunk_rows` rows
    /// (a `chunk_rows` of 0 is treated as 1). Each call replays the full
    /// sequence from the start; blocks arrive on the calling thread, in
    /// order.
    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError>;

    /// Whether [`for_each_chunk_any`](Self::for_each_chunk_any) streams
    /// sparse CSR chunks natively. `false` (the default) means it yields
    /// the same dense blocks as [`for_each_chunk`](Self::for_each_chunk).
    fn is_sparse(&self) -> bool {
        false
    }

    /// Stream every row in its native representation: sources override
    /// this to emit [`Chunk::Sparse`] CSR blocks without densifying; the
    /// default wraps the dense stream. Same ordering/replay contract as
    /// [`for_each_chunk`](Self::for_each_chunk).
    fn for_each_chunk_any(&self, chunk_rows: usize, f: ChunkAnyFn) -> Result<(), KrrError> {
        self.for_each_chunk(chunk_rows, &mut |rows, ys| f(Chunk::Dense(rows), ys))
    }

    /// Collect the whole stream into an in-memory [`Dataset`].
    fn materialize(&self, chunk_rows: usize) -> Result<Dataset, KrrError> {
        let d = self.dim();
        let mut x = Vec::new();
        let mut y = Vec::new();
        if let Some(n) = self.len_hint() {
            x.reserve(n * d);
            y.reserve(n);
        }
        self.for_each_chunk(chunk_rows, &mut |rows, ys| {
            x.extend_from_slice(rows);
            y.extend_from_slice(ys);
            Ok(())
        })?;
        if y.is_empty() {
            return Err(KrrError::Dataset(format!("{}: no data rows", self.name())));
        }
        Ok(Dataset::new(self.name(), x, y, d))
    }

    /// Count the rows by streaming (used when [`len_hint`](Self::len_hint)
    /// is `None`).
    fn count_rows(&self, chunk_rows: usize) -> Result<usize, KrrError> {
        if let Some(n) = self.len_hint() {
            return Ok(n);
        }
        let mut n = 0usize;
        self.for_each_chunk(chunk_rows, &mut |_, ys| {
            n += ys.len();
            Ok(())
        })?;
        Ok(n)
    }
}

impl DataSource for Dataset {
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        let chunk = chunk_rows.max(1);
        let mut start = 0usize;
        while start < self.n {
            let end = (start + chunk).min(self.n);
            f(&self.x[start * self.d..end * self.d], &self.y[start..end])?;
            start = end;
        }
        Ok(())
    }
}

/// A borrowed row-major feature matrix with all-zero targets — the adapter
/// the in-memory sketch constructors use so that slice-based and streamed
/// builds share one assembly path.
pub struct MatrixSource<'a> {
    x: &'a [f32],
    d: usize,
    n: usize,
    name: String,
}

impl<'a> MatrixSource<'a> {
    /// Wrap `x` (row-major, `x.len()` divisible by `d`).
    pub fn new(name: &str, x: &'a [f32], d: usize) -> MatrixSource<'a> {
        assert!(d > 0, "MatrixSource needs d > 0");
        assert_eq!(x.len() % d, 0, "matrix length not divisible by d");
        MatrixSource { x, d, n: x.len() / d, name: name.to_string() }
    }
}

impl DataSource for MatrixSource<'_> {
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        let chunk = chunk_rows.max(1);
        let zeros = vec![0.0f64; chunk.min(self.n.max(1))];
        let mut start = 0usize;
        while start < self.n {
            let end = (start + chunk).min(self.n);
            f(&self.x[start * self.d..end * self.d], &zeros[..end - start])?;
            start = end;
        }
        Ok(())
    }
}

/// Buffered chunked reader over a numeric CSV file. The open scan reads
/// the first line to fix the column count (an unparseable first line is a
/// header, exactly like [`load_csv`](crate::data::load_csv)) and counts
/// data lines for [`len_hint`](DataSource::len_hint); content errors
/// (ragged rows, bad floats) surface lazily as
/// [`KrrError::Dataset`] from the streaming pass, with line numbers.
pub struct CsvSource {
    path: String,
    name: String,
    /// Columns per row (features + target).
    width: usize,
    /// Resolved target column in `0..width`.
    target: usize,
    has_header: bool,
    n: usize,
}

/// Split a CSV line into parsed f64 fields (`,`/`;` separators, trimmed)
/// — the one CSV grammar, shared by [`CsvSource`] and
/// [`load_csv`](crate::data::load_csv).
pub(crate) fn parse_csv_fields(line: &str) -> Result<Vec<f64>, std::num::ParseFloatError> {
    line.split([',', ';']).map(|f| f.trim().parse::<f64>()).collect()
}

impl CsvSource {
    /// Open `path`, fixing the schema from the first line(s). `target_col`
    /// indexes the target column; negative counts from the end.
    ///
    /// The open scan reads the whole file once to count rows (no float
    /// parsing past the first line) — a deliberate trade-off: the exact
    /// `len_hint` lets the RFF build reserve its feature matrix in one
    /// allocation and gives the two-pass Nyström build its row count
    /// without a far costlier full-parse `count_rows` pass.
    pub fn open(path: &str, target_col: i64) -> Result<CsvSource, KrrError> {
        let file = File::open(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        let reader = BufReader::new(file);
        let mut width = None;
        let mut has_header = false;
        let mut n = 0usize;
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match width {
                None => match parse_csv_fields(line) {
                    Ok(fields) => {
                        width = Some(fields.len());
                        n += 1;
                    }
                    Err(_) if lineno == 0 => has_header = true,
                    Err(e) => {
                        return Err(KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))
                    }
                },
                Some(_) => n += 1,
            }
        }
        let width = match width {
            Some(w) => w,
            None => return Err(KrrError::Dataset(format!("{path}: no data rows"))),
        };
        let target = if target_col < 0 { width as i64 + target_col } else { target_col };
        if target < 0 || target >= width as i64 {
            return Err(KrrError::Dataset(format!(
                "{path}: target column {target_col} out of range for {width} columns"
            )));
        }
        if width < 2 {
            return Err(KrrError::Dataset(format!(
                "{path}: need at least one feature column besides the target"
            )));
        }
        Ok(CsvSource {
            path: path.to_string(),
            name: path.to_string(),
            width,
            target: target as usize,
            has_header,
            n,
        })
    }
}

impl DataSource for CsvSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.width - 1
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        let chunk = chunk_rows.max(1);
        let d = self.dim();
        let path = &self.path;
        let file = File::open(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        let reader = BufReader::new(file);
        let mut rows: Vec<f32> = Vec::with_capacity(chunk.min(self.n.max(1)) * d);
        let mut ys: Vec<f64> = Vec::with_capacity(chunk.min(self.n.max(1)));
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
            let line = line.trim();
            if line.is_empty() || (lineno == 0 && self.has_header) {
                continue;
            }
            let fields = parse_csv_fields(line)
                .map_err(|e| KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))?;
            if fields.len() != self.width {
                return Err(KrrError::Dataset(format!(
                    "{path}:{}: ragged row ({} columns, expected {})",
                    lineno + 1,
                    fields.len(),
                    self.width
                )));
            }
            for (j, v) in fields.iter().enumerate() {
                if j == self.target {
                    ys.push(*v);
                } else {
                    rows.push(*v as f32);
                }
            }
            if ys.len() == chunk {
                f(&rows, &ys)?;
                rows.clear();
                ys.clear();
            }
        }
        if !ys.is_empty() {
            f(&rows, &ys)?;
        }
        Ok(())
    }
}

/// Chunked reader for LIBSVM/sparse-text files: one `label idx:val ...`
/// row per line, absent indices meaning 0. The open scan fixes the
/// dimensionality from the largest index and auto-detects the index base
/// (a 0 index anywhere ⇒ 0-based; otherwise the conventional 1-based).
pub struct LibsvmSource {
    path: String,
    name: String,
    d: usize,
    n: usize,
    zero_based: bool,
}

/// Parse one LIBSVM line into (label, pairs). `Err` carries the reason
/// without file/line context (the caller adds it).
fn parse_libsvm_line(line: &str) -> Result<(f64, Vec<(u64, f64)>), String> {
    let mut tokens = line.split_whitespace();
    let label = match tokens.next() {
        Some(t) => t
            .parse::<f64>()
            .map_err(|e| format!("bad label {t:?}: {e}"))?,
        None => return Err("empty row".into()),
    };
    let mut pairs = Vec::new();
    for t in tokens {
        let (i, v) = t
            .split_once(':')
            .ok_or_else(|| format!("bad feature {t:?}: expected index:value"))?;
        let idx = i
            .parse::<u64>()
            .map_err(|e| format!("bad feature index {i:?}: {e}"))?;
        let val = v
            .parse::<f64>()
            .map_err(|e| format!("bad feature value {v:?}: {e}"))?;
        pairs.push((idx, val));
    }
    Ok((label, pairs))
}

impl LibsvmSource {
    /// Open `path` and scan it once for row count, dimensionality, and
    /// index base. Content errors surface here (the scan parses every
    /// line), so a successfully opened source streams cleanly.
    ///
    /// The index base is a heuristic: an index 0 anywhere ⇒ 0-based, else
    /// the conventional 1-based. A 0-based file that never *mentions*
    /// index 0 (its first column all zeros, hence never written) is
    /// indistinguishable from a 1-based one and decodes shifted one
    /// column left — when the convention is known, pin it with
    /// [`open_with_base`](Self::open_with_base).
    pub fn open(path: &str) -> Result<LibsvmSource, KrrError> {
        Self::open_impl(path, None)
    }

    /// As [`open`](Self::open) with the index base pinned explicitly
    /// instead of auto-detected. Fails if the file contains an index 0
    /// while `zero_based` is false.
    pub fn open_with_base(path: &str, zero_based: bool) -> Result<LibsvmSource, KrrError> {
        Self::open_impl(path, Some(zero_based))
    }

    fn open_impl(path: &str, base: Option<bool>) -> Result<LibsvmSource, KrrError> {
        let file = File::open(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        let reader = BufReader::new(file);
        let mut n = 0usize;
        let mut max_idx = 0u64;
        let mut min_idx = u64::MAX;
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (_, pairs) = parse_libsvm_line(line)
                .map_err(|e| KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))?;
            for (idx, _) in pairs {
                max_idx = max_idx.max(idx);
                min_idx = min_idx.min(idx);
            }
            n += 1;
        }
        if n == 0 {
            return Err(KrrError::Dataset(format!("{path}: no data rows")));
        }
        let zero_based = match base {
            Some(false) if min_idx == 0 => {
                return Err(KrrError::Dataset(format!(
                    "{path}: contains a 0 feature index but was opened as 1-based"
                )))
            }
            Some(b) => b,
            None => min_idx == 0,
        };
        let d = if min_idx == u64::MAX {
            0 // no features anywhere
        } else if zero_based {
            max_idx as usize + 1
        } else {
            max_idx as usize
        };
        if d == 0 {
            return Err(KrrError::Dataset(format!("{path}: rows carry no features")));
        }
        if d > u32::MAX as usize {
            // sparse chunks store indices as u32
            return Err(KrrError::Dataset(format!(
                "{path}: dimensionality {d} exceeds the supported 2^32-1"
            )));
        }
        Ok(LibsvmSource { path: path.to_string(), name: path.to_string(), d, n, zero_based })
    }

    /// Detected index convention (`true` ⇒ indices start at 0).
    pub fn zero_based(&self) -> bool {
        self.zero_based
    }
}

impl DataSource for LibsvmSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        let chunk = chunk_rows.max(1);
        let d = self.d;
        let path = &self.path;
        let base = if self.zero_based { 0u64 } else { 1u64 };
        let file = File::open(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        let reader = BufReader::new(file);
        let mut rows: Vec<f32> = Vec::with_capacity(chunk.min(self.n) * d);
        let mut ys: Vec<f64> = Vec::with_capacity(chunk.min(self.n));
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (label, pairs) = parse_libsvm_line(line)
                .map_err(|e| KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))?;
            let row_start = rows.len();
            rows.resize(row_start + d, 0.0);
            for (idx, val) in pairs {
                // the open scan fixed d from the max index, but guard
                // against the file changing between scan and stream
                let j = idx
                    .checked_sub(base)
                    .filter(|&j| (j as usize) < d)
                    .ok_or_else(|| {
                        KrrError::Dataset(format!(
                            "{path}:{}: feature index {idx} out of range for d={d}",
                            lineno + 1
                        ))
                    })?;
                rows[row_start + j as usize] = val as f32;
            }
            ys.push(label);
            if ys.len() == chunk {
                f(&rows, &ys)?;
                rows.clear();
                ys.clear();
            }
        }
        if !ys.is_empty() {
            f(&rows, &ys)?;
        }
        Ok(())
    }

    fn is_sparse(&self) -> bool {
        true
    }

    fn for_each_chunk_any(&self, chunk_rows: usize, f: ChunkAnyFn) -> Result<(), KrrError> {
        let chunk = chunk_rows.max(1);
        let d = self.d;
        let path = &self.path;
        let base = if self.zero_based { 0u64 } else { 1u64 };
        let file = File::open(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        let reader = BufReader::new(file);
        let mut indptr: Vec<usize> = Vec::with_capacity(chunk.min(self.n) + 1);
        indptr.push(0);
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        let mut ys: Vec<f64> = Vec::with_capacity(chunk.min(self.n));
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (label, mut pairs) = parse_libsvm_line(line)
                .map_err(|e| KrrError::Dataset(format!("{path}:{}: {e}", lineno + 1)))?;
            // ascending, unique indices per row (stable sort + last-wins
            // dedupe keeps the dense scatter's overwrite semantics)
            pairs.sort_by_key(|p| p.0);
            let row_start = indices.len();
            for (idx, val) in pairs {
                let j = idx
                    .checked_sub(base)
                    .filter(|&j| (j as usize) < d)
                    .ok_or_else(|| {
                        KrrError::Dataset(format!(
                            "{path}:{}: feature index {idx} out of range for d={d}",
                            lineno + 1
                        ))
                    })? as u32;
                if indices.len() > row_start && *indices.last().unwrap() == j {
                    *values.last_mut().unwrap() = val as f32;
                } else {
                    indices.push(j);
                    values.push(val as f32);
                }
            }
            indptr.push(indices.len());
            ys.push(label);
            if ys.len() == chunk {
                let view =
                    SparseChunk { indptr: &indptr, indices: &indices, values: &values };
                f(Chunk::Sparse(view), &ys)?;
                indptr.clear();
                indptr.push(0);
                indices.clear();
                values.clear();
                ys.clear();
            }
        }
        if !ys.is_empty() {
            let view = SparseChunk { indptr: &indptr, indices: &indices, values: &values };
            f(Chunk::Sparse(view), &ys)?;
        }
        Ok(())
    }
}

/// Force the dense chunk representation: `for_each_chunk_any` on this
/// adapter always yields [`Chunk::Dense`] regardless of the inner
/// source's native representation — the `--sparse=false` escape hatch
/// that restores the densifying pipeline (and its centered
/// standardization) for sparse files.
pub struct DensifySource<'a> {
    inner: &'a dyn DataSource,
}

impl<'a> DensifySource<'a> {
    /// View `inner` as a dense-only source.
    pub fn new(inner: &'a dyn DataSource) -> DensifySource<'a> {
        DensifySource { inner }
    }
}

impl DataSource for DensifySource<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        self.inner.for_each_chunk(chunk_rows, f)
    }
    // is_sparse / for_each_chunk_any deliberately stay at the dense
    // defaults, which route through the inner source's dense stream
}

/// Serialize a dataset in LIBSVM format (nonzero features only) — test
/// round-trips and dataset export. `zero_based` picks the index base.
pub fn write_libsvm(ds: &Dataset, path: &str, zero_based: bool) -> Result<(), KrrError> {
    use std::io::Write;
    let base = if zero_based { 0 } else { 1 };
    let file = File::create(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
    let mut w = std::io::BufWriter::new(file);
    for i in 0..ds.n {
        let mut line = format!("{}", ds.y[i]);
        for (j, &v) in ds.row(i).iter().enumerate() {
            if v != 0.0 {
                line.push_str(&format!(" {}:{}", j + base, v));
            }
        }
        line.push('\n');
        w.write_all(line.as_bytes())
            .map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
    }
    Ok(())
}

/// Serialize a dataset as a numeric CSV with the target as the last
/// column (the `load_csv`/[`CsvSource`] convention for `target_col=-1`).
pub fn write_csv(ds: &Dataset, path: &str) -> Result<(), KrrError> {
    use std::io::Write;
    let file = File::create(path).map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
    let mut w = std::io::BufWriter::new(file);
    for i in 0..ds.n {
        let mut line = String::new();
        for &v in ds.row(i) {
            line.push_str(&format!("{v},"));
        }
        line.push_str(&format!("{}\n", ds.y[i]));
        w.write_all(line.as_bytes())
            .map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
    }
    Ok(())
}

/// Materialize the first `k` rows of a source (O(k·d) memory) — the
/// CLI's held-in-memory evaluation sample for streamed training runs.
/// The pass aborts (via the `ChunkFn` error channel) as soon as `k` rows
/// are collected, so file-backed sources stop parsing after roughly `k`
/// rows rather than replaying the whole stream.
pub fn head_sample(
    src: &dyn DataSource,
    k: usize,
    chunk_rows: usize,
) -> Result<Dataset, KrrError> {
    let d = src.dim();
    let mut x = Vec::with_capacity(k * d);
    let mut y = Vec::with_capacity(k);
    // `done` distinguishes our own early-stop error from a genuine source
    // error structurally — no dependence on message contents, which
    // wrapping sources are free to reformat.
    let mut done = false;
    let result = src.for_each_chunk(chunk_rows, &mut |rows, ys| {
        let take = (k - y.len()).min(ys.len());
        x.extend_from_slice(&rows[..take * d]);
        y.extend_from_slice(&ys[..take]);
        if y.len() >= k {
            done = true;
            return Err(KrrError::Dataset("head sample complete".to_string()));
        }
        Ok(())
    });
    match result {
        Ok(()) => {}
        Err(_) if done => {}
        Err(e) => return Err(e),
    }
    if y.is_empty() {
        return Err(KrrError::Dataset(format!("{}: no data rows", src.name())));
    }
    Ok(Dataset::new(src.name(), x, y, d))
}

/// Sparse analogue of [`head_sample`]: the first `k` rows as an owned CSR
/// [`SparseBlock`] (O(k·nnz) memory instead of O(k·d)) — the evaluation
/// sample for sparse streamed training, where densifying even the head
/// would cost `k × d` floats. Dense chunks from a mixed stream are
/// compressed (zeros dropped).
pub fn head_sample_sparse(
    src: &dyn DataSource,
    k: usize,
    chunk_rows: usize,
) -> Result<SparseBlock, KrrError> {
    let d = src.dim();
    let mut out = SparseBlock {
        d,
        indptr: vec![0usize],
        indices: Vec::new(),
        values: Vec::new(),
        y: Vec::with_capacity(k),
    };
    let mut done = false;
    let result = src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
        let take = (k - out.y.len()).min(ys.len());
        match chunk {
            Chunk::Sparse(sp) => {
                for i in 0..take {
                    let (idx, vals) = sp.row(i);
                    out.indices.extend_from_slice(idx);
                    out.values.extend_from_slice(vals);
                    out.indptr.push(out.indices.len());
                }
            }
            Chunk::Dense(rows) => {
                for row in rows.chunks(d).take(take) {
                    for (j, &v) in row.iter().enumerate() {
                        if v != 0.0 {
                            out.indices.push(j as u32);
                            out.values.push(v);
                        }
                    }
                    out.indptr.push(out.indices.len());
                }
            }
        }
        out.y.extend_from_slice(&ys[..take]);
        if out.y.len() >= k {
            done = true;
            return Err(KrrError::Dataset("head sample complete".to_string()));
        }
        Ok(())
    });
    match result {
        Ok(()) => {}
        Err(_) if done => {}
        Err(e) => return Err(e),
    }
    if out.y.is_empty() {
        return Err(KrrError::Dataset(format!("{}: no data rows", src.name())));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        let y = vec![0.1, 0.2, 0.3, 0.4, 0.5];
        Dataset::new("toy", x, y, 2)
    }

    #[test]
    fn dataset_chunks_cover_all_rows_in_order() {
        let ds = toy();
        for chunk in [1usize, 2, 3, 5, 100] {
            let got = ds.materialize(chunk).unwrap();
            assert_eq!(got.x, ds.x, "chunk={chunk}");
            assert_eq!(got.y, ds.y, "chunk={chunk}");
            assert_eq!(got.d, ds.d);
        }
        // chunk_rows == 0 degrades to 1 instead of spinning
        let got = ds.materialize(0).unwrap();
        assert_eq!(got.y, ds.y);
    }

    #[test]
    fn matrix_source_streams_rows_with_zero_targets() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let src = MatrixSource::new("m", &x, 3);
        assert_eq!(src.dim(), 3);
        assert_eq!(src.len_hint(), Some(2));
        let ds = src.materialize(1).unwrap();
        assert_eq!(ds.x, x);
        assert_eq!(ds.y, vec![0.0, 0.0]);
    }

    #[test]
    fn csv_source_matches_dataset_for_every_chunk_size() {
        let path = std::env::temp_dir().join("wlsh_src_test.csv");
        let ds = toy();
        write_csv(&ds, path.to_str().unwrap()).unwrap();
        let src = CsvSource::open(path.to_str().unwrap(), -1).unwrap();
        assert_eq!(src.dim(), 2);
        assert_eq!(src.len_hint(), Some(5));
        for chunk in [1usize, 2, 5, 64] {
            let got = src.materialize(chunk).unwrap();
            assert_eq!(got.x, ds.x, "chunk={chunk}");
            assert_eq!(got.y, ds.y, "chunk={chunk}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_source_supports_header_and_target_column_choice() {
        let path = std::env::temp_dir().join("wlsh_src_header.csv");
        std::fs::write(&path, "a,b,c\n1.0,2.0,3.0\n4.0,5.0,6.0\n").unwrap();
        let src = CsvSource::open(path.to_str().unwrap(), 0).unwrap();
        let ds = src.materialize(16).unwrap();
        assert_eq!(ds.y, vec![1.0, 4.0]);
        assert_eq!(ds.x, vec![2.0, 3.0, 5.0, 6.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn libsvm_roundtrip_both_index_bases() {
        let ds = toy();
        for zero_based in [false, true] {
            let path = std::env::temp_dir()
                .join(format!("wlsh_src_{}.libsvm", if zero_based { "zb" } else { "ob" }));
            write_libsvm(&ds, path.to_str().unwrap(), zero_based).unwrap();
            let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
            assert_eq!(src.zero_based(), zero_based);
            assert_eq!(src.dim(), 2);
            let got = src.materialize(2).unwrap();
            assert_eq!(got.x, ds.x, "zero_based={zero_based}");
            assert_eq!(got.y, ds.y, "zero_based={zero_based}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn head_sample_takes_a_prefix() {
        let ds = toy();
        let head = head_sample(&ds, 3, 2).unwrap();
        assert_eq!(head.n, 3);
        assert_eq!(head.y, vec![0.1, 0.2, 0.3]);
        assert_eq!(head.x, ds.x[..6].to_vec());
        // k larger than n yields everything
        let all = head_sample(&ds, 99, 2).unwrap();
        assert_eq!(all.n, ds.n);
    }

    #[test]
    fn count_rows_streams_when_no_hint() {
        let ds = toy();
        assert_eq!(ds.count_rows(2).unwrap(), 5);
    }

    /// Materialize through the representation-tagged stream, densifying
    /// sparse chunks — exercises `for_each_chunk_any` end to end.
    fn materialize_any(src: &dyn DataSource, chunk: usize) -> Dataset {
        let d = src.dim();
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut buf = Vec::new();
        src.for_each_chunk_any(chunk, &mut |c, ys| {
            match c {
                Chunk::Dense(rows) => x.extend_from_slice(rows),
                Chunk::Sparse(sp) => {
                    sp.densify_into(d, &mut buf);
                    x.extend_from_slice(&buf);
                }
            }
            y.extend_from_slice(ys);
            Ok(())
        })
        .unwrap();
        Dataset::new(src.name(), x, y, d)
    }

    #[test]
    fn libsvm_sparse_chunks_densify_to_the_dense_stream() {
        let ds = toy();
        let path = std::env::temp_dir().join("wlsh_src_sparse_eq.libsvm");
        write_libsvm(&ds, path.to_str().unwrap(), false).unwrap();
        let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
        assert!(src.is_sparse());
        for chunk in [1usize, 2, 3, 5, 64] {
            let got = materialize_any(&src, chunk);
            assert_eq!(got.x, ds.x, "chunk={chunk}");
            assert_eq!(got.y, ds.y, "chunk={chunk}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn libsvm_sparse_chunks_sort_and_dedupe_indices() {
        // out-of-order and duplicate indices: ascending unique output,
        // last value winning like the dense scatter's overwrite
        let path = std::env::temp_dir().join("wlsh_src_sparse_dup.libsvm");
        std::fs::write(&path, "1.5 3:9 1:2 3:7 2:4\n").unwrap();
        let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
        src.for_each_chunk_any(8, &mut |c, ys| {
            let sp = match c {
                Chunk::Sparse(sp) => sp,
                Chunk::Dense(_) => panic!("expected sparse"),
            };
            assert_eq!(ys, [1.5]);
            let (idx, vals) = sp.row(0);
            assert_eq!(idx, [0, 1, 2]);
            assert_eq!(vals, [2.0, 4.0, 7.0]);
            Ok(())
        })
        .unwrap();
        let dense = src.materialize(8).unwrap();
        assert_eq!(dense.x, vec![2.0, 4.0, 7.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn densify_source_hides_the_sparse_representation() {
        let ds = toy();
        let path = std::env::temp_dir().join("wlsh_src_densify.libsvm");
        write_libsvm(&ds, path.to_str().unwrap(), false).unwrap();
        let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
        let dense_view = DensifySource::new(&src);
        assert!(!dense_view.is_sparse());
        let got = materialize_any(&dense_view, 2);
        assert_eq!(got.x, ds.x);
        dense_view
            .for_each_chunk_any(2, &mut |c, _| {
                assert!(matches!(c, Chunk::Dense(_)));
                Ok(())
            })
            .unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn head_sample_sparse_takes_a_csr_prefix() {
        let ds = toy();
        let path = std::env::temp_dir().join("wlsh_src_head_sparse.libsvm");
        write_libsvm(&ds, path.to_str().unwrap(), false).unwrap();
        let src = LibsvmSource::open(path.to_str().unwrap()).unwrap();
        let head = head_sample_sparse(&src, 3, 2).unwrap();
        assert_eq!(head.n(), 3);
        assert_eq!(head.y, vec![0.1, 0.2, 0.3]);
        let mut dense = Vec::new();
        head.view().densify_into(head.d, &mut dense);
        assert_eq!(dense, ds.x[..6].to_vec());
        // a dense source compresses through the same helper
        let from_dense = head_sample_sparse(&ds, 3, 2).unwrap();
        let mut dense2 = Vec::new();
        from_dense.view().densify_into(from_dense.d, &mut dense2);
        assert_eq!(dense2, ds.x[..6].to_vec());
        std::fs::remove_file(&path).ok();
    }
}
