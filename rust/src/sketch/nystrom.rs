//! Nyström low-rank baseline (related work: Musco–Musco 2017, Rudi et al.
//! 2015): K̃ = C W⁺ Cᵀ with C = K(X, L), W = K(L, L) for uniformly sampled
//! landmarks L. Data-dependent, unlike WLSH/RFF — included as the ablation
//! point the paper contrasts against in §1.1.

use super::KrrOperator;
use crate::kernels::Kernel;
use crate::linalg::{CholeskyFactor, Matrix};
use crate::util::rng::Pcg64;

/// Nyström sketch with `k` uniformly-sampled landmarks.
pub struct NystromSketch {
    x: Vec<f32>,
    n: usize,
    d: usize,
    kernel: Kernel,
    /// Landmark rows (k×d).
    landmarks: Vec<f32>,
    k: usize,
    /// Cholesky of W + jitter.
    w_chol: CholeskyFactor,
    /// n×k C = K(X, L), row-major.
    c: Vec<f64>,
}

impl NystromSketch {
    pub fn build(
        x: &[f32],
        n: usize,
        d: usize,
        k: usize,
        kernel: Kernel,
        seed: u64,
    ) -> NystromSketch {
        assert_eq!(x.len(), n * d);
        assert!(k <= n && k > 0);
        let mut rng = Pcg64::new(seed, 0);
        // sample k distinct landmark indices (floyd's algorithm is overkill;
        // partial fisher-yates)
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        let mut landmarks = Vec::with_capacity(k * d);
        for &i in idx.iter().take(k) {
            landmarks.extend_from_slice(&x[i * d..(i + 1) * d]);
        }
        let mut w = Matrix::zeros(k, k);
        for a in 0..k {
            for b in 0..k {
                w[(a, b)] = kernel.eval_f32(
                    &landmarks[a * d..(a + 1) * d],
                    &landmarks[b * d..(b + 1) * d],
                );
            }
        }
        let w_chol = CholeskyFactor::new(&w, 1e-8 * k as f64)
            .expect("landmark kernel matrix not PD");
        let mut c = vec![0.0f64; n * k];
        for i in 0..n {
            for a in 0..k {
                c[i * k + a] = kernel.eval_f32(
                    &x[i * d..(i + 1) * d],
                    &landmarks[a * d..(a + 1) * d],
                );
            }
        }
        NystromSketch { x: x.to_vec(), n, d, kernel, landmarks, k, w_chol, c }
    }

    /// v = W⁻¹ Cᵀ β (the k-dim core of every product).
    fn core(&self, beta: &[f64]) -> Vec<f64> {
        let mut ct_beta = vec![0.0f64; self.k];
        for i in 0..self.n {
            let ci = &self.c[i * self.k..(i + 1) * self.k];
            let bi = beta[i];
            for (acc, cv) in ct_beta.iter_mut().zip(ci) {
                *acc += bi * cv;
            }
        }
        self.w_chol.solve(&ct_beta)
    }
}

impl KrrOperator for NystromSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let v = self.core(beta);
        (0..self.n)
            .map(|i| {
                let ci = &self.c[i * self.k..(i + 1) * self.k];
                ci.iter().zip(&v).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    fn prepare(&self, beta: &[f64]) -> super::PreparedState {
        super::PreparedState { slots: vec![self.core(beta)] }
    }

    fn predict_prepared(
        &self,
        queries: &[f32],
        _beta: &[f64],
        state: &super::PreparedState,
    ) -> Vec<f64> {
        self.predict_core(&state.slots[0], queries)
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let v = self.core(beta);
        self.predict_core(&v, queries)
    }

    fn name(&self) -> String {
        format!("nystrom({},k={})", self.kernel.name(), self.k)
    }

    fn memory_bytes(&self) -> usize {
        self.x.len() * 4 + self.c.len() * 8 + self.landmarks.len() * 4
    }
}

impl NystromSketch {
    fn predict_core(&self, v: &[f64], queries: &[f32]) -> Vec<f64> {
        let q = queries.len() / self.d;
        (0..q)
            .map(|qi| {
                let xq = &queries[qi * self.d..(qi + 1) * self.d];
                (0..self.k)
                    .map(|a| {
                        self.kernel.eval_f32(
                            xq,
                            &self.landmarks[a * self.d..(a + 1) * self.d],
                        ) * v[a]
                    })
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_rank_nystrom_is_exact() {
        // k = n with distinct landmarks ⇒ K̃ = K exactly.
        let mut rng = Pcg64::new(1, 0);
        let (n, d) = (12, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let kern = Kernel::squared_exp(1.0);
        let nys = NystromSketch::build(&x, n, d, n, kern.clone(), 2);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = nys.matvec(&beta);
        for i in 0..n {
            let want: f64 = (0..n)
                .map(|j| kern.eval_f32(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]) * beta[j])
                .sum();
            assert!((y[i] - want).abs() < 1e-4 * (1.0 + want.abs()), "row {i}: {} vs {want}", y[i]);
        }
    }

    #[test]
    fn low_rank_is_psd() {
        let mut rng = Pcg64::new(3, 0);
        let (n, d, k) = (40, 3, 8);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let nys = NystromSketch::build(&x, n, d, k, Kernel::matern52(1.0), 4);
        for _ in 0..5 {
            let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = nys.matvec(&beta);
            let q: f64 = beta.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-8, "quadratic form {q}");
        }
    }
}
