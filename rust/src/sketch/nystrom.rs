//! Nyström low-rank baseline (related work: Musco–Musco 2017, Rudi et al.
//! 2015): K̃ = C W⁺ Cᵀ with C = K(X, L), W = K(L, L) for uniformly sampled
//! landmarks L. Data-dependent, unlike WLSH/RFF — included as the ablation
//! point the paper contrasts against in §1.1.

use std::sync::Arc;

use super::{KrrOperator, Predictor};
use crate::api::KrrError;
use crate::data::{DataSource, MatrixSource};
use crate::kernels::Kernel;
use crate::linalg::{CholeskyFactor, Matrix};
use crate::util::par;
use crate::util::rng::Pcg64;

/// Rows per thread task when evaluating C = K(X, L) in parallel. Fixed so
/// the decomposition is machine-independent (the evaluation is pure per
/// row, so any decomposition is bit-identical to the serial loop).
const C_BLOCK: usize = 128;

/// Nyström sketch with `k` uniformly-sampled landmarks.
///
/// The sketch retains only the k×d landmarks and the n×k cross matrix C —
/// never the n×d training matrix; both in-memory and streamed builds
/// funnel through the chunked [`build_source`](Self::build_source) path.
pub struct NystromSketch {
    n: usize,
    d: usize,
    kernel: Kernel,
    /// Landmark rows (k×d).
    landmarks: Vec<f32>,
    k: usize,
    /// Cholesky of W + jitter.
    w_chol: CholeskyFactor,
    /// n×k C = K(X, L), row-major.
    c: Vec<f64>,
}

impl NystromSketch {
    /// Sample `k` landmarks and factor the core. Fails (rather than
    /// panicking) when the landmark kernel matrix is not positive definite
    /// — e.g. duplicate points under a degenerate kernel.
    pub fn build(
        x: &[f32],
        n: usize,
        d: usize,
        k: usize,
        kernel: Kernel,
        seed: u64,
    ) -> Result<NystromSketch, KrrError> {
        assert_eq!(x.len(), n * d);
        let src = MatrixSource::new("mem", x, d);
        Self::build_source(&src, k, kernel, seed, n.max(1), 1)
    }

    /// Streaming build over a re-iterable source: pass 1 collects the
    /// sampled landmark rows (indices drawn exactly as the in-memory
    /// constructor draws them), then W factors, then pass 2 evaluates the
    /// cross matrix C chunk by chunk (rows within a chunk fanned out over
    /// `workers`). Peak memory is O(chunk·d + n·k + k·d) — the sketch
    /// itself plus one chunk — and the result is bit-identical to
    /// [`build`](Self::build) on the materialized rows for every chunk
    /// size and worker count.
    pub fn build_source(
        src: &dyn DataSource,
        k: usize,
        kernel: Kernel,
        seed: u64,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<NystromSketch, KrrError> {
        let d = src.dim();
        let n = src.count_rows(chunk_rows)?;
        if k == 0 || k > n {
            return Err(KrrError::BadParam(format!(
                "nystrom landmark count must be in 1..={n}, got {k}"
            )));
        }
        let mut rng = Pcg64::new(seed, 0);
        // sample k distinct landmark indices (floyd's algorithm is overkill;
        // partial fisher-yates)
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        // landmark slot of each sampled row index, for the collection pass
        let slot_of: std::collections::HashMap<usize, usize> =
            idx.iter().take(k).enumerate().map(|(s, &i)| (i, s)).collect();
        drop(idx);
        // pass 1: pull the landmark rows out of the stream
        let mut landmarks = vec![0.0f32; k * d];
        let mut row0 = 0usize;
        src.for_each_chunk(chunk_rows, &mut |rows, ys| {
            for r in 0..ys.len() {
                if let Some(&s) = slot_of.get(&(row0 + r)) {
                    landmarks[s * d..(s + 1) * d].copy_from_slice(&rows[r * d..(r + 1) * d]);
                }
            }
            row0 += ys.len();
            Ok(())
        })?;
        if row0 != n {
            return Err(KrrError::Dataset(format!(
                "{}: row count changed between passes ({row0} vs {n})",
                src.name()
            )));
        }
        let mut w = Matrix::zeros(k, k);
        for a in 0..k {
            for b in 0..k {
                w[(a, b)] = kernel.eval_f32(
                    &landmarks[a * d..(a + 1) * d],
                    &landmarks[b * d..(b + 1) * d],
                );
            }
        }
        let w_chol = CholeskyFactor::new(&w, 1e-8 * k as f64)
            .map_err(|e| KrrError::SolveFailed(format!("landmark kernel matrix not PD: {e}")))?;
        // pass 2: C = K(X, L), appended in row order chunk by chunk
        let mut c: Vec<f64> = Vec::with_capacity(n * k);
        src.for_each_chunk(chunk_rows, &mut |rows, ys| {
            let q = ys.len();
            if workers <= 1 || q <= C_BLOCK {
                // push straight into the reserved c — the whole-matrix
                // chunk of the in-memory build() must not transiently
                // double the dominant n×k allocation
                for r in 0..q {
                    let xr = &rows[r * d..(r + 1) * d];
                    for a in 0..k {
                        c.push(kernel.eval_f32(xr, &landmarks[a * d..(a + 1) * d]));
                    }
                }
            } else {
                let eval_block = |lo: usize, hi: usize| {
                    let mut block = Vec::with_capacity((hi - lo) * k);
                    for r in lo..hi {
                        let xr = &rows[r * d..(r + 1) * d];
                        for a in 0..k {
                            block.push(kernel.eval_f32(xr, &landmarks[a * d..(a + 1) * d]));
                        }
                    }
                    block
                };
                let n_blocks = q.div_ceil(C_BLOCK);
                let pieces = par::fan_out(n_blocks, workers, |b| {
                    eval_block(b * C_BLOCK, ((b + 1) * C_BLOCK).min(q))
                });
                for p in pieces {
                    c.extend_from_slice(&p);
                }
            }
            Ok(())
        })?;
        // same TOCTOU guard as pass 1: a file shrinking between passes
        // must be a clean error, not an out-of-bounds panic in matvec
        if c.len() != n * k {
            return Err(KrrError::Dataset(format!(
                "{}: row count changed between passes ({} vs {n})",
                src.name(),
                c.len() / k
            )));
        }
        Ok(NystromSketch { n, d, kernel, landmarks, k, w_chol, c })
    }

    /// Factor (K̃ + λI)⁻¹ for use as a CG preconditioner (the rank-k
    /// analogue of Avron et al.'s RFF preconditioner for sketched KRR).
    ///
    /// By the Woodbury identity, with K̃ = C W⁻¹ Cᵀ:
    ///
    ///   (λI + C W⁻¹ Cᵀ)⁻¹ r = (r − C S⁻¹ Cᵀ r) / λ,   S = λW + CᵀC,
    ///
    /// so one application costs O(n·k + k²) after a one-time O(n·k² + k³)
    /// factorization of S (Cholesky; S is SPD because W is PD and CᵀC is
    /// PSD). Requires λ > 0.
    pub fn ridge_precond(&self, lambda: f64) -> Result<NystromPrecond, String> {
        if lambda <= 0.0 {
            return Err(format!("ridge_precond needs lambda > 0, got {lambda}"));
        }
        // W = L Lᵀ (build-time jitter folded into L).
        let l = &self.w_chol.l;
        let w = l.matmul(&l.transpose());
        let mut s = Matrix::zeros(self.k, self.k);
        for a in 0..self.k {
            for b in 0..self.k {
                s[(a, b)] = lambda * w[(a, b)];
            }
        }
        // S += CᵀC, accumulated row-by-row over the n×k C.
        for i in 0..self.n {
            let ci = &self.c[i * self.k..(i + 1) * self.k];
            for (a, &ca) in ci.iter().enumerate() {
                if ca != 0.0 {
                    let row = s.row_mut(a);
                    for (sv, &cb) in row.iter_mut().zip(ci) {
                        *sv += ca * cb;
                    }
                }
            }
        }
        let s_chol = CholeskyFactor::new(&s, 0.0)?;
        Ok(NystromPrecond {
            c: self.c.clone(),
            n: self.n,
            k: self.k,
            lambda,
            s_chol,
        })
    }

    /// v = W⁻¹ Cᵀ β (the k-dim core of every product).
    fn core(&self, beta: &[f64]) -> Vec<f64> {
        let mut ct_beta = vec![0.0f64; self.k];
        for i in 0..self.n {
            let ci = &self.c[i * self.k..(i + 1) * self.k];
            let bi = beta[i];
            for (acc, cv) in ct_beta.iter_mut().zip(ci) {
                *acc += bi * cv;
            }
        }
        self.w_chol.solve(&ct_beta)
    }
}

impl KrrOperator for NystromSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let v = self.core(beta);
        (0..self.n)
            .map(|i| {
                let ci = &self.c[i * self.k..(i + 1) * self.k];
                ci.iter().zip(&v).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let v = self.core(beta);
        self.predict_core(&v, queries)
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        let core = self.core(beta);
        Box::new(NystromPredictor { sketch: self, core })
    }

    fn diag(&self) -> Option<Vec<f64>> {
        // (C W⁻¹ Cᵀ)_ii = c_iᵀ W⁻¹ c_i — one k×k triangular solve per row.
        Some(
            (0..self.n)
                .map(|i| {
                    let ci = &self.c[i * self.k..(i + 1) * self.k];
                    let wi = self.w_chol.solve(ci);
                    ci.iter().zip(&wi).map(|(a, b)| a * b).sum()
                })
                .collect(),
        )
    }

    fn name(&self) -> String {
        format!("nystrom({},k={})", self.kernel.name(), self.k)
    }

    fn memory_bytes(&self) -> usize {
        self.c.len() * 8 + self.landmarks.len() * 4
    }
}

/// A factored (K̃_nys + λI)⁻¹ — see [`NystromSketch::ridge_precond`].
/// Applying it is O(n·k): two C products and one k×k triangular solve.
pub struct NystromPrecond {
    /// n×k C = K(X, L), row-major (copied from the sketch).
    c: Vec<f64>,
    n: usize,
    k: usize,
    lambda: f64,
    /// Cholesky of S = λW + CᵀC.
    s_chol: CholeskyFactor,
}

impl NystromPrecond {
    /// z = (K̃_nys + λI)⁻¹ r via the Woodbury identity.
    pub fn apply(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let mut t = vec![0.0f64; self.k];
        for i in 0..self.n {
            let ci = &self.c[i * self.k..(i + 1) * self.k];
            let ri = r[i];
            for (acc, &cv) in t.iter_mut().zip(ci) {
                *acc += ri * cv;
            }
        }
        let u = self.s_chol.solve(&t);
        let inv_lambda = 1.0 / self.lambda;
        (0..self.n)
            .map(|i| {
                let ci = &self.c[i * self.k..(i + 1) * self.k];
                let cu: f64 = ci.iter().zip(&u).map(|(a, b)| a * b).sum();
                (r[i] - cu) * inv_lambda
            })
            .collect()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Landmark count (rank) of the factored operator.
    pub fn rank(&self) -> usize {
        self.k
    }
}

impl NystromSketch {
    fn predict_core(&self, v: &[f64], queries: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; queries.len() / self.d];
        self.predict_core_into(v, queries, &mut out);
        out
    }

    fn predict_core_into(&self, v: &[f64], queries: &[f32], out: &mut [f64]) {
        assert_eq!(out.len(), queries.len() / self.d);
        for (qi, o) in out.iter_mut().enumerate() {
            let xq = &queries[qi * self.d..(qi + 1) * self.d];
            *o = (0..self.k)
                .map(|a| {
                    self.kernel
                        .eval_f32(xq, &self.landmarks[a * self.d..(a + 1) * self.d])
                        * v[a]
                })
                .sum();
        }
    }
}

/// Frozen Nyström serving handle: the landmark core v = W⁻¹Cᵀβ, so a
/// prediction is k kernel evaluations against the landmarks.
pub struct NystromPredictor {
    sketch: Arc<NystromSketch>,
    core: Vec<f64>,
}

impl Predictor for NystromPredictor {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.sketch.predict_core_into(&self.core, queries, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_rank_nystrom_is_exact() {
        // k = n with distinct landmarks ⇒ K̃ = K exactly.
        let mut rng = Pcg64::new(1, 0);
        let (n, d) = (12, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let kern = Kernel::squared_exp(1.0);
        let nys = NystromSketch::build(&x, n, d, n, kern.clone(), 2).unwrap();
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = nys.matvec(&beta);
        for i in 0..n {
            let want: f64 = (0..n)
                .map(|j| kern.eval_f32(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]) * beta[j])
                .sum();
            assert!((y[i] - want).abs() < 1e-4 * (1.0 + want.abs()), "row {i}: {} vs {want}", y[i]);
        }
    }

    #[test]
    fn ridge_precond_inverts_shifted_operator() {
        // M = K̃ + λI; apply(M v) must recover v (Woodbury algebra check).
        let mut rng = Pcg64::new(5, 0);
        let (n, d, k) = (30, 2, 10);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let nys = NystromSketch::build(&x, n, d, k, Kernel::squared_exp(1.0), 6).unwrap();
        let lambda = 0.37;
        let pre = nys.ridge_precond(lambda).unwrap();
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut mv = nys.matvec(&v);
        for (m, vi) in mv.iter_mut().zip(&v) {
            *m += lambda * vi;
        }
        let back = pre.apply(&mv);
        for i in 0..n {
            assert!(
                (back[i] - v[i]).abs() < 1e-8 * (1.0 + v[i].abs()),
                "row {i}: {} vs {}",
                back[i],
                v[i]
            );
        }
        assert_eq!(pre.rank(), k);
        assert_eq!(pre.n(), n);
    }

    #[test]
    fn ridge_precond_rejects_nonpositive_lambda() {
        let mut rng = Pcg64::new(7, 0);
        let (n, d) = (12, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let nys = NystromSketch::build(&x, n, d, 4, Kernel::squared_exp(1.0), 8).unwrap();
        assert!(nys.ridge_precond(0.0).is_err());
        assert!(nys.ridge_precond(-1.0).is_err());
    }

    #[test]
    fn diag_matches_matvec_columns() {
        let mut rng = Pcg64::new(9, 0);
        let (n, d, k) = (25, 3, 9);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let nys = NystromSketch::build(&x, n, d, k, Kernel::matern52(1.0), 10).unwrap();
        let diag = KrrOperator::diag(&nys).unwrap();
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = nys.matvec(&e);
            assert!(
                (diag[j] - col[j]).abs() < 1e-9 * (1.0 + col[j].abs()),
                "diag[{j}] {} vs {}",
                diag[j],
                col[j]
            );
        }
    }

    #[test]
    fn low_rank_is_psd() {
        let mut rng = Pcg64::new(3, 0);
        let (n, d, k) = (40, 3, 8);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let nys = NystromSketch::build(&x, n, d, k, Kernel::matern52(1.0), 4).unwrap();
        for _ in 0..5 {
            let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = nys.matvec(&beta);
            let q: f64 = beta.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-8, "quadratic form {q}");
        }
    }
}
