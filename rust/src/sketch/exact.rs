//! Exact kernel operator — the paper's exact-KRR baselines (Table 1/2).
//! O(n²d) mat-vec, never materializes K (blockwise row streaming).

use std::sync::Arc;

use super::{KrrOperator, Predictor};
use crate::kernels::Kernel;

/// Exact K(X, X) as a mat-vec operator.
pub struct ExactKernelOp {
    x: Vec<f32>,
    n: usize,
    d: usize,
    pub kernel: Kernel,
}

impl ExactKernelOp {
    pub fn new(x: &[f32], n: usize, d: usize, kernel: Kernel) -> ExactKernelOp {
        assert_eq!(x.len(), n * d);
        ExactKernelOp { x: x.to_vec(), n, d, kernel }
    }

    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Shared predict kernel (one O(n·d) pass per query row).
    fn predict_into_impl(&self, queries: &[f32], beta: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), queries.len() / self.d);
        for (qi, o) in out.iter_mut().enumerate() {
            let xq = &queries[qi * self.d..(qi + 1) * self.d];
            *o = (0..self.n)
                .map(|j| self.kernel.eval_f32(xq, self.row(j)) * beta[j])
                .sum();
        }
    }
}

/// Serving handle for the exact operator: the β-dependent state is β
/// itself (there is no cheaper summary for an exact kernel).
pub struct ExactPredictor {
    op: Arc<ExactKernelOp>,
    beta: Vec<f64>,
}

impl Predictor for ExactPredictor {
    fn dim(&self) -> usize {
        self.op.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.op.predict_into_impl(queries, &self.beta, out);
    }
}

impl KrrOperator for ExactKernelOp {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        // Symmetric: evaluate each pair once, scatter both contributions.
        let mut y: Vec<f64> = beta.iter().map(|b| b * self.kernel.diag()).collect();
        for i in 0..self.n {
            let xi = self.row(i);
            let mut acc = 0.0f64;
            for j in 0..i {
                let kij = self.kernel.eval_f32(xi, self.row(j));
                acc += kij * beta[j];
                y[j] += kij * beta[i];
            }
            y[i] += acc;
        }
        y
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; queries.len() / self.d];
        self.predict_into_impl(queries, beta, &mut out);
        out
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        assert_eq!(beta.len(), self.n);
        Box::new(ExactPredictor { op: self, beta: beta.to_vec() })
    }

    fn diag(&self) -> Option<Vec<f64>> {
        // Stationary kernels: K_ii = k(0) for every row.
        Some(vec![self.kernel.diag(); self.n])
    }

    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        assert_eq!(query.len(), self.d, "query must have d features");
        let v = (0..self.n)
            .map(|j| self.kernel.eval_f32(query, self.row(j)))
            .collect();
        Some((self.kernel.diag(), v))
    }

    fn name(&self) -> String {
        format!("exact({})", self.kernel.name())
    }

    fn memory_bytes(&self) -> usize {
        self.x.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::new(1, 0);
        let (n, d) = (20, 3);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        for kernel in [
            Kernel::laplace(1.0),
            Kernel::squared_exp(1.3),
            Kernel::matern52(0.8),
        ] {
            let op = ExactKernelOp::new(&x, n, d, kernel.clone());
            let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = op.matvec(&beta);
            for i in 0..n {
                let want: f64 = (0..n)
                    .map(|j| kernel.eval_f32(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]) * beta[j])
                    .sum();
                assert!(
                    (y[i] - want).abs() < 1e-10 * (1.0 + want.abs()),
                    "{} row {i}",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn predict_on_train_is_matvec() {
        let mut rng = Pcg64::new(2, 0);
        let (n, d) = (15, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let op = ExactKernelOp::new(&x, n, d, Kernel::matern52(1.0));
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = op.matvec(&beta);
        let p = op.predict(&x, &beta);
        for i in 0..n {
            assert!((y[i] - p[i]).abs() < 1e-9);
        }
    }
}
