//! The WLSH estimator sketch — the paper's core contribution.
//!
//! K̃ = (1/m) Σ_s D_s a_s a_sᵀ D_s where instance s hashes every point into
//! a bucket (Def. 5), D_s holds the f^{⊗d} weights (Def. 6), and a_s is the
//! bucket indicator. Lemma 27: O(dn) preprocessing, O(n) memory, O(n)
//! mat-vec per instance via bucket loads:
//!
//!   B_j(β) = Σ_{i: h(x_i)=j} w_i β_i,      (K̃β)_i = w_i · B_{h(x_i)}(β).
//!
//! The bucket loads are accumulated over the table's flat CSR arrays
//! ([`BucketTable::members`] plus the instance's CSR-aligned
//! `weights_csr`), so the load pass walks two contiguous arrays instead of
//! scattering into a random bucket slot per point (cf. Wu et al.,
//! "Revisiting Random Binning Features", KDD 2018). The mat-vec fuses a
//! fixed-size block of instances into each thread task
//! ([`WlshSketch::matvec_threads`]), and reductions happen in fixed block
//! order so every result is bit-identical to the serial path for every
//! thread count. The pre-CSR instance-at-a-time path is kept as
//! [`WlshSketch::matvec_unfused`] for benchmarking and cross-checking.

use std::sync::{Arc, OnceLock};

use super::{KrrOperator, Predictor};
use crate::api::{BucketSpec, KrrError, SamplingSpec};
use crate::data::{Chunk, DataSource, MatrixSource, SparseChunk};
use crate::linalg::lanczos::lanczos_quadform_inv;
use crate::lsh::{
    BucketTable, BucketTableBuilder, IdMode, LshFamily, LshFunction, SparseHashPlan,
};
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::simd;

/// Query batches at or below this size are predicted serially; larger
/// batches split into chunks of this many rows for the thread fan-out.
/// Shared with the coordinator's router so sharding never nests two levels
/// of parallelism.
pub(crate) const SERIAL_QUERY_CHUNK: usize = 256;

/// Below this many scatter ops (n·m) the automatic-thread paths stay
/// serial: a mat-vec this small runs in well under a millisecond, so
/// per-call thread spawns would dominate. Explicit `*_threads` calls are
/// never gated — the caller decides.
const PAR_MIN_WORK: usize = 1 << 17;

/// Row floor for the automatic paths: the fused mat-vec spawns threads
/// once per `FUSE_BLOCK · PAR_ROUND` = 256-instance reduction round, so a
/// round carries ≥ 256·n scatter ops and n only needs to clear a small
/// floor for the spawn/join cost to amortize (the pre-fusion path spawned
/// once per 32 instances and needed n ≥ 2048).
const PAR_MIN_ROWS: usize = 256;

/// Instances fused into one thread task of the mat-vec. Fixed (never
/// derived from the thread count) so the block decomposition — and hence
/// the floating-point reduction order — is machine-independent.
const FUSE_BLOCK: usize = 8;

/// Blocks buffered per reduction round of the fused mat-vec: peak extra
/// memory is `PAR_ROUND · n` f64s regardless of m, and round boundaries
/// fall at fixed block indices so they never affect the result.
const PAR_ROUND: usize = 32;

/// One hashed instance: the function, its dense CSR bucket table, the
/// per-point weights, and the same weights permuted into CSR member order.
#[derive(Clone)]
pub struct WlshInstance {
    pub func: LshFunction,
    pub table: BucketTable,
    /// f^{⊗d} weight of each point, in point order.
    pub weights: Vec<f32>,
    /// `weights` permuted into [`BucketTable::members`] order, so the
    /// bucket-load pass reads weights and member ids from two contiguous
    /// arrays.
    pub weights_csr: Vec<f32>,
    /// Importance weight of this instance in the averaged estimator:
    /// K̃ = (1/m′) Σ_s iweight_s · D_s a_s a_sᵀ D_s. Uniform sampling
    /// leaves every instance at exactly 1.0, and multiplying by 1.0 is
    /// bit-exact — the uniform paths are unchanged to the last bit.
    pub iweight: f64,
}

impl WlshInstance {
    /// Assemble an instance, deriving the CSR-aligned weight array.
    pub fn new(func: LshFunction, table: BucketTable, weights: Vec<f32>) -> WlshInstance {
        let weights_csr = table.members.iter().map(|&i| weights[i as usize]).collect();
        WlshInstance { func, table, weights, weights_csr, iweight: 1.0 }
    }

    /// Set the instance's importance weight.
    pub fn with_iweight(mut self, iweight: f64) -> WlshInstance {
        self.iweight = iweight;
        self
    }
}

/// Per-instance accumulator of the streaming build: the sampled hash
/// function, the incremental bucket renumbering, and the weights gathered
/// so far. Advanced one shared chunk at a time (instances are mutually
/// independent, so accumulators thread freely without affecting results).
struct InstanceAccum {
    func: LshFunction,
    builder: BucketTableBuilder,
    weights: Vec<f32>,
    /// Importance weight carried into the finished instance (1.0 for
    /// uniform builds; the stored keep-weight for selected builds).
    iweight: f64,
    /// Reused per-chunk scratch (raw ids / weights of the current chunk).
    ids_buf: Vec<u64>,
    w_buf: Vec<f32>,
    /// Sparse hash plan (batch arithmetic), built lazily on the first
    /// sparse chunk so dense-only builds pay nothing.
    plan: Option<SparseHashPlan>,
    done: Option<WlshInstance>,
}

/// Typed parameter set for every WLSH sketch construction path — the
/// single front door that replaced the positional
/// `build/build_spec/build_spec_mode/build_source/build_source_range`
/// constructor zoo. Start from [`WlshBuildParams::new`] and chain the
/// setters for everything that differs from the defaults.
#[derive(Clone, Debug)]
pub struct WlshBuildParams {
    /// Expected row count (a capacity hint for streaming builds; the
    /// in-memory [`WlshSketch::build_mem`] asserts `x.len() == n·d`).
    pub n: usize,
    /// Feature dimension (must match the data source's).
    pub d: usize,
    /// Instance budget m — the pool size that [`sampling`](Self::sampling)
    /// selects from (uniform keeps all m).
    pub m: usize,
    pub bucket: BucketSpec,
    pub gamma_shape: f64,
    /// Kernel bandwidth (> 0).
    pub scale: f64,
    pub seed: u64,
    pub id_mode: IdMode,
    /// How instances are selected/weighted out of the m-instance pool.
    pub sampling: SamplingSpec,
    /// Rows per streamed chunk (≥ 1; bit-transparent to the result).
    pub chunk_rows: usize,
    /// Build worker threads (bit-transparent to the result).
    pub workers: usize,
    /// Ridge λ of the downstream solve — regularizes the pilot operator
    /// of the leverage-score quadrature. Unused by uniform sampling.
    pub lambda: f64,
}

impl WlshBuildParams {
    /// Defaults: rect bucket, Gamma shape 2, scale 1, seed 42, `U64` ids,
    /// uniform sampling, whole-matrix chunks, one worker, λ = 0.5.
    pub fn new(n: usize, d: usize, m: usize) -> WlshBuildParams {
        WlshBuildParams {
            n,
            d,
            m,
            bucket: BucketSpec::Rect,
            gamma_shape: 2.0,
            scale: 1.0,
            seed: 42,
            id_mode: IdMode::U64,
            sampling: SamplingSpec::Uniform,
            chunk_rows: n.max(1),
            workers: 1,
            lambda: 0.5,
        }
    }

    /// Derive the trainer's build parameters from a [`KrrConfig`]:
    /// `budget` → m, plus bucket/shape/scale/seed, the sampling spec, the
    /// ridge λ (which regularizes the leverage pilot), and the streaming
    /// knobs. `n` is the row-count hint; `d` the feature dimension.
    pub fn from_config(c: &crate::config::KrrConfig, n: usize, d: usize) -> WlshBuildParams {
        WlshBuildParams::new(n, d, c.budget)
            .bucket(c.bucket)
            .gamma_shape(c.gamma_shape)
            .scale(c.scale)
            .seed(c.seed)
            .sampling(c.sampling)
            .chunk_rows(c.chunk_rows)
            .workers(c.workers)
            .lambda(c.lambda)
    }

    pub fn bucket(mut self, bucket: BucketSpec) -> Self {
        self.bucket = bucket;
        self
    }

    /// Bucket by its string name, panicking on an unknown name — a
    /// test/bench convenience mirroring the old string-typed constructors
    /// (typed callers should parse a [`BucketSpec`] and use
    /// [`bucket`](Self::bucket)).
    pub fn bucket_str(self, bucket: &str) -> Self {
        match bucket.parse() {
            Ok(b) => self.bucket(b),
            Err(e) => panic!("{e}"),
        }
    }

    pub fn gamma_shape(mut self, gamma_shape: f64) -> Self {
        self.gamma_shape = gamma_shape;
        self
    }

    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn id_mode(mut self, id_mode: IdMode) -> Self {
        self.id_mode = id_mode;
        self
    }

    pub fn sampling(mut self, sampling: SamplingSpec) -> Self {
        self.sampling = sampling;
        self
    }

    pub fn chunk_rows(mut self, chunk_rows: usize) -> Self {
        self.chunk_rows = chunk_rows.max(1);
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }
}

/// Importance-sampling provenance of a sketch built with a non-uniform
/// [`SamplingSpec`]: which pool the kept instances came from and their
/// exact weights — round-tripped verbatim through checkpoint headers so a
/// reload replays the selection instead of recomputing it.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingInfo {
    /// Instance-pool size the kept instances were drawn from.
    pub pool_m: usize,
    /// Kept `(pool index, importance weight)` pairs, ascending by index.
    pub kept: Vec<(usize, f64)>,
}

/// The averaged m-instance WLSH sketch of the training set.
///
/// Memory is O(n) per instance (Lemma 27) — the sketch never retains the
/// n×d training matrix: every constructor funnels through the chunked
/// [`build_source`](Self::build_source) assembly, which only ever holds
/// one O(chunk·d) block of (scaled) rows at a time.
///
/// `Clone` supports the online-update path's copy-on-write
/// (`Arc::make_mut`): models already serving the old sketch keep it,
/// while the online trainer appends into its private copy.
#[derive(Clone)]
pub struct WlshSketch {
    pub instances: Vec<WlshInstance>,
    pub family: LshFamily,
    pub mode: IdMode,
    n: usize,
    /// Kernel bandwidth: data is divided by `scale` before hashing, so the
    /// sketch estimates k_{f,p}((x-y)/scale).
    pub scale: f64,
    /// `Some` when the instances were importance-sampled out of a larger
    /// pool (leverage/stein); `None` for uniform builds.
    pub sampling_info: Option<SamplingInfo>,
}

impl WlshSketch {
    /// The fused-mat-vec block size, re-exported for the shard topology
    /// layer: distributed instance ranges must cut on block boundaries so
    /// the coordinator's partial reduction replays
    /// [`matvec_threads`](Self::matvec_threads)'s block order exactly.
    pub const FUSE_BLOCK: usize = FUSE_BLOCK;

    /// Build a sketch from a typed parameter set — THE constructor; every
    /// other entry point (including the deprecated positional shims) is a
    /// thin wrapper over this one.
    ///
    /// Uniform sampling keeps all `params.m` instances at unit weight —
    /// bit-identical to every pre-params build. `leverage(pilot=P,keep=K)`
    /// builds the full m-instance pool, scores each instance's ridge
    /// leverage against a P-instance pilot operator by Lanczos quadrature
    /// (deterministic probe; see [`Self::leverage_select`]), keeps the
    /// top-K, and reweights them trace-preservingly. `stein` keeps all m
    /// with mean-1 leverage-proportional weights. All three are
    /// deterministic in `(params, data)` at every thread/chunk count.
    pub fn build(params: &WlshBuildParams, src: &dyn DataSource) -> Result<WlshSketch, KrrError> {
        match params.sampling {
            SamplingSpec::Uniform => {
                let sel: Vec<(usize, f64)> = (0..params.m).map(|s| (s, 1.0)).collect();
                Self::build_selected_impl(params, src, params.m, &sel, None)
            }
            SamplingSpec::Leverage { pilot, keep } => {
                let sel: Vec<(usize, f64)> = (0..params.m).map(|s| (s, 1.0)).collect();
                let mut pool = Self::build_selected_impl(params, src, params.m, &sel, None)?;
                let kept = Self::leverage_select(&pool, pilot, keep, params.lambda, params.seed);
                let mut slots: Vec<Option<WlshInstance>> =
                    std::mem::take(&mut pool.instances).into_iter().map(Some).collect();
                pool.instances = kept
                    .iter()
                    .map(|&(s, w)| {
                        slots[s].take().expect("kept indices are distinct").with_iweight(w)
                    })
                    .collect();
                pool.sampling_info = Some(SamplingInfo { pool_m: params.m, kept });
                Ok(pool)
            }
            SamplingSpec::Stein => {
                let sel: Vec<(usize, f64)> = (0..params.m).map(|s| (s, 1.0)).collect();
                let mut pool = Self::build_selected_impl(params, src, params.m, &sel, None)?;
                let m = pool.m();
                let tau = Self::leverage_scores(&pool, m, params.lambda, params.seed);
                let total: f64 = tau.iter().sum();
                let weights: Vec<f64> = if total > 0.0 && total.is_finite() {
                    tau.iter().map(|t| m as f64 * t / total).collect()
                } else {
                    vec![1.0; m]
                };
                for (inst, &w) in pool.instances.iter_mut().zip(&weights) {
                    inst.iweight = w;
                }
                pool.sampling_info = Some(SamplingInfo {
                    pool_m: m,
                    kept: weights.iter().copied().enumerate().collect(),
                });
                Ok(pool)
            }
        }
    }

    /// In-memory convenience over [`build`](Self::build): wraps the slice
    /// in a [`MatrixSource`] and panics on failure (in-memory builds only
    /// fail on programmer error). Asserts `x.len() == params.n · params.d`.
    pub fn build_mem(x: &[f32], params: &WlshBuildParams) -> WlshSketch {
        assert_eq!(x.len(), params.n * params.d);
        let src = MatrixSource::new("mem", x, params.d);
        Self::build(params, &src).expect("in-memory WLSH build cannot fail")
    }

    /// Build only instances `[lo, hi)` of a uniformly sampled
    /// `params.m`-instance sketch — the shard worker's constructor.
    /// Instance `s`'s hash function is sampled from the `s`-th fork of the
    /// seed RNG, and forking advances the parent state, so the range build
    /// replays every fork below `hi` and samples only the owned ones: the
    /// produced instances are *bit-identical* to instances `[lo, hi)` of
    /// the full build.
    ///
    /// The returned sketch's `m()` is the local count `hi - lo`, so its
    /// trait `matvec`/`predict` normalize by the *local* instance count —
    /// distributed callers must use the raw partial kernels
    /// ([`block_partials`](Self::block_partials),
    /// [`predict_terms`](Self::predict_terms)) and let the coordinator
    /// apply `1/m_total` once.
    pub fn build_range(
        params: &WlshBuildParams,
        src: &dyn DataSource,
        lo: usize,
        hi: usize,
    ) -> Result<WlshSketch, KrrError> {
        assert!(
            lo <= hi && hi <= params.m,
            "instance range [{lo}, {hi}) out of bounds for m_total={}",
            params.m
        );
        let sel: Vec<(usize, f64)> = (lo..hi).map(|s| (s, 1.0)).collect();
        Self::build_selected_impl(params, src, params.m, &sel, None)
    }

    /// Build exactly the listed `(pool index, importance weight)`
    /// instances of a `pool_m`-instance pool — the checkpoint-restore and
    /// leverage-shard constructor. The fork-replay discipline makes each
    /// produced instance bit-identical to the same pool index of the full
    /// build, and the weights are applied verbatim (never recomputed), so
    /// a reload of a stored keep list reproduces the saved model exactly.
    /// `keep` must be ascending and within the pool.
    pub fn build_selected(
        params: &WlshBuildParams,
        src: &dyn DataSource,
        pool_m: usize,
        keep: &[(usize, f64)],
    ) -> Result<WlshSketch, KrrError> {
        for pair in keep.windows(2) {
            if pair[1].0 <= pair[0].0 {
                return Err(KrrError::BadParam(format!(
                    "kept instance indices must be strictly ascending, got {} after {}",
                    pair[1].0, pair[0].0
                )));
            }
        }
        if let Some(&(last, _)) = keep.last() {
            if last >= pool_m {
                return Err(KrrError::BadParam(format!(
                    "kept instance index {last} out of bounds for pool_m={pool_m}"
                )));
            }
        }
        let info = SamplingInfo { pool_m, kept: keep.to_vec() };
        Self::build_selected_impl(params, src, pool_m, keep, Some(info))
    }

    /// The one streaming assembly path: one pass over a re-iterable
    /// chunked source, holding O(chunk·d) scaled rows plus the growing
    /// O(n·m′) sketch — never the n×d matrix. Instance `s` of the
    /// `pool_m`-instance pool is materialized iff it appears in `selected`
    /// (ascending `(index, iweight)` pairs); every fork below the last
    /// selected index is replayed so each materialized instance is
    /// bit-identical to the full build's. Each chunk is hashed under all
    /// selected instances (accumulators fanned out over `workers` threads
    /// via [`par::fan_out_mut`]), raw ids feed the incremental
    /// [`BucketTableBuilder`] renumbering, and tables finish with the same
    /// counting sort as the in-memory constructor — so the result is
    /// bit-identical for every chunk size and worker count (asserted by
    /// `tests/stream_equivalence.rs`).
    ///
    /// Sparse sources stay sparse: CSR chunks are hashed through
    /// [`LshFunction::hash_sparse`] in O(nnz) per rect row (O(d) with a
    /// smooth bucket, for the weight product), and the sparse ids/weights
    /// are bit-identical to hashing the densified rows — so the whole
    /// equivalence above carries over to sparse streams unchanged.
    fn build_selected_impl(
        params: &WlshBuildParams,
        src: &dyn DataSource,
        pool_m: usize,
        selected: &[(usize, f64)],
        sampling_info: Option<SamplingInfo>,
    ) -> Result<WlshSketch, KrrError> {
        let mode = params.id_mode;
        let chunk_rows = params.chunk_rows.max(1);
        let workers = params.workers.max(1);
        let d = src.dim();
        let mut rng = Pcg64::new(params.seed, 0);
        let family = LshFamily::new(d, params.gamma_shape, &params.bucket, &mut rng);
        let n_hint = src.len_hint().unwrap_or(0);
        // Sample the selected instances' hash functions up front, in pool
        // order from per-instance RNG forks — the exact draw sequence of
        // the full build (each fork advances the parent, so forks of
        // unselected indices are drawn and discarded).
        let replay_hi = selected.last().map_or(0, |&(s, _)| s + 1).min(pool_m);
        let mut accums: Vec<InstanceAccum> = Vec::with_capacity(selected.len());
        let mut next = 0usize;
        for s in 0..replay_hi {
            let mut irng = rng.fork(s as u64);
            if next < selected.len() && selected[next].0 == s {
                accums.push(InstanceAccum {
                    func: family.sample(&mut irng),
                    builder: BucketTableBuilder::with_capacity(n_hint),
                    weights: Vec::with_capacity(n_hint),
                    iweight: selected[next].1,
                    ids_buf: Vec::new(),
                    w_buf: Vec::new(),
                    plan: None,
                    done: None,
                });
                next += 1;
            }
        }
        let inv = (1.0 / params.scale) as f32;
        let mut x_buf: Vec<f32> = Vec::new();
        let mut v_buf: Vec<f32> = Vec::new();
        let mut n = 0usize;
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            n += ys.len();
            // Bandwidth-scale the chunk into reused buffers, keeping its
            // representation: dense rows scale in place; sparse chunks
            // scale only the stored values (0 · inv = 0, so the implicit
            // zeros need no work). The I32 id collapse has no sparse hash
            // kernel, so sparse chunks densify there — a fallback, not the
            // streaming path (HLO mode is a compatibility mode).
            let scaled: Chunk<'_> = match chunk {
                Chunk::Dense(rows) => {
                    x_buf.clear();
                    x_buf.extend(rows.iter().map(|&v| v * inv));
                    Chunk::Dense(&x_buf)
                }
                Chunk::Sparse(sp) if mode == IdMode::U64 => {
                    v_buf.clear();
                    v_buf.extend(sp.values.iter().map(|&v| v * inv));
                    Chunk::Sparse(SparseChunk {
                        indptr: sp.indptr,
                        indices: sp.indices,
                        values: &v_buf,
                    })
                }
                Chunk::Sparse(sp) => {
                    sp.densify_into(d, &mut x_buf);
                    for v in x_buf.iter_mut() {
                        *v *= inv;
                    }
                    Chunk::Dense(&x_buf)
                }
            };
            par::fan_out_mut(&mut accums, workers, |_, acc| {
                acc.ids_buf.clear();
                acc.w_buf.clear();
                match &scaled {
                    Chunk::Dense(rows) => {
                        acc.func
                            .hash_batch(rows, &family, mode, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                    Chunk::Sparse(sp) => {
                        if acc.plan.is_none() {
                            acc.plan = Some(acc.func.sparse_plan(&family));
                        }
                        let plan = acc.plan.as_ref().expect("plan just built");
                        acc.func
                            .hash_sparse(sp, plan, &family, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                }
                for &id in &acc.ids_buf {
                    acc.builder.push(id);
                }
                acc.weights.extend_from_slice(&acc.w_buf);
            });
            Ok(())
        })?;
        par::fan_out_mut(&mut accums, workers, |_, acc| {
            let table = std::mem::take(&mut acc.builder).finish();
            let weights = std::mem::take(&mut acc.weights);
            acc.done = Some(
                WlshInstance::new(acc.func.clone(), table, weights).with_iweight(acc.iweight),
            );
        });
        let instances = accums
            .into_iter()
            .map(|a| a.done.expect("instance finalized"))
            .collect();
        Ok(WlshSketch { instances, family, mode, n, scale: params.scale, sampling_info })
    }

    /// Mat-vec of the pilot operator (1/p)·Σ_{s<p} iweight_s·T_s — the
    /// prefix sub-estimator the leverage quadrature inverts. Serial and
    /// fixed-order, so scores are machine-independent.
    fn matvec_prefix(&self, p: usize, beta: &[f64]) -> Vec<f64> {
        let p = p.min(self.m()).max(1);
        let mut out = self.block_contrib(&self.instances[..p], beta);
        let inv_p = 1.0 / p as f64;
        for v in out.iter_mut() {
            *v *= inv_p;
        }
        out
    }

    /// Ridge-leverage proxy of every pool instance: with a deterministic
    /// Gaussian probe g (seeded from `seed`, decorrelated from the
    /// instance-sampling stream), instance s scores
    /// τ_s = yᵀ(K_pilot + λI)⁻¹y with y = T_s·g, estimated by
    /// `k`-step Gauss–Lanczos quadrature
    /// ([`lanczos_quadform_inv`]) against the `pilot`-instance prefix
    /// operator. Instances whose one-dimensional range aligns with
    /// directions the pilot operator (and hence its siblings) already
    /// covers score low; directions the pool under-covers score high.
    /// Non-finite or non-positive estimates clamp to 0. Fully
    /// deterministic: no RNG is drawn inside the quadrature, and the probe
    /// depends only on `(seed, n)`.
    fn leverage_scores(&self, pilot: usize, lambda: f64, seed: u64) -> Vec<f64> {
        /// Lanczos steps per score — enough for the quadrature to settle
        /// on the pilot operator's coarse spectrum (the scores only rank).
        const QUAD_RANK: usize = 16;
        let n = self.n;
        if n == 0 {
            return vec![0.0; self.m()];
        }
        let mut prng = Pcg64::new(seed.wrapping_add(0x9e37_79b9_7f4a_7c15), 1);
        let g: Vec<f64> = (0..n).map(|_| prng.normal()).collect();
        // λ = 0 would make a rank-deficient pilot operator singular; the
        // floor only affects the scores' scale, not the ranking.
        let lam = lambda.max(1e-9);
        self.instances
            .iter()
            .map(|inst| {
                let y = self.instance_contrib(inst, &g);
                let q = lanczos_quadform_inv(n, QUAD_RANK, &y, |v| {
                    let mut out = self.matvec_prefix(pilot, v);
                    for (o, x) in out.iter_mut().zip(v) {
                        *o += lam * *x;
                    }
                    out
                });
                if q.value.is_finite() && q.value > 0.0 {
                    q.value
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Deterministic leverage selection over a built pool: score every
    /// instance ([`leverage_scores`](Self::leverage_scores)), keep the
    /// top-`keep` (ties broken by the lower pool index), and give every
    /// kept instance the common trace-preserving weight
    /// c = (K·tr_pool)/(m·tr_kept) with tr(T_s) = Σ_i w_{s,i}² — so
    /// tr((1/K)·Σ_kept c·T_s) = tr((1/m)·Σ_pool T_s) exactly and the kept
    /// sub-estimator's diagonal mass matches the full pool's. An all-zero
    /// score vector (degenerate probe) falls back to keeping the first K
    /// instances. Returns ascending `(pool index, weight)` pairs.
    fn leverage_select(
        pool: &WlshSketch,
        pilot: usize,
        keep: usize,
        lambda: f64,
        seed: u64,
    ) -> Vec<(usize, f64)> {
        let m = pool.m();
        if m == 0 {
            return Vec::new();
        }
        let keep = keep.min(m).max(1);
        let mut tau = pool.leverage_scores(pilot, lambda, seed);
        if tau.iter().all(|&t| t == 0.0) {
            tau = vec![1.0; m];
        }
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            tau[b]
                .partial_cmp(&tau[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut kept: Vec<usize> = order[..keep].to_vec();
        kept.sort_unstable();
        let trace = |s: usize| {
            pool.instances[s]
                .weights
                .iter()
                .map(|&w| w as f64 * w as f64)
                .sum::<f64>()
        };
        let tr_total: f64 = (0..m).map(trace).sum();
        let tr_kept: f64 = kept.iter().map(|&s| trace(s)).sum();
        let c = if tr_kept > 0.0 && tr_total.is_finite() && tr_total > 0.0 {
            (keep as f64 * tr_total) / (m as f64 * tr_kept)
        } else {
            1.0
        };
        kept.into_iter().map(|s| (s, c)).collect()
    }

    /// Hash additional rows into the existing sketch — the online-update
    /// path. Every instance keeps its already-sampled hash function (no RNG
    /// is consumed), its finished bucket table reopens as a
    /// [`BucketTableBuilder`] positioned exactly where the original build
    /// stopped, and the appended chunks run through the same scale /
    /// hash / push / counting-sort pipeline as
    /// [`build_source`](Self::build_source) — so the appended sketch is
    /// **bit-identical** to a from-scratch build over the concatenated
    /// data, at every chunk size and worker count
    /// (`tests/online_equivalence.rs`). Returns the number of rows
    /// appended.
    pub fn append_source(
        &mut self,
        src: &dyn DataSource,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<usize, KrrError> {
        let d = self.family.d;
        if src.dim() != d {
            return Err(KrrError::Dataset(format!(
                "append expects {d} features per row, got {}",
                src.dim()
            )));
        }
        let family = self.family.clone();
        let mode = self.mode;
        // Reopen every instance as a mid-build accumulator: the finished
        // table's renumbering map + per-point indices ARE the builder
        // state after the original rows.
        let mut accums: Vec<InstanceAccum> = std::mem::take(&mut self.instances)
            .into_iter()
            .map(|inst| InstanceAccum {
                func: inst.func,
                builder: inst.table.into_builder(),
                weights: inst.weights,
                iweight: inst.iweight,
                ids_buf: Vec::new(),
                w_buf: Vec::new(),
                plan: None,
                done: None,
            })
            .collect();
        let inv = (1.0 / self.scale) as f32;
        let mut x_buf: Vec<f32> = Vec::new();
        let mut v_buf: Vec<f32> = Vec::new();
        let mut appended = 0usize;
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            appended += ys.len();
            let scaled: Chunk<'_> = match chunk {
                Chunk::Dense(rows) => {
                    x_buf.clear();
                    x_buf.extend(rows.iter().map(|&v| v * inv));
                    Chunk::Dense(&x_buf)
                }
                Chunk::Sparse(sp) if mode == IdMode::U64 => {
                    v_buf.clear();
                    v_buf.extend(sp.values.iter().map(|&v| v * inv));
                    Chunk::Sparse(SparseChunk {
                        indptr: sp.indptr,
                        indices: sp.indices,
                        values: &v_buf,
                    })
                }
                Chunk::Sparse(sp) => {
                    sp.densify_into(d, &mut x_buf);
                    for v in x_buf.iter_mut() {
                        *v *= inv;
                    }
                    Chunk::Dense(&x_buf)
                }
            };
            par::fan_out_mut(&mut accums, workers, |_, acc| {
                acc.ids_buf.clear();
                acc.w_buf.clear();
                match &scaled {
                    Chunk::Dense(rows) => {
                        acc.func
                            .hash_batch(rows, &family, mode, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                    Chunk::Sparse(sp) => {
                        if acc.plan.is_none() {
                            acc.plan = Some(acc.func.sparse_plan(&family));
                        }
                        let plan = acc.plan.as_ref().expect("plan just built");
                        acc.func
                            .hash_sparse(sp, plan, &family, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                }
                for &id in &acc.ids_buf {
                    acc.builder.push(id);
                }
                acc.weights.extend_from_slice(&acc.w_buf);
            });
            Ok(())
        })?;
        par::fan_out_mut(&mut accums, workers, |_, acc| {
            let table = std::mem::take(&mut acc.builder).finish();
            let weights = std::mem::take(&mut acc.weights);
            acc.done = Some(
                WlshInstance::new(acc.func.clone(), table, weights).with_iweight(acc.iweight),
            );
        });
        self.instances = accums
            .into_iter()
            .map(|a| a.done.expect("instance finalized"))
            .collect();
        self.n += appended;
        Ok(appended)
    }

    pub fn m(&self) -> usize {
        self.instances.len()
    }

    /// Per-instance bucket loads for a coefficient vector (paper §4),
    /// accumulated over the CSR arrays: bucket j's load sums
    /// `weights_csr[k] · β[members[k]]` over its member range.
    ///
    /// Each bucket reduces in the fixed 4-lane-strided order of
    /// `util::simd::weighted_gather_sum` (lane j sums member indices ≡ j
    /// mod 4 within the bucket, then `tail + lane0..lane3`). The order
    /// depends only on the CSR layout — never on ISA, thread count, or
    /// chunking — so loads are bit-identical across `WLSH_SIMD=on|off`,
    /// worker counts, and streamed vs in-memory builds.
    fn loads(&self, inst: &WlshInstance, beta: &[f64]) -> Vec<f64> {
        let mut loads = vec![0.0f64; inst.table.n_buckets];
        Self::loads_into(inst, beta, &mut loads);
        loads
    }

    /// CSR bucket-load kernel writing into a caller-provided buffer
    /// (`loads.len() == inst.table.n_buckets`; every slot is overwritten).
    /// The instance's importance weight is folded into the loads — a
    /// single multiply per bucket that every loads consumer (fused
    /// mat-vec, predictors, sparse serve) then carries for free; uniform
    /// instances multiply by exactly 1.0, which is bit-exact.
    fn loads_into(inst: &WlshInstance, beta: &[f64], loads: &mut [f64]) {
        let offsets = &inst.table.offsets;
        let members = &inst.table.members;
        let w = &inst.weights_csr;
        let iw = inst.iweight;
        for (j, out) in loads.iter_mut().enumerate() {
            let lo = offsets[j] as usize;
            let hi = offsets[j + 1] as usize;
            *out = iw * simd::weighted_gather_sum(&w[lo..hi], &members[lo..hi], beta);
        }
    }

    /// Bucket loads for every instance, the per-instance work fanned out
    /// over `threads` worker threads. Instances are independent, so the
    /// result is identical (bitwise) to the serial instance loop for any
    /// thread count.
    pub fn loads_all(&self, beta: &[f64], threads: usize) -> Vec<Vec<f64>> {
        par::fan_out(self.m(), threads, |s| self.loads(&self.instances[s], beta))
    }

    /// Worker count for the automatic (trait) paths: all cores when the
    /// sketch is big enough to amortize thread spawns, else serial.
    fn auto_threads(&self) -> usize {
        if self.n < PAR_MIN_ROWS || self.n * self.m() < PAR_MIN_WORK {
            1
        } else {
            par::num_threads()
        }
    }

    /// Freeze the sketch + solved β into an O(m·d)-per-query predictor.
    /// The handle shares the sketch via `Arc`, so it outlives local
    /// borrows and can be moved into server threads.
    pub fn predictor(self: Arc<Self>, beta: &[f64]) -> WlshPredictor {
        let loads = self.loads_all(beta, self.auto_threads());
        WlshPredictor { sketch: self, loads, sparse_plans: OnceLock::new() }
    }

    /// Mean bucket count across instances (rank(K̃) proxy, Lemma 30's
    /// footnote: non-empty buckets grow sublinearly in n).
    pub fn mean_buckets(&self) -> f64 {
        self.instances
            .iter()
            .map(|i| i.table.n_buckets as f64)
            .sum::<f64>()
            / self.m() as f64
    }

    /// diag(K̃): every point collides with itself in every instance, so
    /// K̃_ii = (1/m) Σ_s w_{s,i}². O(n·m); feeds the solver's Jacobi
    /// preconditioner.
    pub fn diag_values(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        for inst in &self.instances {
            let iw = inst.iweight;
            for (o, &w) in out.iter_mut().zip(&inst.weights) {
                *o += iw * (w as f64 * w as f64);
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }

    /// Serial reference mat-vec: the fused block algorithm on one thread.
    /// [`matvec_threads`](Self::matvec_threads) is bit-identical to this
    /// for every thread count (asserted by
    /// `tests/parallel_determinism.rs`).
    pub fn matvec_serial(&self, beta: &[f64]) -> Vec<f64> {
        self.matvec_threads(beta, 1)
    }

    /// One fused block's additive contribution: for each instance in the
    /// block (in order), accumulate its CSR bucket loads into a reused
    /// buffer, then gather `c_i += w_i · B_{h(x_i)}` into the block's
    /// single output buffer. One O(n) buffer per block instead of one per
    /// instance.
    fn block_contrib(&self, block: &[WlshInstance], beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        let mut loads: Vec<f64> = Vec::new();
        for inst in block {
            loads.clear();
            loads.resize(inst.table.n_buckets, 0.0);
            Self::loads_into(inst, beta, &mut loads);
            simd::scaled_gather_add(&mut out, &inst.weights, &inst.table.bucket_of, &loads);
        }
        out
    }

    /// Fused parallel mat-vec: instances are grouped into fixed 8-instance
    /// blocks (`FUSE_BLOCK`), each thread task computes one block's
    /// contribution over the CSR arrays, and block partials are reduced in
    /// fixed block order (rounds of `PAR_ROUND` blocks bound peak
    /// memory). The decomposition depends only on m — never on `threads` —
    /// so the result is bit-identical to
    /// [`matvec_serial`](Self::matvec_serial) for every thread count. The
    /// requested `threads` is always honored (the work-size gate lives in
    /// the trait path only).
    pub fn matvec_threads(&self, beta: &[f64], threads: usize) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        let mut out = vec![0.0f64; self.n];
        for round in blocks.chunks(PAR_ROUND) {
            let partials =
                par::fan_out(round.len(), threads, |b| self.block_contrib(round[b], beta));
            for p in &partials {
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }

    /// Raw per-block mat-vec partials, in local block order: entry `b` is
    /// the un-normalized contribution of instance block `b`
    /// (`FUSE_BLOCK` instances each) — exactly the vectors
    /// [`matvec_threads`](Self::matvec_threads) reduces. The distributed
    /// solve ships these to the coordinator, which accumulates them in
    /// global block order and applies `1/m_total` once, reproducing the
    /// single-process mat-vec bit for bit (blocks are computed
    /// independently, so `threads` never affects the values).
    pub fn block_partials(&self, beta: &[f64], threads: usize) -> Vec<Vec<f64>> {
        assert_eq!(beta.len(), self.n);
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        par::fan_out(blocks.len(), threads, |b| self.block_contrib(blocks[b], beta))
    }

    /// Raw per-instance prediction terms for a row-major query batch: for
    /// query `q` and local instance `s`, `Some(w · B_{h(q)})` when `q`'s
    /// bucket is non-empty in instance `s`, else `None`. These are the
    /// exact addends of the serial predict kernel
    /// (`predict_query_range`), un-normalized; the coordinator
    /// concatenates shards in instance order, accumulates left-to-right
    /// skipping the `None`s, and applies `1/m_total` — bit-identical to
    /// the single-process prediction. (A miss must stay a skip, not a
    /// `0.0` addend: adding 0.0 can flip a `-0.0` accumulator to `+0.0`.)
    pub fn predict_terms(&self, loads: &[Vec<f64>], queries: &[f32]) -> Vec<Vec<Option<f64>>> {
        let d = self.family.d;
        let inv = (1.0 / self.scale) as f32;
        let nq = queries.len() / d;
        let mut q_scaled = vec![0.0f32; d];
        (0..nq)
            .map(|qi| {
                let q = &queries[qi * d..(qi + 1) * d];
                for (dst, src) in q_scaled.iter_mut().zip(q) {
                    *dst = *src * inv;
                }
                self.instances
                    .iter()
                    .zip(loads)
                    .map(|(inst, loads_s)| {
                        let (id, w) = inst.func.hash_point(&q_scaled, &self.family, self.mode);
                        inst.table.lookup(id).map(|b| w as f64 * loads_s[b as usize])
                    })
                    .collect()
            })
            .collect()
    }

    /// One fused block's un-normalized cross-covariance contribution for a
    /// pre-scaled query: `(Σ_s w_s(q)², Σ_s w_s(q)·w_s(x_i)·1[h_s(x_i)=h_s(q)])`
    /// over the block's instances, walking each matched bucket's CSR member
    /// range. Instances inside the block accumulate in order, mirroring
    /// [`block_contrib`](Self::block_contrib).
    fn cross_block_contrib(&self, block: &[WlshInstance], q_scaled: &[f32]) -> (f64, Vec<f64>) {
        let mut kxx = 0.0f64;
        let mut out = vec![0.0f64; self.n];
        for inst in block {
            let iw = inst.iweight;
            let (id, w) = inst.func.hash_point(q_scaled, &self.family, self.mode);
            kxx += iw * (w as f64 * w as f64);
            if let Some(b) = inst.table.lookup(id) {
                let lo = inst.table.offsets[b as usize] as usize;
                let hi = inst.table.offsets[b as usize + 1] as usize;
                for k in lo..hi {
                    out[inst.table.members[k] as usize] +=
                        iw * (w as f64 * inst.weights_csr[k] as f64);
                }
            }
        }
        (kxx, out)
    }

    /// Raw per-block cross-covariance partials for one query, in local
    /// block order: entry `b` is the un-normalized
    /// `(Σ w_s(q)², cross vector)` contribution of instance block `b` —
    /// the cross-vector analogue of [`block_partials`](Self::block_partials).
    /// Shard workers ship these to the coordinator, which reduces them in
    /// global block order and applies `1/m_total` once, reproducing the
    /// single-process [`cross_vector`](Self::cross_vector) bit for bit.
    pub fn cross_partials(&self, query: &[f32], threads: usize) -> Vec<(f64, Vec<f64>)> {
        let d = self.family.d;
        assert_eq!(query.len(), d, "query must have d features");
        let inv = (1.0 / self.scale) as f32;
        let q_scaled: Vec<f32> = query.iter().map(|&x| x * inv).collect();
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        par::fan_out(blocks.len(), threads, |b| {
            self.cross_block_contrib(blocks[b], &q_scaled)
        })
    }

    /// Cross-covariance of one query against the training set in the
    /// sketched geometry: `(k̃(q,q), k̃_q)` with
    /// k̃(q,q) = (1/m)·Σ_s w_s(q)² and
    /// (k̃_q)_i = (1/m)·Σ_s w_s(q)·w_s(x_i)·1[h_s(x_i)=h_s(q)] — O(m·d)
    /// hashing plus one walk over each matched bucket. Block partials are
    /// reduced in fixed block order, so the value is thread-count
    /// independent.
    pub fn cross_vector(&self, query: &[f32]) -> (f64, Vec<f64>) {
        let partials = self.cross_partials(query, self.auto_threads());
        let mut kxx = 0.0f64;
        let mut v = vec![0.0f64; self.n];
        for (kp, p) in &partials {
            kxx += kp;
            for (o, x) in v.iter_mut().zip(p) {
                *o += *x;
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for x in v.iter_mut() {
            *x *= inv_m;
        }
        (kxx * inv_m, v)
    }

    /// One instance's additive mat-vec contribution (the pre-fusion
    /// formulation: one O(n) buffer per instance).
    fn instance_contrib(&self, inst: &WlshInstance, beta: &[f64]) -> Vec<f64> {
        let loads = self.loads(inst, beta);
        let bucket_of = &inst.table.bucket_of;
        let weights = &inst.weights;
        let mut c = vec![0.0f64; self.n];
        for (i, cv) in c.iter_mut().enumerate() {
            *cv = weights[i] as f64 * loads[bucket_of[i] as usize];
        }
        c
    }

    /// The pre-fusion (PR-1) mat-vec: per-instance contribution vectors
    /// reduced in fixed instance order, 32 instances per round. Kept as the
    /// baseline `bench_matvec` compares the fused path against and as an
    /// independent cross-check (it computes the same per-instance terms,
    /// summed in per-instance rather than per-block grouping, so the two
    /// paths agree to floating-point reassociation error).
    pub fn matvec_unfused(&self, beta: &[f64], threads: usize) -> Vec<f64> {
        const ROUND: usize = 32;
        assert_eq!(beta.len(), self.n);
        let mut out = vec![0.0f64; self.n];
        if threads <= 1 || self.m() <= 1 {
            for inst in &self.instances {
                let loads = self.loads(inst, beta);
                let bucket_of = &inst.table.bucket_of;
                let weights = &inst.weights;
                for i in 0..self.n {
                    out[i] += weights[i] as f64 * loads[bucket_of[i] as usize];
                }
            }
        } else {
            for round in self.instances.chunks(ROUND) {
                let partials = par::fan_out(round.len(), threads, |s| {
                    self.instance_contrib(&round[s], beta)
                });
                for p in &partials {
                    for (o, v) in out.iter_mut().zip(p) {
                        *o += *v;
                    }
                }
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }
}

/// Deprecated positional constructors — thin shims over
/// [`WlshBuildParams`] kept for one release so out-of-tree callers get a
/// warning instead of a break. The in-repo caller count is zero (enforced
/// by `clippy -D warnings`). Note the old `build(x, n, d, m, ...)`
/// positional form is gone outright: the `build` name now takes a
/// [`WlshBuildParams`] (see the README migration table).
impl WlshSketch {
    /// Deprecated: use [`WlshSketch::build_mem`] with [`WlshBuildParams`].
    #[deprecated(note = "use WlshSketch::build_mem with WlshBuildParams")]
    #[allow(clippy::too_many_arguments)]
    pub fn build_spec(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
    ) -> WlshSketch {
        let params = WlshBuildParams::new(n, d, m)
            .bucket(*bucket)
            .gamma_shape(gamma_shape)
            .scale(scale)
            .seed(seed);
        Self::build_mem(x, &params)
    }

    /// Deprecated: use [`WlshSketch::build_mem`] with [`WlshBuildParams`].
    #[deprecated(note = "use WlshSketch::build_mem with WlshBuildParams")]
    #[allow(clippy::too_many_arguments)]
    pub fn build_mode(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
    ) -> WlshSketch {
        let params = WlshBuildParams::new(n, d, m)
            .bucket_str(bucket)
            .gamma_shape(gamma_shape)
            .scale(scale)
            .seed(seed)
            .id_mode(mode);
        Self::build_mem(x, &params)
    }

    /// Deprecated: use [`WlshSketch::build_mem`] with [`WlshBuildParams`].
    #[deprecated(note = "use WlshSketch::build_mem with WlshBuildParams")]
    #[allow(clippy::too_many_arguments)]
    pub fn build_spec_mode(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
    ) -> WlshSketch {
        let params = WlshBuildParams::new(n, d, m)
            .bucket(*bucket)
            .gamma_shape(gamma_shape)
            .scale(scale)
            .seed(seed)
            .id_mode(mode);
        Self::build_mem(x, &params)
    }

    /// Deprecated: use [`WlshSketch::build`] with [`WlshBuildParams`].
    #[deprecated(note = "use WlshSketch::build with WlshBuildParams")]
    #[allow(clippy::too_many_arguments)]
    pub fn build_source(
        src: &dyn DataSource,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<WlshSketch, KrrError> {
        let params = WlshBuildParams::new(src.len_hint().unwrap_or(0), src.dim(), m)
            .bucket(*bucket)
            .gamma_shape(gamma_shape)
            .scale(scale)
            .seed(seed)
            .id_mode(mode)
            .chunk_rows(chunk_rows)
            .workers(workers);
        Self::build(&params, src)
    }

    /// Deprecated: use [`WlshSketch::build_range`] with [`WlshBuildParams`].
    #[deprecated(note = "use WlshSketch::build_range with WlshBuildParams")]
    #[allow(clippy::too_many_arguments)]
    pub fn build_source_range(
        src: &dyn DataSource,
        m_total: usize,
        lo: usize,
        hi: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<WlshSketch, KrrError> {
        let params = WlshBuildParams::new(src.len_hint().unwrap_or(0), src.dim(), m_total)
            .bucket(*bucket)
            .gamma_shape(gamma_shape)
            .scale(scale)
            .seed(seed)
            .id_mode(mode)
            .chunk_rows(chunk_rows)
            .workers(workers);
        Self::build_range(&params, src, lo, hi)
    }
}

impl KrrOperator for WlshSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.matvec_threads(beta, self.auto_threads())
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let loads = self.loads_all(beta, self.auto_threads());
        self.predict_with_loads(&loads, queries, par::num_threads())
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        Box::new(WlshSketch::predictor(self, beta))
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some(self.diag_values())
    }

    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        Some(WlshSketch::cross_vector(self, query))
    }

    fn name(&self) -> String {
        format!(
            "wlsh(f={},shape={},m={})",
            self.family.bucket_spec,
            self.family.gamma_shape,
            self.m()
        )
    }

    fn memory_bytes(&self) -> usize {
        // O(n) words per instance and nothing else: the training matrix is
        // never retained (Lemma 27).
        self.instances
            .iter()
            .map(|i| i.table.memory_bytes() + i.weights.len() * 4 + i.weights_csr.len() * 4)
            .sum::<usize>()
    }

    fn sampling_header(&self) -> Option<&SamplingInfo> {
        self.sampling_info.as_ref()
    }
}

/// Serving-time predictor: per-instance bucket loads are precomputed from
/// the solved β, so a query costs O(m·d) — hash, lookup, multiply. Owns an
/// `Arc` of the sketch (hash functions + tables) and the load vectors; the
/// only state a prediction touches.
pub struct WlshPredictor {
    sketch: Arc<WlshSketch>,
    loads: Vec<Vec<f64>>,
    /// Per-instance sparse hash plans in *point* arithmetic (the query
    /// path divides by w where the batch path multiplies by 1/w — the two
    /// differ in f32, so each side carries its own plan). Built lazily on
    /// the first sparse query and shared across serve threads.
    sparse_plans: OnceLock<Vec<SparseHashPlan>>,
}

impl WlshPredictor {
    /// As [`Predictor::predict`] with an explicit worker-thread count
    /// (1 = the serial reference path).
    pub fn predict_threads(&self, queries: &[f32], threads: usize) -> Vec<f64> {
        self.sketch.predict_with_loads(&self.loads, queries, threads)
    }
}

impl Predictor for WlshPredictor {
    fn dim(&self) -> usize {
        self.sketch.family.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.sketch
            .predict_with_loads_into(&self.loads, queries, par::num_threads(), out);
    }

    fn predict(&self, queries: &[f32]) -> Vec<f64> {
        self.predict_threads(queries, par::num_threads())
    }

    /// Native sparse serve path: hash each CSR row with the point-arithmetic
    /// [`SparseHashPlan`]s — bit-identical to densifying the row and calling
    /// [`predict_into`](Predictor::predict_into), but O(nnz + d) per query
    /// with no scatter. I32/HLO mode has no sparse kernel and densifies
    /// row-by-row.
    fn predict_sparse_into(&self, queries: &SparseChunk<'_>, out: &mut [f64]) {
        let sk = &self.sketch;
        assert_eq!(out.len(), queries.nrows(), "one output slot per query row");
        if sk.mode != IdMode::U64 {
            let d = sk.family.d;
            let mut row = vec![0.0f32; d];
            for (i, o) in out.iter_mut().enumerate() {
                let (idx, vals) = queries.row(i);
                for v in row.iter_mut() {
                    *v = 0.0;
                }
                for (&j, &v) in idx.iter().zip(vals) {
                    row[j as usize] = v;
                }
                self.predict_into(&row, std::slice::from_mut(o));
            }
            return;
        }
        let plans = self.sparse_plans.get_or_init(|| {
            sk.instances
                .iter()
                .map(|inst| inst.func.sparse_plan_point(&sk.family))
                .collect()
        });
        let inv = (1.0 / sk.scale) as f32;
        let inv_m = 1.0 / sk.m() as f64;
        let mut vals_buf: Vec<f32> = Vec::new();
        for (i, o) in out.iter_mut().enumerate() {
            let (idx, vals) = queries.row(i);
            vals_buf.clear();
            vals_buf.extend(vals.iter().map(|&v| v * inv));
            let mut acc = 0.0f64;
            for ((inst, loads_s), plan) in sk.instances.iter().zip(&self.loads).zip(plans) {
                let (id, w) = inst.func.hash_sparse_row(idx, &vals_buf, plan, &sk.family);
                if let Some(b) = inst.table.lookup(id) {
                    acc += w as f64 * loads_s[b as usize];
                }
            }
            *o = acc * inv_m;
        }
    }
}

impl WlshSketch {
    /// Shared predict kernel: hash each query, look its bucket up in every
    /// instance, combine the precomputed loads (paper §4.2's η̃(x)).
    fn predict_with_loads(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        threads: usize,
    ) -> Vec<f64> {
        let d = self.family.d;
        let mut out = vec![0.0f64; queries.len() / d];
        self.predict_with_loads_into(loads, queries, threads, &mut out);
        out
    }

    /// As [`predict_with_loads`](Self::predict_with_loads), writing into a
    /// caller-provided buffer (one slot per query row) — the batch-serving
    /// path allocates nothing per call on the serial route.
    ///
    /// Queries are independent, so the batch is split into fixed-size
    /// chunks fanned out over `threads` workers; per-query arithmetic is
    /// untouched and results are reassembled in query order, keeping the
    /// output bit-identical to the serial loop for any thread count.
    fn predict_with_loads_into(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        threads: usize,
        out: &mut [f64],
    ) {
        // Chunk size is fixed (not derived from `threads`) so the work
        // decomposition never depends on the machine.
        let d = self.family.d;
        let nq = queries.len() / d;
        assert_eq!(out.len(), nq, "one output slot per query row");
        if threads <= 1 || nq <= SERIAL_QUERY_CHUNK {
            self.predict_query_range(loads, queries, 0, nq, out);
            return;
        }
        let n_chunks = nq.div_ceil(SERIAL_QUERY_CHUNK);
        let pieces = par::fan_out(n_chunks, threads, |c| {
            let lo = c * SERIAL_QUERY_CHUNK;
            let hi = ((c + 1) * SERIAL_QUERY_CHUNK).min(nq);
            let mut buf = vec![0.0f64; hi - lo];
            self.predict_query_range(loads, queries, lo, hi, &mut buf);
            buf
        });
        let mut off = 0;
        for p in pieces {
            out[off..off + p.len()].copy_from_slice(&p);
            off += p.len();
        }
    }

    /// Predict queries `lo..hi` of a row-major batch into `out` (the
    /// serial kernel; `out.len() == hi - lo`).
    fn predict_query_range(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        lo: usize,
        hi: usize,
        out: &mut [f64],
    ) {
        let d = self.family.d;
        let inv = (1.0 / self.scale) as f32;
        let inv_m = 1.0 / self.m() as f64;
        let mut q_scaled = vec![0.0f32; d];
        for (qi, o) in (lo..hi).zip(out.iter_mut()) {
            let q = &queries[qi * d..(qi + 1) * d];
            for (dst, src) in q_scaled.iter_mut().zip(q) {
                *dst = *src * inv;
            }
            let mut acc = 0.0f64;
            for (inst, loads_s) in self.instances.iter().zip(loads) {
                let (id, w) = inst.func.hash_point(&q_scaled, &self.family, self.mode);
                if let Some(b) = inst.table.lookup(id) {
                    acc += w as f64 * loads_s[b as usize];
                }
            }
            *o = acc * inv_m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::util::prop::{gens, prop_check};

    fn random_x(seed: u64, n: usize, d: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    /// Test shorthand over [`WlshSketch::build_mem`] — the positional shape
    /// every test below used before the params struct existed.
    #[allow(clippy::too_many_arguments)]
    fn build(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        shape: f64,
        scale: f64,
        seed: u64,
    ) -> WlshSketch {
        WlshSketch::build_mem(
            x,
            &WlshBuildParams::new(n, d, m)
                .bucket_str(bucket)
                .gamma_shape(shape)
                .scale(scale)
                .seed(seed),
        )
    }

    /// Materialize K̃ from mat-vecs against basis vectors.
    fn materialize(op: &dyn KrrOperator) -> Vec<Vec<f64>> {
        let n = op.n();
        (0..n)
            .map(|j| {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                op.matvec(&e)
            })
            .collect()
    }

    #[test]
    fn matvec_matches_materialized_definition() {
        // Def. 6 brute force: K̃_ij = (1/m) Σ_s w_i w_j [h_s(x_i) = h_s(x_j)]
        let (n, d, m) = (40, 3, 5);
        let x = random_x(1, n, d);
        let sk = build(&x, n, d, m, "smooth2", 7.0, 1.0, 2);
        let k = materialize(&sk);
        // brute force from the instances themselves
        for i in 0..n {
            for j in 0..n {
                let mut want = 0.0;
                for inst in &sk.instances {
                    if inst.table.bucket_of[i] == inst.table.bucket_of[j] {
                        want += inst.weights[i] as f64 * inst.weights[j] as f64;
                    }
                }
                want /= m as f64;
                assert!(
                    (k[j][i] - want).abs() < 1e-9,
                    "K[{i}][{j}] {} vs {want}",
                    k[j][i]
                );
            }
        }
    }

    #[test]
    fn sketch_is_symmetric_psd() {
        let (n, d, m) = (32, 4, 8);
        let x = random_x(3, n, d);
        let sk = build(&x, n, d, m, "rect", 2.0, 1.0, 4);
        let k = materialize(&sk);
        for i in 0..n {
            for j in 0..n {
                assert!((k[i][j] - k[j][i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // E[K̃_ij] = k_{f,p}(x_i - x_j): average many independent sketches.
        let d = 2;
        let x: Vec<f32> = vec![0.0, 0.0, 0.4, -0.3];
        let kern = Kernel::wlsh("rect", 2.0, 1.0);
        let want = kern.eval_f32(&x[0..2], &x[2..4]);
        let trials = 400;
        let mut acc = 0.0;
        let mut acc2 = 0.0;
        for t in 0..trials {
            let sk = build(&x, 2, d, 8, "rect", 2.0, 1.0, 1000 + t);
            let y = sk.matvec(&[0.0, 1.0]); // column j=1
            acc += y[0];
            acc2 += y[0] * y[0];
        }
        let mean = acc / trials as f64;
        let se = ((acc2 / trials as f64 - mean * mean) / trials as f64).sqrt();
        assert!(
            (mean - want).abs() < 4.0 * se + 5e-3,
            "mean {mean} vs {want} (se {se})"
        );
    }

    #[test]
    fn predictor_matches_trait_predict() {
        let (n, d, m) = (64, 5, 10);
        let x = random_x(5, n, d);
        let sk = Arc::new(build(&x, n, d, m, "smooth2", 7.0, 1.5, 6));
        let mut rng = Pcg64::new(7, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let q = random_x(8, 10, d);
        let a = sk.predict(&q, &beta);
        let b = sk.clone().predictor(&beta).predict(&q);
        assert_eq!(a, b);
    }

    #[test]
    fn predict_far_query_is_zero() {
        let (n, d) = (16, 2);
        let x = random_x(9, n, d);
        let sk = build(&x, n, d, 6, "rect", 2.0, 1.0, 10);
        let beta = vec![1.0; n];
        // a query 1e6 away shares no bucket with any training point
        let q = vec![1e6f32, -1e6];
        let y = sk.predict(&q, &beta);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn scale_changes_effective_kernel() {
        // wider scale ⇒ more collisions ⇒ larger quadratic form
        let (n, d) = (64, 3);
        let x = random_x(11, n, d);
        let beta = vec![1.0; n];
        let narrow = build(&x, n, d, 32, "rect", 2.0, 0.25, 12);
        let wide = build(&x, n, d, 32, "rect", 2.0, 4.0, 12);
        let qn: f64 = narrow.matvec(&beta).iter().sum();
        let qw: f64 = wide.matvec(&beta).iter().sum();
        assert!(qw > qn, "wide {qw} <= narrow {qn}");
    }

    #[test]
    fn parallel_matvec_and_predict_are_bit_identical() {
        let (n, d, m) = (300, 4, 64);
        let x = random_x(17, n, d);
        let sk = Arc::new(build(&x, n, d, m, "smooth2", 7.0, 1.0, 18));
        let mut rng = Pcg64::new(19, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let want = sk.matvec_serial(&beta);
        for threads in [1usize, 2, 8] {
            assert_eq!(sk.matvec_threads(&beta, threads), want, "threads={threads}");
        }
        let q = random_x(20, 600, d);
        let pred = sk.clone().predictor(&beta);
        let want_p = pred.predict_threads(&q, 1);
        for threads in [2usize, 8] {
            assert_eq!(pred.predict_threads(&q, threads), want_p, "threads={threads}");
        }
    }

    #[test]
    fn fused_matches_unfused_to_reassociation_error() {
        // Same per-instance terms, different summation grouping: the fused
        // block path and the pre-fusion instance path must agree to
        // floating-point reassociation error, at every thread count.
        let (n, d, m) = (257, 5, 77); // deliberately not multiples of block sizes
        let x = random_x(23, n, d);
        let sk = build(&x, n, d, m, "smooth2", 7.0, 1.0, 24);
        let mut rng = Pcg64::new(25, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let fused = sk.matvec_serial(&beta);
        for threads in [1usize, 2, 8] {
            let unfused = sk.matvec_unfused(&beta, threads);
            for i in 0..n {
                assert!(
                    (fused[i] - unfused[i]).abs() < 1e-11 * (1.0 + fused[i].abs()),
                    "row {i} (threads={threads}): fused {} vs unfused {}",
                    fused[i],
                    unfused[i]
                );
            }
        }
    }

    #[test]
    fn diag_matches_materialized_diagonal() {
        let (n, d, m) = (48, 3, 12);
        let x = random_x(29, n, d);
        let sk = build(&x, n, d, m, "smooth2", 7.0, 1.0, 30);
        let k = materialize(&sk);
        let diag = sk.diag_values();
        for i in 0..n {
            assert!(
                (diag[i] - k[i][i]).abs() < 1e-10 * (1.0 + k[i][i].abs()),
                "diag[{i}] {} vs K_ii {}",
                diag[i],
                k[i][i]
            );
        }
        // the trait accessor exposes the same values
        assert_eq!(KrrOperator::diag(&sk), Some(diag));
    }

    #[test]
    fn range_builds_reproduce_the_full_build_exactly() {
        // Shard constructor: instances [lo, hi) of a range build must be
        // bit-identical to the same slice of the full build, including at
        // non-block-aligned cuts.
        let (n, d, m) = (120, 4, 20);
        let x = random_x(31, n, d);
        let src = crate::data::MatrixSource::new("mem", &x, d);
        let full_params = WlshBuildParams::new(n, d, m)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .seed(32)
            .chunk_rows(50)
            .workers(2);
        let full = WlshSketch::build(&full_params, &src).unwrap();
        // different chunking/worker split on the shard side: still bit-exact
        let part_params = WlshBuildParams::new(n, d, m)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .seed(32)
            .chunk_rows(17)
            .workers(3);
        for (lo, hi) in [(0usize, 7usize), (7, 16), (16, 20), (0, 20), (8, 16)] {
            let part = WlshSketch::build_range(&part_params, &src, lo, hi).unwrap();
            assert_eq!(part.m(), hi - lo);
            for (k, inst) in part.instances.iter().enumerate() {
                let want = &full.instances[lo + k];
                assert_eq!(inst.weights, want.weights, "instance {} weights", lo + k);
                assert_eq!(
                    inst.table.bucket_of,
                    want.table.bucket_of,
                    "instance {} buckets",
                    lo + k
                );
            }
        }
    }

    #[test]
    fn block_partials_reassemble_into_the_exact_matvec() {
        // Coordinator-side reduction contract: accumulate the raw block
        // partials in global block order, then normalize once — must be
        // bit-identical to matvec_threads at any thread count.
        let (n, d, m) = (150, 3, 37); // m not a multiple of FUSE_BLOCK
        let x = random_x(33, n, d);
        let sk = build(&x, n, d, m, "smooth2", 7.0, 1.0, 34);
        let mut rng = Pcg64::new(35, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let want = sk.matvec_serial(&beta);
        for threads in [1usize, 3] {
            let partials = sk.block_partials(&beta, threads);
            assert_eq!(partials.len(), m.div_ceil(FUSE_BLOCK));
            let mut out = vec![0.0f64; n];
            for p in &partials {
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
            let inv_m = 1.0 / m as f64;
            for v in out.iter_mut() {
                *v *= inv_m;
            }
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn predict_terms_reassemble_into_the_exact_prediction() {
        let (n, d, m) = (90, 4, 11);
        let x = random_x(37, n, d);
        let sk = Arc::new(build(&x, n, d, m, "rect", 2.0, 1.0, 38));
        let mut rng = Pcg64::new(39, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // include a far query so at least one row has all-miss terms
        let mut q = random_x(40, 12, d);
        q[0] = 1e6;
        let want = sk.clone().predictor(&beta).predict_threads(&q, 1);
        let loads = sk.loads_all(&beta, 1);
        let terms = sk.predict_terms(&loads, &q);
        assert_eq!(terms.len(), 12);
        let inv_m = 1.0 / m as f64;
        for (qi, row) in terms.iter().enumerate() {
            assert_eq!(row.len(), m);
            let mut acc = 0.0f64;
            for t in row.iter().flatten() {
                acc += *t;
            }
            assert_eq!(acc * inv_m, want[qi], "query {qi}");
        }
    }

    #[test]
    fn prop_matvec_linear() {
        // K̃(aα + bβ) = a K̃α + b K̃β
        prop_check(13, 10, |r| {
            let n = gens::size(r, 8, 40);
            let d = gens::size(r, 1, 5);
            let x = gens::vec_normal_f32(r, n * d);
            let alpha = gens::vec_f64(r, n, -2.0, 2.0);
            let beta = gens::vec_f64(r, n, -2.0, 2.0);
            (n, d, x, alpha, beta)
        }, |(n, d, x, alpha, beta)| {
            let sk = build(x, *n, *d, 4, "smooth2", 7.0, 1.0, 21);
            let mixed: Vec<f64> = alpha
                .iter()
                .zip(beta)
                .map(|(a, b)| 2.0 * a - 0.5 * b)
                .collect();
            let lhs = sk.matvec(&mixed);
            let ya = sk.matvec(alpha);
            let yb = sk.matvec(beta);
            for i in 0..*n {
                let want = 2.0 * ya[i] - 0.5 * yb[i];
                if (lhs[i] - want).abs() > 1e-8 * (1.0 + want.abs()) {
                    return Err(format!("row {i}: {} vs {want}", lhs[i]));
                }
            }
            Ok(())
        });
    }

    fn leverage_params(n: usize, d: usize) -> WlshBuildParams {
        WlshBuildParams::new(n, d, 24)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .seed(51)
            .sampling(SamplingSpec::Leverage { pilot: 8, keep: 12 })
            .lambda(0.7)
    }

    #[test]
    fn leverage_build_keeps_a_weighted_subset_of_the_pool() {
        let (n, d) = (80, 4);
        let x = random_x(50, n, d);
        let params = leverage_params(n, d);
        let sk = WlshSketch::build_mem(&x, &params);
        let pool = WlshSketch::build_mem(&x, &params.clone().sampling(SamplingSpec::Uniform));
        assert_eq!(sk.m(), 12);
        let info = sk.sampling_info.clone().expect("leverage build records provenance");
        assert_eq!(info.pool_m, 24);
        assert_eq!(info.kept.len(), 12);
        // indices strictly ascending, weights all equal (trace-preserving c)
        for pair in info.kept.windows(2) {
            assert!(pair[0].0 < pair[1].0);
            assert_eq!(pair[0].1, pair[1].1);
        }
        let c = info.kept[0].1;
        assert!(c.is_finite() && c > 0.0);
        // each kept instance is bit-identical to its pool sibling, reweighted
        for (inst, &(s, w)) in sk.instances.iter().zip(&info.kept) {
            let want = &pool.instances[s];
            assert_eq!(inst.weights, want.weights, "instance {s} weights");
            assert_eq!(inst.table.bucket_of, want.table.bucket_of, "instance {s} buckets");
            assert_eq!(inst.iweight, w);
        }
        // trait accessor exposes the same provenance
        assert_eq!(KrrOperator::sampling_header(&sk), Some(&info));
        assert_eq!(KrrOperator::sampling_header(&pool), None);
    }

    #[test]
    fn selected_build_replays_the_leverage_build_exactly() {
        // Checkpoint-restore contract: rebuilding from the stored keep list
        // (uniform params + build_selected) is bit-identical to the original
        // leverage build — matvec, diag, predict.
        let (n, d) = (64, 3);
        let x = random_x(53, n, d);
        let params = leverage_params(n, d);
        let sk = WlshSketch::build_mem(&x, &params);
        let info = sk.sampling_info.clone().unwrap();
        let src = crate::data::MatrixSource::new("mem", &x, d);
        let uniform = params.clone().sampling(SamplingSpec::Uniform);
        let re = WlshSketch::build_selected(&uniform, &src, info.pool_m, &info.kept).unwrap();
        assert_eq!(re.sampling_info.as_ref(), Some(&info));
        let mut rng = Pcg64::new(55, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        assert_eq!(re.matvec(&beta), sk.matvec(&beta));
        assert_eq!(re.diag_values(), sk.diag_values());
        let q = random_x(56, 8, d);
        assert_eq!(re.predict(&q, &beta), sk.predict(&q, &beta));
    }

    #[test]
    fn selected_build_rejects_bad_keep_lists() {
        let (n, d) = (16, 2);
        let x = random_x(57, n, d);
        let src = crate::data::MatrixSource::new("mem", &x, d);
        let params = WlshBuildParams::new(n, d, 8);
        let err = WlshSketch::build_selected(&params, &src, 8, &[(1, 1.0), (1, 1.0)]);
        assert!(matches!(err, Err(KrrError::BadParam(_))), "duplicate index");
        let err = WlshSketch::build_selected(&params, &src, 8, &[(3, 1.0), (8, 1.0)]);
        assert!(matches!(err, Err(KrrError::BadParam(_))), "index past pool");
    }

    #[test]
    fn iweighted_operator_matches_brute_force_with_weights() {
        // Every consumer of iweight — matvec (via loads), diag, cross — must
        // agree with the weighted Def. 6 brute force
        // K̃_ij = (1/m′) Σ_s iw_s w_i w_j [h_s(x_i) = h_s(x_j)].
        let (n, d) = (48, 3);
        let x = random_x(59, n, d);
        let sk = WlshSketch::build_mem(&x, &leverage_params(n, d));
        let mp = sk.m();
        let k = materialize(&sk);
        for i in 0..n {
            for j in 0..n {
                let mut want = 0.0;
                for inst in &sk.instances {
                    if inst.table.bucket_of[i] == inst.table.bucket_of[j] {
                        want += inst.iweight * inst.weights[i] as f64 * inst.weights[j] as f64;
                    }
                }
                want /= mp as f64;
                assert!(
                    (k[j][i] - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "K[{i}][{j}] {} vs {want}",
                    k[j][i]
                );
            }
        }
        let diag = sk.diag_values();
        for i in 0..n {
            assert!(
                (diag[i] - k[i][i]).abs() < 1e-10 * (1.0 + k[i][i].abs()),
                "diag[{i}] {} vs K_ii {}",
                diag[i],
                k[i][i]
            );
        }
        // cross vector against training row 0 reproduces column 0
        let (_, kq) = sk.cross_vector(&x[0..d]);
        for i in 0..n {
            assert!(
                (kq[i] - k[0][i]).abs() < 1e-9 * (1.0 + k[0][i].abs()),
                "cross[{i}] {} vs K_0i {}",
                kq[i],
                k[0][i]
            );
        }
    }

    #[test]
    fn leverage_selection_is_deterministic_across_reruns_and_workers() {
        let (n, d) = (72, 4);
        let x = random_x(61, n, d);
        let base = leverage_params(n, d);
        let a = WlshSketch::build_mem(&x, &base);
        let info = a.sampling_info.clone().unwrap();
        for workers in [1usize, 2, 8] {
            let b = WlshSketch::build_mem(&x, &base.clone().workers(workers).chunk_rows(13));
            assert_eq!(b.sampling_info.as_ref(), Some(&info), "workers={workers}");
            let beta = vec![1.0; n];
            assert_eq!(b.matvec(&beta), a.matvec(&beta), "workers={workers}");
        }
    }

    #[test]
    fn stein_build_keeps_all_instances_with_mean_one_weights() {
        let (n, d, m) = (64, 3, 16);
        let x = random_x(63, n, d);
        let params = WlshBuildParams::new(n, d, m)
            .bucket_str("rect")
            .seed(65)
            .sampling(SamplingSpec::Stein);
        let sk = WlshSketch::build_mem(&x, &params);
        assert_eq!(sk.m(), m);
        let info = sk.sampling_info.as_ref().unwrap();
        assert_eq!(info.pool_m, m);
        let mean: f64 = sk.instances.iter().map(|i| i.iweight).sum::<f64>() / m as f64;
        assert!((mean - 1.0).abs() < 1e-9, "mean iweight {mean}");
        // weights are not all identical (the scores actually discriminate)
        let first = sk.instances[0].iweight;
        assert!(sk.instances.iter().any(|i| i.iweight != first));
    }
}
