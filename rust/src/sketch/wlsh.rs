//! The WLSH estimator sketch — the paper's core contribution.
//!
//! K̃ = (1/m) Σ_s D_s a_s a_sᵀ D_s where instance s hashes every point into
//! a bucket (Def. 5), D_s holds the f^{⊗d} weights (Def. 6), and a_s is the
//! bucket indicator. Lemma 27: O(dn) preprocessing, O(n) memory, O(n)
//! mat-vec per instance via bucket loads:
//!
//!   B_j(β) = Σ_{i: h(x_i)=j} w_i β_i,      (K̃β)_i = w_i · B_{h(x_i)}(β).

use super::KrrOperator;
use crate::lsh::{BucketTable, IdMode, LshFamily, LshFunction};
use crate::util::rng::Pcg64;

/// One hashed instance: the function, its dense bucket table, and weights.
pub struct WlshInstance {
    pub func: LshFunction,
    pub table: BucketTable,
    pub weights: Vec<f32>,
}

/// The averaged m-instance WLSH sketch of the training set.
pub struct WlshSketch {
    pub instances: Vec<WlshInstance>,
    pub family: LshFamily,
    pub mode: IdMode,
    /// Training rows scaled by 1/scale (hash space).
    x_scaled: Vec<f32>,
    n: usize,
    /// Kernel bandwidth: data is divided by `scale` before hashing, so the
    /// sketch estimates k_{f,p}((x-y)/scale).
    pub scale: f64,
}

impl WlshSketch {
    /// Hash all n training rows under m fresh LSH instances.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
    ) -> WlshSketch {
        Self::build_mode(x, n, d, m, bucket, gamma_shape, scale, seed, IdMode::U64)
    }

    /// As [`build`], selecting the id-collapse mode (I32 = HLO-compatible).
    #[allow(clippy::too_many_arguments)]
    pub fn build_mode(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
    ) -> WlshSketch {
        assert_eq!(x.len(), n * d);
        let mut rng = Pcg64::new(seed, 0);
        let family = LshFamily::new(d, gamma_shape, bucket, &mut rng);
        let inv = (1.0 / scale) as f32;
        let x_scaled: Vec<f32> = x.iter().map(|&v| v * inv).collect();
        let instances = (0..m)
            .map(|s| {
                let mut irng = rng.fork(s as u64);
                Self::build_instance(&x_scaled, &family, mode, &mut irng)
            })
            .collect();
        WlshSketch { instances, family, mode, x_scaled, n, scale }
    }

    /// Assemble a sketch from externally-built parts (the trainer's sharded
    /// build and the XLA-backend build path).
    pub fn from_parts(
        instances: Vec<WlshInstance>,
        family: LshFamily,
        mode: IdMode,
        x_scaled: Vec<f32>,
        n: usize,
        scale: f64,
    ) -> WlshSketch {
        assert!(instances.iter().all(|i| i.weights.len() == n));
        WlshSketch { instances, family, mode, x_scaled, n, scale }
    }

    /// Hash + renumber one instance (used by the trainer's worker shards).
    pub fn build_instance(
        x_scaled: &[f32],
        family: &LshFamily,
        mode: IdMode,
        rng: &mut Pcg64,
    ) -> WlshInstance {
        let func = family.sample(rng);
        let mut ids = Vec::new();
        let mut weights = Vec::new();
        func.hash_batch(x_scaled, family, mode, &mut ids, &mut weights);
        let table = BucketTable::build(&ids);
        WlshInstance { func, table, weights }
    }

    pub fn m(&self) -> usize {
        self.instances.len()
    }

    /// Per-instance bucket loads for a coefficient vector (paper §4).
    fn loads(&self, inst: &WlshInstance, beta: &[f64]) -> Vec<f64> {
        let mut loads = vec![0.0f64; inst.table.n_buckets];
        for i in 0..self.n {
            loads[inst.table.bucket_of[i] as usize] +=
                inst.weights[i] as f64 * beta[i];
        }
        loads
    }

    /// Freeze the sketch + solved β into an O(m·d)-per-query predictor.
    pub fn predictor(&self, beta: &[f64]) -> WlshPredictor<'_> {
        let loads = self
            .instances
            .iter()
            .map(|inst| self.loads(inst, beta))
            .collect();
        WlshPredictor { sketch: self, loads }
    }

    /// Mean bucket count across instances (rank(K̃) proxy, Lemma 30's
    /// footnote: non-empty buckets grow sublinearly in n).
    pub fn mean_buckets(&self) -> f64 {
        self.instances
            .iter()
            .map(|i| i.table.n_buckets as f64)
            .sum::<f64>()
            / self.m() as f64
    }
}

impl KrrOperator for WlshSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let mut out = vec![0.0f64; self.n];
        for inst in &self.instances {
            let loads = self.loads(inst, beta);
            let bucket_of = &inst.table.bucket_of;
            let weights = &inst.weights;
            for i in 0..self.n {
                out[i] += weights[i] as f64 * loads[bucket_of[i] as usize];
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        self.predictor(beta).predict(queries)
    }

    fn prepare(&self, beta: &[f64]) -> super::PreparedState {
        super::PreparedState {
            slots: self.instances.iter().map(|i| self.loads(i, beta)).collect(),
        }
    }

    fn predict_prepared(
        &self,
        queries: &[f32],
        _beta: &[f64],
        state: &super::PreparedState,
    ) -> Vec<f64> {
        self.predict_with_loads(&state.slots, queries)
    }

    fn name(&self) -> String {
        format!(
            "wlsh(f={},shape={},m={})",
            self.family.bucket_name,
            self.family.gamma_shape,
            self.m()
        )
    }

    fn memory_bytes(&self) -> usize {
        self.x_scaled.len() * 4
            + self
                .instances
                .iter()
                .map(|i| i.table.memory_bytes() + i.weights.len() * 4)
                .sum::<usize>()
    }
}

/// Serving-time predictor: per-instance bucket loads are precomputed from
/// the solved β, so a query costs O(m·d) — hash, lookup, multiply.
pub struct WlshPredictor<'a> {
    sketch: &'a WlshSketch,
    loads: Vec<Vec<f64>>,
}

impl WlshPredictor<'_> {
    /// η̃(q) for each row of `queries` (unscaled feature space).
    pub fn predict(&self, queries: &[f32]) -> Vec<f64> {
        self.sketch.predict_with_loads(&self.loads, queries)
    }
}

impl WlshSketch {
    /// Shared predict kernel: hash each query, look its bucket up in every
    /// instance, combine the precomputed loads (paper §4.2's η̃(x)).
    fn predict_with_loads(&self, loads: &[Vec<f64>], queries: &[f32]) -> Vec<f64> {
        let d = self.family.d;
        let nq = queries.len() / d;
        let inv = (1.0 / self.scale) as f32;
        let inv_m = 1.0 / self.m() as f64;
        let mut out = vec![0.0f64; nq];
        let mut q_scaled = vec![0.0f32; d];
        for (qi, o) in out.iter_mut().enumerate() {
            let q = &queries[qi * d..(qi + 1) * d];
            for (dst, src) in q_scaled.iter_mut().zip(q) {
                *dst = *src * inv;
            }
            let mut acc = 0.0f64;
            for (inst, loads_s) in self.instances.iter().zip(loads) {
                let (id, w) = inst.func.hash_point(&q_scaled, &self.family, self.mode);
                if let Some(b) = inst.table.lookup(id) {
                    acc += w as f64 * loads_s[b as usize];
                }
            }
            *o = acc * inv_m;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::util::prop::{gens, prop_check};

    fn random_x(seed: u64, n: usize, d: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    /// Materialize K̃ from mat-vecs against basis vectors.
    fn materialize(op: &dyn KrrOperator) -> Vec<Vec<f64>> {
        let n = op.n();
        (0..n)
            .map(|j| {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                op.matvec(&e)
            })
            .collect()
    }

    #[test]
    fn matvec_matches_materialized_definition() {
        // Def. 6 brute force: K̃_ij = (1/m) Σ_s w_i w_j [h_s(x_i) = h_s(x_j)]
        let (n, d, m) = (40, 3, 5);
        let x = random_x(1, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 2);
        let k = materialize(&sk);
        // brute force from the instances themselves
        for i in 0..n {
            for j in 0..n {
                let mut want = 0.0;
                for inst in &sk.instances {
                    if inst.table.bucket_of[i] == inst.table.bucket_of[j] {
                        want += inst.weights[i] as f64 * inst.weights[j] as f64;
                    }
                }
                want /= m as f64;
                assert!(
                    (k[j][i] - want).abs() < 1e-9,
                    "K[{i}][{j}] {} vs {want}",
                    k[j][i]
                );
            }
        }
    }

    #[test]
    fn sketch_is_symmetric_psd() {
        let (n, d, m) = (32, 4, 8);
        let x = random_x(3, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "rect", 2.0, 1.0, 4);
        let k = materialize(&sk);
        for i in 0..n {
            for j in 0..n {
                assert!((k[i][j] - k[j][i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // E[K̃_ij] = k_{f,p}(x_i - x_j): average many independent sketches.
        let d = 2;
        let x: Vec<f32> = vec![0.0, 0.0, 0.4, -0.3];
        let kern = Kernel::wlsh("rect", 2.0, 1.0);
        let want = kern.eval_f32(&x[0..2], &x[2..4]);
        let trials = 400;
        let mut acc = 0.0;
        let mut acc2 = 0.0;
        for t in 0..trials {
            let sk = WlshSketch::build(&x, 2, d, 8, "rect", 2.0, 1.0, 1000 + t);
            let y = sk.matvec(&[0.0, 1.0]); // column j=1
            acc += y[0];
            acc2 += y[0] * y[0];
        }
        let mean = acc / trials as f64;
        let se = ((acc2 / trials as f64 - mean * mean) / trials as f64).sqrt();
        assert!(
            (mean - want).abs() < 4.0 * se + 5e-3,
            "mean {mean} vs {want} (se {se})"
        );
    }

    #[test]
    fn predictor_matches_trait_predict() {
        let (n, d, m) = (64, 5, 10);
        let x = random_x(5, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.5, 6);
        let mut rng = Pcg64::new(7, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let q = random_x(8, 10, d);
        let a = sk.predict(&q, &beta);
        let b = sk.predictor(&beta).predict(&q);
        assert_eq!(a, b);
    }

    #[test]
    fn predict_far_query_is_zero() {
        let (n, d) = (16, 2);
        let x = random_x(9, n, d);
        let sk = WlshSketch::build(&x, n, d, 6, "rect", 2.0, 1.0, 10);
        let beta = vec![1.0; n];
        // a query 1e6 away shares no bucket with any training point
        let q = vec![1e6f32, -1e6];
        let y = sk.predict(&q, &beta);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn scale_changes_effective_kernel() {
        // wider scale ⇒ more collisions ⇒ larger quadratic form
        let (n, d) = (64, 3);
        let x = random_x(11, n, d);
        let beta = vec![1.0; n];
        let narrow = WlshSketch::build(&x, n, d, 32, "rect", 2.0, 0.25, 12);
        let wide = WlshSketch::build(&x, n, d, 32, "rect", 2.0, 4.0, 12);
        let qn: f64 = narrow.matvec(&beta).iter().sum();
        let qw: f64 = wide.matvec(&beta).iter().sum();
        assert!(qw > qn, "wide {qw} <= narrow {qn}");
    }

    #[test]
    fn prop_matvec_linear() {
        // K̃(aα + bβ) = a K̃α + b K̃β
        prop_check(13, 10, |r| {
            let n = gens::size(r, 8, 40);
            let d = gens::size(r, 1, 5);
            let x = gens::vec_normal_f32(r, n * d);
            let alpha = gens::vec_f64(r, n, -2.0, 2.0);
            let beta = gens::vec_f64(r, n, -2.0, 2.0);
            (n, d, x, alpha, beta)
        }, |(n, d, x, alpha, beta)| {
            let sk = WlshSketch::build(x, *n, *d, 4, "smooth2", 7.0, 1.0, 21);
            let mixed: Vec<f64> = alpha
                .iter()
                .zip(beta)
                .map(|(a, b)| 2.0 * a - 0.5 * b)
                .collect();
            let lhs = sk.matvec(&mixed);
            let ya = sk.matvec(alpha);
            let yb = sk.matvec(beta);
            for i in 0..*n {
                let want = 2.0 * ya[i] - 0.5 * yb[i];
                if (lhs[i] - want).abs() > 1e-8 * (1.0 + want.abs()) {
                    return Err(format!("row {i}: {} vs {want}", lhs[i]));
                }
            }
            Ok(())
        });
    }
}
