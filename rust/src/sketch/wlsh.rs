//! The WLSH estimator sketch — the paper's core contribution.
//!
//! K̃ = (1/m) Σ_s D_s a_s a_sᵀ D_s where instance s hashes every point into
//! a bucket (Def. 5), D_s holds the f^{⊗d} weights (Def. 6), and a_s is the
//! bucket indicator. Lemma 27: O(dn) preprocessing, O(n) memory, O(n)
//! mat-vec per instance via bucket loads:
//!
//!   B_j(β) = Σ_{i: h(x_i)=j} w_i β_i,      (K̃β)_i = w_i · B_{h(x_i)}(β).
//!
//! The bucket loads are accumulated over the table's flat CSR arrays
//! ([`BucketTable::members`] plus the instance's CSR-aligned
//! `weights_csr`), so the load pass walks two contiguous arrays instead of
//! scattering into a random bucket slot per point (cf. Wu et al.,
//! "Revisiting Random Binning Features", KDD 2018). The mat-vec fuses a
//! fixed-size block of instances into each thread task
//! ([`WlshSketch::matvec_threads`]), and reductions happen in fixed block
//! order so every result is bit-identical to the serial path for every
//! thread count. The pre-CSR instance-at-a-time path is kept as
//! [`WlshSketch::matvec_unfused`] for benchmarking and cross-checking.

use std::sync::{Arc, OnceLock};

use super::{KrrOperator, Predictor};
use crate::api::{BucketSpec, KrrError};
use crate::data::{Chunk, DataSource, MatrixSource, SparseChunk};
use crate::lsh::{
    BucketTable, BucketTableBuilder, IdMode, LshFamily, LshFunction, SparseHashPlan,
};
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::simd;

/// Query batches at or below this size are predicted serially; larger
/// batches split into chunks of this many rows for the thread fan-out.
/// Shared with the coordinator's router so sharding never nests two levels
/// of parallelism.
pub(crate) const SERIAL_QUERY_CHUNK: usize = 256;

/// Below this many scatter ops (n·m) the automatic-thread paths stay
/// serial: a mat-vec this small runs in well under a millisecond, so
/// per-call thread spawns would dominate. Explicit `*_threads` calls are
/// never gated — the caller decides.
const PAR_MIN_WORK: usize = 1 << 17;

/// Row floor for the automatic paths: the fused mat-vec spawns threads
/// once per `FUSE_BLOCK · PAR_ROUND` = 256-instance reduction round, so a
/// round carries ≥ 256·n scatter ops and n only needs to clear a small
/// floor for the spawn/join cost to amortize (the pre-fusion path spawned
/// once per 32 instances and needed n ≥ 2048).
const PAR_MIN_ROWS: usize = 256;

/// Instances fused into one thread task of the mat-vec. Fixed (never
/// derived from the thread count) so the block decomposition — and hence
/// the floating-point reduction order — is machine-independent.
const FUSE_BLOCK: usize = 8;

/// Blocks buffered per reduction round of the fused mat-vec: peak extra
/// memory is `PAR_ROUND · n` f64s regardless of m, and round boundaries
/// fall at fixed block indices so they never affect the result.
const PAR_ROUND: usize = 32;

/// One hashed instance: the function, its dense CSR bucket table, the
/// per-point weights, and the same weights permuted into CSR member order.
#[derive(Clone)]
pub struct WlshInstance {
    pub func: LshFunction,
    pub table: BucketTable,
    /// f^{⊗d} weight of each point, in point order.
    pub weights: Vec<f32>,
    /// `weights` permuted into [`BucketTable::members`] order, so the
    /// bucket-load pass reads weights and member ids from two contiguous
    /// arrays.
    pub weights_csr: Vec<f32>,
}

impl WlshInstance {
    /// Assemble an instance, deriving the CSR-aligned weight array.
    pub fn new(func: LshFunction, table: BucketTable, weights: Vec<f32>) -> WlshInstance {
        let weights_csr = table.members.iter().map(|&i| weights[i as usize]).collect();
        WlshInstance { func, table, weights, weights_csr }
    }
}

/// Per-instance accumulator of the streaming build: the sampled hash
/// function, the incremental bucket renumbering, and the weights gathered
/// so far. Advanced one shared chunk at a time (instances are mutually
/// independent, so accumulators thread freely without affecting results).
struct InstanceAccum {
    func: LshFunction,
    builder: BucketTableBuilder,
    weights: Vec<f32>,
    /// Reused per-chunk scratch (raw ids / weights of the current chunk).
    ids_buf: Vec<u64>,
    w_buf: Vec<f32>,
    /// Sparse hash plan (batch arithmetic), built lazily on the first
    /// sparse chunk so dense-only builds pay nothing.
    plan: Option<SparseHashPlan>,
    done: Option<WlshInstance>,
}

/// The averaged m-instance WLSH sketch of the training set.
///
/// Memory is O(n) per instance (Lemma 27) — the sketch never retains the
/// n×d training matrix: every constructor funnels through the chunked
/// [`build_source`](Self::build_source) assembly, which only ever holds
/// one O(chunk·d) block of (scaled) rows at a time.
///
/// `Clone` supports the online-update path's copy-on-write
/// (`Arc::make_mut`): models already serving the old sketch keep it,
/// while the online trainer appends into its private copy.
#[derive(Clone)]
pub struct WlshSketch {
    pub instances: Vec<WlshInstance>,
    pub family: LshFamily,
    pub mode: IdMode,
    n: usize,
    /// Kernel bandwidth: data is divided by `scale` before hashing, so the
    /// sketch estimates k_{f,p}((x-y)/scale).
    pub scale: f64,
}

impl WlshSketch {
    /// The fused-mat-vec block size, re-exported for the shard topology
    /// layer: distributed instance ranges must cut on block boundaries so
    /// the coordinator's partial reduction replays
    /// [`matvec_threads`](Self::matvec_threads)'s block order exactly.
    pub const FUSE_BLOCK: usize = FUSE_BLOCK;

    /// Hash all n training rows under m fresh LSH instances. The bucket is
    /// given by its string name for test/bench convenience; it must parse
    /// as a [`BucketSpec`] (typed callers use
    /// [`build_spec`](Self::build_spec)).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
    ) -> WlshSketch {
        let spec: BucketSpec = match bucket.parse() {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        };
        Self::build_spec_mode(x, n, d, m, &spec, gamma_shape, scale, seed, IdMode::U64)
    }

    /// As [`build`](Self::build) with a typed bucket spec.
    #[allow(clippy::too_many_arguments)]
    pub fn build_spec(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
    ) -> WlshSketch {
        Self::build_spec_mode(x, n, d, m, bucket, gamma_shape, scale, seed, IdMode::U64)
    }

    /// As [`build`](Self::build), selecting the id-collapse mode
    /// (I32 = HLO-compatible).
    #[allow(clippy::too_many_arguments)]
    pub fn build_mode(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &str,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
    ) -> WlshSketch {
        let spec: BucketSpec = match bucket.parse() {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        };
        Self::build_spec_mode(x, n, d, m, &spec, gamma_shape, scale, seed, mode)
    }

    /// Fully-typed in-memory build: wraps the slice in a
    /// [`MatrixSource`] and runs the one chunked assembly path
    /// ([`build_source`](Self::build_source)) with a single whole-matrix
    /// chunk.
    #[allow(clippy::too_many_arguments)]
    pub fn build_spec_mode(
        x: &[f32],
        n: usize,
        d: usize,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
    ) -> WlshSketch {
        assert_eq!(x.len(), n * d);
        let src = MatrixSource::new("mem", x, d);
        Self::build_source(&src, m, bucket, gamma_shape, scale, seed, mode, n.max(1), 1)
            .expect("in-memory WLSH build cannot fail")
    }

    /// Streaming build over a re-iterable chunked source: one pass,
    /// holding O(chunk·d) scaled rows plus the growing O(n·m) sketch —
    /// never the n×d matrix. Each chunk is hashed under all m instances
    /// (the per-instance accumulators fanned out over `workers` threads
    /// via [`par::fan_out_mut`]), raw ids feed the incremental
    /// [`BucketTableBuilder`] renumbering, and tables finish with the same
    /// counting sort as the in-memory constructor — so the result is
    /// bit-identical to [`build_spec_mode`](Self::build_spec_mode) on the
    /// materialized rows, for every chunk size and worker count
    /// (asserted by `tests/stream_equivalence.rs`).
    ///
    /// Sparse sources stay sparse: CSR chunks are hashed through
    /// [`LshFunction::hash_sparse`] in O(nnz) per rect row (O(d) with a
    /// smooth bucket, for the weight product), and the sparse ids/weights
    /// are bit-identical to hashing the densified rows — so the whole
    /// equivalence above carries over to sparse streams unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn build_source(
        src: &dyn DataSource,
        m: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<WlshSketch, KrrError> {
        Self::build_source_range(
            src, m, 0, m, bucket, gamma_shape, scale, seed, mode, chunk_rows, workers,
        )
    }

    /// Build only instances `[lo, hi)` of an `m_total`-instance sketch —
    /// the shard worker's constructor. Instance `s`'s hash function is
    /// sampled from the `s`-th fork of the seed RNG, and forking advances
    /// the parent state, so the range build replays every fork below `hi`
    /// and samples only the owned ones: the produced instances are
    /// *bit-identical* to instances `[lo, hi)` of the full build.
    ///
    /// The returned sketch's `m()` is the local count `hi - lo`, so its
    /// trait `matvec`/`predict` normalize by the *local* instance count —
    /// distributed callers must use the raw partial kernels
    /// ([`block_partials`](Self::block_partials),
    /// [`predict_terms`](Self::predict_terms)) and let the coordinator
    /// apply `1/m_total` once.
    #[allow(clippy::too_many_arguments)]
    pub fn build_source_range(
        src: &dyn DataSource,
        m_total: usize,
        lo: usize,
        hi: usize,
        bucket: &BucketSpec,
        gamma_shape: f64,
        scale: f64,
        seed: u64,
        mode: IdMode,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<WlshSketch, KrrError> {
        assert!(
            lo <= hi && hi <= m_total,
            "instance range [{lo}, {hi}) out of bounds for m_total={m_total}"
        );
        let d = src.dim();
        let mut rng = Pcg64::new(seed, 0);
        let family = LshFamily::new(d, gamma_shape, bucket, &mut rng);
        let n_hint = src.len_hint().unwrap_or(0);
        // Sample the owned instances' hash functions up front, in instance
        // order from per-instance RNG forks — the exact draw sequence of
        // the full build (each fork advances the parent, so forks below
        // `lo` are drawn and discarded).
        let mut accums: Vec<InstanceAccum> = Vec::with_capacity(hi - lo);
        for s in 0..hi {
            let mut irng = rng.fork(s as u64);
            if s >= lo {
                accums.push(InstanceAccum {
                    func: family.sample(&mut irng),
                    builder: BucketTableBuilder::with_capacity(n_hint),
                    weights: Vec::with_capacity(n_hint),
                    ids_buf: Vec::new(),
                    w_buf: Vec::new(),
                    plan: None,
                    done: None,
                });
            }
        }
        let inv = (1.0 / scale) as f32;
        let mut x_buf: Vec<f32> = Vec::new();
        let mut v_buf: Vec<f32> = Vec::new();
        let mut n = 0usize;
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            n += ys.len();
            // Bandwidth-scale the chunk into reused buffers, keeping its
            // representation: dense rows scale in place; sparse chunks
            // scale only the stored values (0 · inv = 0, so the implicit
            // zeros need no work). The I32 id collapse has no sparse hash
            // kernel, so sparse chunks densify there — a fallback, not the
            // streaming path (HLO mode is a compatibility mode).
            let scaled: Chunk<'_> = match chunk {
                Chunk::Dense(rows) => {
                    x_buf.clear();
                    x_buf.extend(rows.iter().map(|&v| v * inv));
                    Chunk::Dense(&x_buf)
                }
                Chunk::Sparse(sp) if mode == IdMode::U64 => {
                    v_buf.clear();
                    v_buf.extend(sp.values.iter().map(|&v| v * inv));
                    Chunk::Sparse(SparseChunk {
                        indptr: sp.indptr,
                        indices: sp.indices,
                        values: &v_buf,
                    })
                }
                Chunk::Sparse(sp) => {
                    sp.densify_into(d, &mut x_buf);
                    for v in x_buf.iter_mut() {
                        *v *= inv;
                    }
                    Chunk::Dense(&x_buf)
                }
            };
            par::fan_out_mut(&mut accums, workers, |_, acc| {
                acc.ids_buf.clear();
                acc.w_buf.clear();
                match &scaled {
                    Chunk::Dense(rows) => {
                        acc.func
                            .hash_batch(rows, &family, mode, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                    Chunk::Sparse(sp) => {
                        if acc.plan.is_none() {
                            acc.plan = Some(acc.func.sparse_plan(&family));
                        }
                        let plan = acc.plan.as_ref().expect("plan just built");
                        acc.func
                            .hash_sparse(sp, plan, &family, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                }
                for &id in &acc.ids_buf {
                    acc.builder.push(id);
                }
                acc.weights.extend_from_slice(&acc.w_buf);
            });
            Ok(())
        })?;
        par::fan_out_mut(&mut accums, workers, |_, acc| {
            let table = std::mem::take(&mut acc.builder).finish();
            let weights = std::mem::take(&mut acc.weights);
            acc.done = Some(WlshInstance::new(acc.func.clone(), table, weights));
        });
        let instances = accums
            .into_iter()
            .map(|a| a.done.expect("instance finalized"))
            .collect();
        Ok(WlshSketch { instances, family, mode, n, scale })
    }

    /// Hash additional rows into the existing sketch — the online-update
    /// path. Every instance keeps its already-sampled hash function (no RNG
    /// is consumed), its finished bucket table reopens as a
    /// [`BucketTableBuilder`] positioned exactly where the original build
    /// stopped, and the appended chunks run through the same scale /
    /// hash / push / counting-sort pipeline as
    /// [`build_source`](Self::build_source) — so the appended sketch is
    /// **bit-identical** to a from-scratch build over the concatenated
    /// data, at every chunk size and worker count
    /// (`tests/online_equivalence.rs`). Returns the number of rows
    /// appended.
    pub fn append_source(
        &mut self,
        src: &dyn DataSource,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<usize, KrrError> {
        let d = self.family.d;
        if src.dim() != d {
            return Err(KrrError::Dataset(format!(
                "append expects {d} features per row, got {}",
                src.dim()
            )));
        }
        let family = self.family.clone();
        let mode = self.mode;
        // Reopen every instance as a mid-build accumulator: the finished
        // table's renumbering map + per-point indices ARE the builder
        // state after the original rows.
        let mut accums: Vec<InstanceAccum> = std::mem::take(&mut self.instances)
            .into_iter()
            .map(|inst| InstanceAccum {
                func: inst.func,
                builder: inst.table.into_builder(),
                weights: inst.weights,
                ids_buf: Vec::new(),
                w_buf: Vec::new(),
                plan: None,
                done: None,
            })
            .collect();
        let inv = (1.0 / self.scale) as f32;
        let mut x_buf: Vec<f32> = Vec::new();
        let mut v_buf: Vec<f32> = Vec::new();
        let mut appended = 0usize;
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            appended += ys.len();
            let scaled: Chunk<'_> = match chunk {
                Chunk::Dense(rows) => {
                    x_buf.clear();
                    x_buf.extend(rows.iter().map(|&v| v * inv));
                    Chunk::Dense(&x_buf)
                }
                Chunk::Sparse(sp) if mode == IdMode::U64 => {
                    v_buf.clear();
                    v_buf.extend(sp.values.iter().map(|&v| v * inv));
                    Chunk::Sparse(SparseChunk {
                        indptr: sp.indptr,
                        indices: sp.indices,
                        values: &v_buf,
                    })
                }
                Chunk::Sparse(sp) => {
                    sp.densify_into(d, &mut x_buf);
                    for v in x_buf.iter_mut() {
                        *v *= inv;
                    }
                    Chunk::Dense(&x_buf)
                }
            };
            par::fan_out_mut(&mut accums, workers, |_, acc| {
                acc.ids_buf.clear();
                acc.w_buf.clear();
                match &scaled {
                    Chunk::Dense(rows) => {
                        acc.func
                            .hash_batch(rows, &family, mode, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                    Chunk::Sparse(sp) => {
                        if acc.plan.is_none() {
                            acc.plan = Some(acc.func.sparse_plan(&family));
                        }
                        let plan = acc.plan.as_ref().expect("plan just built");
                        acc.func
                            .hash_sparse(sp, plan, &family, &mut acc.ids_buf, &mut acc.w_buf);
                    }
                }
                for &id in &acc.ids_buf {
                    acc.builder.push(id);
                }
                acc.weights.extend_from_slice(&acc.w_buf);
            });
            Ok(())
        })?;
        par::fan_out_mut(&mut accums, workers, |_, acc| {
            let table = std::mem::take(&mut acc.builder).finish();
            let weights = std::mem::take(&mut acc.weights);
            acc.done = Some(WlshInstance::new(acc.func.clone(), table, weights));
        });
        self.instances = accums
            .into_iter()
            .map(|a| a.done.expect("instance finalized"))
            .collect();
        self.n += appended;
        Ok(appended)
    }

    pub fn m(&self) -> usize {
        self.instances.len()
    }

    /// Per-instance bucket loads for a coefficient vector (paper §4),
    /// accumulated over the CSR arrays: bucket j's load sums
    /// `weights_csr[k] · β[members[k]]` over its member range.
    ///
    /// Each bucket reduces in the fixed 4-lane-strided order of
    /// `util::simd::weighted_gather_sum` (lane j sums member indices ≡ j
    /// mod 4 within the bucket, then `tail + lane0..lane3`). The order
    /// depends only on the CSR layout — never on ISA, thread count, or
    /// chunking — so loads are bit-identical across `WLSH_SIMD=on|off`,
    /// worker counts, and streamed vs in-memory builds.
    fn loads(&self, inst: &WlshInstance, beta: &[f64]) -> Vec<f64> {
        let mut loads = vec![0.0f64; inst.table.n_buckets];
        Self::loads_into(inst, beta, &mut loads);
        loads
    }

    /// CSR bucket-load kernel writing into a caller-provided buffer
    /// (`loads.len() == inst.table.n_buckets`; every slot is overwritten).
    fn loads_into(inst: &WlshInstance, beta: &[f64], loads: &mut [f64]) {
        let offsets = &inst.table.offsets;
        let members = &inst.table.members;
        let w = &inst.weights_csr;
        for (j, out) in loads.iter_mut().enumerate() {
            let lo = offsets[j] as usize;
            let hi = offsets[j + 1] as usize;
            *out = simd::weighted_gather_sum(&w[lo..hi], &members[lo..hi], beta);
        }
    }

    /// Bucket loads for every instance, the per-instance work fanned out
    /// over `threads` worker threads. Instances are independent, so the
    /// result is identical (bitwise) to the serial instance loop for any
    /// thread count.
    pub fn loads_all(&self, beta: &[f64], threads: usize) -> Vec<Vec<f64>> {
        par::fan_out(self.m(), threads, |s| self.loads(&self.instances[s], beta))
    }

    /// Worker count for the automatic (trait) paths: all cores when the
    /// sketch is big enough to amortize thread spawns, else serial.
    fn auto_threads(&self) -> usize {
        if self.n < PAR_MIN_ROWS || self.n * self.m() < PAR_MIN_WORK {
            1
        } else {
            par::num_threads()
        }
    }

    /// Freeze the sketch + solved β into an O(m·d)-per-query predictor.
    /// The handle shares the sketch via `Arc`, so it outlives local
    /// borrows and can be moved into server threads.
    pub fn predictor(self: Arc<Self>, beta: &[f64]) -> WlshPredictor {
        let loads = self.loads_all(beta, self.auto_threads());
        WlshPredictor { sketch: self, loads, sparse_plans: OnceLock::new() }
    }

    /// Mean bucket count across instances (rank(K̃) proxy, Lemma 30's
    /// footnote: non-empty buckets grow sublinearly in n).
    pub fn mean_buckets(&self) -> f64 {
        self.instances
            .iter()
            .map(|i| i.table.n_buckets as f64)
            .sum::<f64>()
            / self.m() as f64
    }

    /// diag(K̃): every point collides with itself in every instance, so
    /// K̃_ii = (1/m) Σ_s w_{s,i}². O(n·m); feeds the solver's Jacobi
    /// preconditioner.
    pub fn diag_values(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        for inst in &self.instances {
            for (o, &w) in out.iter_mut().zip(&inst.weights) {
                *o += w as f64 * w as f64;
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }

    /// Serial reference mat-vec: the fused block algorithm on one thread.
    /// [`matvec_threads`](Self::matvec_threads) is bit-identical to this
    /// for every thread count (asserted by
    /// `tests/parallel_determinism.rs`).
    pub fn matvec_serial(&self, beta: &[f64]) -> Vec<f64> {
        self.matvec_threads(beta, 1)
    }

    /// One fused block's additive contribution: for each instance in the
    /// block (in order), accumulate its CSR bucket loads into a reused
    /// buffer, then gather `c_i += w_i · B_{h(x_i)}` into the block's
    /// single output buffer. One O(n) buffer per block instead of one per
    /// instance.
    fn block_contrib(&self, block: &[WlshInstance], beta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        let mut loads: Vec<f64> = Vec::new();
        for inst in block {
            loads.clear();
            loads.resize(inst.table.n_buckets, 0.0);
            Self::loads_into(inst, beta, &mut loads);
            simd::scaled_gather_add(&mut out, &inst.weights, &inst.table.bucket_of, &loads);
        }
        out
    }

    /// Fused parallel mat-vec: instances are grouped into fixed 8-instance
    /// blocks (`FUSE_BLOCK`), each thread task computes one block's
    /// contribution over the CSR arrays, and block partials are reduced in
    /// fixed block order (rounds of `PAR_ROUND` blocks bound peak
    /// memory). The decomposition depends only on m — never on `threads` —
    /// so the result is bit-identical to
    /// [`matvec_serial`](Self::matvec_serial) for every thread count. The
    /// requested `threads` is always honored (the work-size gate lives in
    /// the trait path only).
    pub fn matvec_threads(&self, beta: &[f64], threads: usize) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        let mut out = vec![0.0f64; self.n];
        for round in blocks.chunks(PAR_ROUND) {
            let partials =
                par::fan_out(round.len(), threads, |b| self.block_contrib(round[b], beta));
            for p in &partials {
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }

    /// Raw per-block mat-vec partials, in local block order: entry `b` is
    /// the un-normalized contribution of instance block `b`
    /// (`FUSE_BLOCK` instances each) — exactly the vectors
    /// [`matvec_threads`](Self::matvec_threads) reduces. The distributed
    /// solve ships these to the coordinator, which accumulates them in
    /// global block order and applies `1/m_total` once, reproducing the
    /// single-process mat-vec bit for bit (blocks are computed
    /// independently, so `threads` never affects the values).
    pub fn block_partials(&self, beta: &[f64], threads: usize) -> Vec<Vec<f64>> {
        assert_eq!(beta.len(), self.n);
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        par::fan_out(blocks.len(), threads, |b| self.block_contrib(blocks[b], beta))
    }

    /// Raw per-instance prediction terms for a row-major query batch: for
    /// query `q` and local instance `s`, `Some(w · B_{h(q)})` when `q`'s
    /// bucket is non-empty in instance `s`, else `None`. These are the
    /// exact addends of the serial predict kernel
    /// (`predict_query_range`), un-normalized; the coordinator
    /// concatenates shards in instance order, accumulates left-to-right
    /// skipping the `None`s, and applies `1/m_total` — bit-identical to
    /// the single-process prediction. (A miss must stay a skip, not a
    /// `0.0` addend: adding 0.0 can flip a `-0.0` accumulator to `+0.0`.)
    pub fn predict_terms(&self, loads: &[Vec<f64>], queries: &[f32]) -> Vec<Vec<Option<f64>>> {
        let d = self.family.d;
        let inv = (1.0 / self.scale) as f32;
        let nq = queries.len() / d;
        let mut q_scaled = vec![0.0f32; d];
        (0..nq)
            .map(|qi| {
                let q = &queries[qi * d..(qi + 1) * d];
                for (dst, src) in q_scaled.iter_mut().zip(q) {
                    *dst = *src * inv;
                }
                self.instances
                    .iter()
                    .zip(loads)
                    .map(|(inst, loads_s)| {
                        let (id, w) = inst.func.hash_point(&q_scaled, &self.family, self.mode);
                        inst.table.lookup(id).map(|b| w as f64 * loads_s[b as usize])
                    })
                    .collect()
            })
            .collect()
    }

    /// One fused block's un-normalized cross-covariance contribution for a
    /// pre-scaled query: `(Σ_s w_s(q)², Σ_s w_s(q)·w_s(x_i)·1[h_s(x_i)=h_s(q)])`
    /// over the block's instances, walking each matched bucket's CSR member
    /// range. Instances inside the block accumulate in order, mirroring
    /// [`block_contrib`](Self::block_contrib).
    fn cross_block_contrib(&self, block: &[WlshInstance], q_scaled: &[f32]) -> (f64, Vec<f64>) {
        let mut kxx = 0.0f64;
        let mut out = vec![0.0f64; self.n];
        for inst in block {
            let (id, w) = inst.func.hash_point(q_scaled, &self.family, self.mode);
            kxx += w as f64 * w as f64;
            if let Some(b) = inst.table.lookup(id) {
                let lo = inst.table.offsets[b as usize] as usize;
                let hi = inst.table.offsets[b as usize + 1] as usize;
                for k in lo..hi {
                    out[inst.table.members[k] as usize] += w as f64 * inst.weights_csr[k] as f64;
                }
            }
        }
        (kxx, out)
    }

    /// Raw per-block cross-covariance partials for one query, in local
    /// block order: entry `b` is the un-normalized
    /// `(Σ w_s(q)², cross vector)` contribution of instance block `b` —
    /// the cross-vector analogue of [`block_partials`](Self::block_partials).
    /// Shard workers ship these to the coordinator, which reduces them in
    /// global block order and applies `1/m_total` once, reproducing the
    /// single-process [`cross_vector`](Self::cross_vector) bit for bit.
    pub fn cross_partials(&self, query: &[f32], threads: usize) -> Vec<(f64, Vec<f64>)> {
        let d = self.family.d;
        assert_eq!(query.len(), d, "query must have d features");
        let inv = (1.0 / self.scale) as f32;
        let q_scaled: Vec<f32> = query.iter().map(|&x| x * inv).collect();
        let blocks: Vec<&[WlshInstance]> = self.instances.chunks(FUSE_BLOCK).collect();
        par::fan_out(blocks.len(), threads, |b| {
            self.cross_block_contrib(blocks[b], &q_scaled)
        })
    }

    /// Cross-covariance of one query against the training set in the
    /// sketched geometry: `(k̃(q,q), k̃_q)` with
    /// k̃(q,q) = (1/m)·Σ_s w_s(q)² and
    /// (k̃_q)_i = (1/m)·Σ_s w_s(q)·w_s(x_i)·1[h_s(x_i)=h_s(q)] — O(m·d)
    /// hashing plus one walk over each matched bucket. Block partials are
    /// reduced in fixed block order, so the value is thread-count
    /// independent.
    pub fn cross_vector(&self, query: &[f32]) -> (f64, Vec<f64>) {
        let partials = self.cross_partials(query, self.auto_threads());
        let mut kxx = 0.0f64;
        let mut v = vec![0.0f64; self.n];
        for (kp, p) in &partials {
            kxx += kp;
            for (o, x) in v.iter_mut().zip(p) {
                *o += *x;
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for x in v.iter_mut() {
            *x *= inv_m;
        }
        (kxx * inv_m, v)
    }

    /// One instance's additive mat-vec contribution (the pre-fusion
    /// formulation: one O(n) buffer per instance).
    fn instance_contrib(&self, inst: &WlshInstance, beta: &[f64]) -> Vec<f64> {
        let loads = self.loads(inst, beta);
        let bucket_of = &inst.table.bucket_of;
        let weights = &inst.weights;
        let mut c = vec![0.0f64; self.n];
        for (i, cv) in c.iter_mut().enumerate() {
            *cv = weights[i] as f64 * loads[bucket_of[i] as usize];
        }
        c
    }

    /// The pre-fusion (PR-1) mat-vec: per-instance contribution vectors
    /// reduced in fixed instance order, 32 instances per round. Kept as the
    /// baseline `bench_matvec` compares the fused path against and as an
    /// independent cross-check (it computes the same per-instance terms,
    /// summed in per-instance rather than per-block grouping, so the two
    /// paths agree to floating-point reassociation error).
    pub fn matvec_unfused(&self, beta: &[f64], threads: usize) -> Vec<f64> {
        const ROUND: usize = 32;
        assert_eq!(beta.len(), self.n);
        let mut out = vec![0.0f64; self.n];
        if threads <= 1 || self.m() <= 1 {
            for inst in &self.instances {
                let loads = self.loads(inst, beta);
                let bucket_of = &inst.table.bucket_of;
                let weights = &inst.weights;
                for i in 0..self.n {
                    out[i] += weights[i] as f64 * loads[bucket_of[i] as usize];
                }
            }
        } else {
            for round in self.instances.chunks(ROUND) {
                let partials = par::fan_out(round.len(), threads, |s| {
                    self.instance_contrib(&round[s], beta)
                });
                for p in &partials {
                    for (o, v) in out.iter_mut().zip(p) {
                        *o += *v;
                    }
                }
            }
        }
        let inv_m = 1.0 / self.m() as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        out
    }
}

impl KrrOperator for WlshSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.matvec_threads(beta, self.auto_threads())
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let loads = self.loads_all(beta, self.auto_threads());
        self.predict_with_loads(&loads, queries, par::num_threads())
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        Box::new(WlshSketch::predictor(self, beta))
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some(self.diag_values())
    }

    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        Some(WlshSketch::cross_vector(self, query))
    }

    fn name(&self) -> String {
        format!(
            "wlsh(f={},shape={},m={})",
            self.family.bucket_spec,
            self.family.gamma_shape,
            self.m()
        )
    }

    fn memory_bytes(&self) -> usize {
        // O(n) words per instance and nothing else: the training matrix is
        // never retained (Lemma 27).
        self.instances
            .iter()
            .map(|i| i.table.memory_bytes() + i.weights.len() * 4 + i.weights_csr.len() * 4)
            .sum::<usize>()
    }
}

/// Serving-time predictor: per-instance bucket loads are precomputed from
/// the solved β, so a query costs O(m·d) — hash, lookup, multiply. Owns an
/// `Arc` of the sketch (hash functions + tables) and the load vectors; the
/// only state a prediction touches.
pub struct WlshPredictor {
    sketch: Arc<WlshSketch>,
    loads: Vec<Vec<f64>>,
    /// Per-instance sparse hash plans in *point* arithmetic (the query
    /// path divides by w where the batch path multiplies by 1/w — the two
    /// differ in f32, so each side carries its own plan). Built lazily on
    /// the first sparse query and shared across serve threads.
    sparse_plans: OnceLock<Vec<SparseHashPlan>>,
}

impl WlshPredictor {
    /// As [`Predictor::predict`] with an explicit worker-thread count
    /// (1 = the serial reference path).
    pub fn predict_threads(&self, queries: &[f32], threads: usize) -> Vec<f64> {
        self.sketch.predict_with_loads(&self.loads, queries, threads)
    }
}

impl Predictor for WlshPredictor {
    fn dim(&self) -> usize {
        self.sketch.family.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.sketch
            .predict_with_loads_into(&self.loads, queries, par::num_threads(), out);
    }

    fn predict(&self, queries: &[f32]) -> Vec<f64> {
        self.predict_threads(queries, par::num_threads())
    }

    /// Native sparse serve path: hash each CSR row with the point-arithmetic
    /// [`SparseHashPlan`]s — bit-identical to densifying the row and calling
    /// [`predict_into`](Predictor::predict_into), but O(nnz + d) per query
    /// with no scatter. I32/HLO mode has no sparse kernel and densifies
    /// row-by-row.
    fn predict_sparse_into(&self, queries: &SparseChunk<'_>, out: &mut [f64]) {
        let sk = &self.sketch;
        assert_eq!(out.len(), queries.nrows(), "one output slot per query row");
        if sk.mode != IdMode::U64 {
            let d = sk.family.d;
            let mut row = vec![0.0f32; d];
            for (i, o) in out.iter_mut().enumerate() {
                let (idx, vals) = queries.row(i);
                for v in row.iter_mut() {
                    *v = 0.0;
                }
                for (&j, &v) in idx.iter().zip(vals) {
                    row[j as usize] = v;
                }
                self.predict_into(&row, std::slice::from_mut(o));
            }
            return;
        }
        let plans = self.sparse_plans.get_or_init(|| {
            sk.instances
                .iter()
                .map(|inst| inst.func.sparse_plan_point(&sk.family))
                .collect()
        });
        let inv = (1.0 / sk.scale) as f32;
        let inv_m = 1.0 / sk.m() as f64;
        let mut vals_buf: Vec<f32> = Vec::new();
        for (i, o) in out.iter_mut().enumerate() {
            let (idx, vals) = queries.row(i);
            vals_buf.clear();
            vals_buf.extend(vals.iter().map(|&v| v * inv));
            let mut acc = 0.0f64;
            for ((inst, loads_s), plan) in sk.instances.iter().zip(&self.loads).zip(plans) {
                let (id, w) = inst.func.hash_sparse_row(idx, &vals_buf, plan, &sk.family);
                if let Some(b) = inst.table.lookup(id) {
                    acc += w as f64 * loads_s[b as usize];
                }
            }
            *o = acc * inv_m;
        }
    }
}

impl WlshSketch {
    /// Shared predict kernel: hash each query, look its bucket up in every
    /// instance, combine the precomputed loads (paper §4.2's η̃(x)).
    fn predict_with_loads(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        threads: usize,
    ) -> Vec<f64> {
        let d = self.family.d;
        let mut out = vec![0.0f64; queries.len() / d];
        self.predict_with_loads_into(loads, queries, threads, &mut out);
        out
    }

    /// As [`predict_with_loads`](Self::predict_with_loads), writing into a
    /// caller-provided buffer (one slot per query row) — the batch-serving
    /// path allocates nothing per call on the serial route.
    ///
    /// Queries are independent, so the batch is split into fixed-size
    /// chunks fanned out over `threads` workers; per-query arithmetic is
    /// untouched and results are reassembled in query order, keeping the
    /// output bit-identical to the serial loop for any thread count.
    fn predict_with_loads_into(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        threads: usize,
        out: &mut [f64],
    ) {
        // Chunk size is fixed (not derived from `threads`) so the work
        // decomposition never depends on the machine.
        let d = self.family.d;
        let nq = queries.len() / d;
        assert_eq!(out.len(), nq, "one output slot per query row");
        if threads <= 1 || nq <= SERIAL_QUERY_CHUNK {
            self.predict_query_range(loads, queries, 0, nq, out);
            return;
        }
        let n_chunks = nq.div_ceil(SERIAL_QUERY_CHUNK);
        let pieces = par::fan_out(n_chunks, threads, |c| {
            let lo = c * SERIAL_QUERY_CHUNK;
            let hi = ((c + 1) * SERIAL_QUERY_CHUNK).min(nq);
            let mut buf = vec![0.0f64; hi - lo];
            self.predict_query_range(loads, queries, lo, hi, &mut buf);
            buf
        });
        let mut off = 0;
        for p in pieces {
            out[off..off + p.len()].copy_from_slice(&p);
            off += p.len();
        }
    }

    /// Predict queries `lo..hi` of a row-major batch into `out` (the
    /// serial kernel; `out.len() == hi - lo`).
    fn predict_query_range(
        &self,
        loads: &[Vec<f64>],
        queries: &[f32],
        lo: usize,
        hi: usize,
        out: &mut [f64],
    ) {
        let d = self.family.d;
        let inv = (1.0 / self.scale) as f32;
        let inv_m = 1.0 / self.m() as f64;
        let mut q_scaled = vec![0.0f32; d];
        for (qi, o) in (lo..hi).zip(out.iter_mut()) {
            let q = &queries[qi * d..(qi + 1) * d];
            for (dst, src) in q_scaled.iter_mut().zip(q) {
                *dst = *src * inv;
            }
            let mut acc = 0.0f64;
            for (inst, loads_s) in self.instances.iter().zip(loads) {
                let (id, w) = inst.func.hash_point(&q_scaled, &self.family, self.mode);
                if let Some(b) = inst.table.lookup(id) {
                    acc += w as f64 * loads_s[b as usize];
                }
            }
            *o = acc * inv_m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::util::prop::{gens, prop_check};

    fn random_x(seed: u64, n: usize, d: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..n * d).map(|_| rng.normal() as f32).collect()
    }

    /// Materialize K̃ from mat-vecs against basis vectors.
    fn materialize(op: &dyn KrrOperator) -> Vec<Vec<f64>> {
        let n = op.n();
        (0..n)
            .map(|j| {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                op.matvec(&e)
            })
            .collect()
    }

    #[test]
    fn matvec_matches_materialized_definition() {
        // Def. 6 brute force: K̃_ij = (1/m) Σ_s w_i w_j [h_s(x_i) = h_s(x_j)]
        let (n, d, m) = (40, 3, 5);
        let x = random_x(1, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 2);
        let k = materialize(&sk);
        // brute force from the instances themselves
        for i in 0..n {
            for j in 0..n {
                let mut want = 0.0;
                for inst in &sk.instances {
                    if inst.table.bucket_of[i] == inst.table.bucket_of[j] {
                        want += inst.weights[i] as f64 * inst.weights[j] as f64;
                    }
                }
                want /= m as f64;
                assert!(
                    (k[j][i] - want).abs() < 1e-9,
                    "K[{i}][{j}] {} vs {want}",
                    k[j][i]
                );
            }
        }
    }

    #[test]
    fn sketch_is_symmetric_psd() {
        let (n, d, m) = (32, 4, 8);
        let x = random_x(3, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "rect", 2.0, 1.0, 4);
        let k = materialize(&sk);
        for i in 0..n {
            for j in 0..n {
                assert!((k[i][j] - k[j][i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // E[K̃_ij] = k_{f,p}(x_i - x_j): average many independent sketches.
        let d = 2;
        let x: Vec<f32> = vec![0.0, 0.0, 0.4, -0.3];
        let kern = Kernel::wlsh("rect", 2.0, 1.0);
        let want = kern.eval_f32(&x[0..2], &x[2..4]);
        let trials = 400;
        let mut acc = 0.0;
        let mut acc2 = 0.0;
        for t in 0..trials {
            let sk = WlshSketch::build(&x, 2, d, 8, "rect", 2.0, 1.0, 1000 + t);
            let y = sk.matvec(&[0.0, 1.0]); // column j=1
            acc += y[0];
            acc2 += y[0] * y[0];
        }
        let mean = acc / trials as f64;
        let se = ((acc2 / trials as f64 - mean * mean) / trials as f64).sqrt();
        assert!(
            (mean - want).abs() < 4.0 * se + 5e-3,
            "mean {mean} vs {want} (se {se})"
        );
    }

    #[test]
    fn predictor_matches_trait_predict() {
        let (n, d, m) = (64, 5, 10);
        let x = random_x(5, n, d);
        let sk = Arc::new(WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.5, 6));
        let mut rng = Pcg64::new(7, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let q = random_x(8, 10, d);
        let a = sk.predict(&q, &beta);
        let b = sk.clone().predictor(&beta).predict(&q);
        assert_eq!(a, b);
    }

    #[test]
    fn predict_far_query_is_zero() {
        let (n, d) = (16, 2);
        let x = random_x(9, n, d);
        let sk = WlshSketch::build(&x, n, d, 6, "rect", 2.0, 1.0, 10);
        let beta = vec![1.0; n];
        // a query 1e6 away shares no bucket with any training point
        let q = vec![1e6f32, -1e6];
        let y = sk.predict(&q, &beta);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn scale_changes_effective_kernel() {
        // wider scale ⇒ more collisions ⇒ larger quadratic form
        let (n, d) = (64, 3);
        let x = random_x(11, n, d);
        let beta = vec![1.0; n];
        let narrow = WlshSketch::build(&x, n, d, 32, "rect", 2.0, 0.25, 12);
        let wide = WlshSketch::build(&x, n, d, 32, "rect", 2.0, 4.0, 12);
        let qn: f64 = narrow.matvec(&beta).iter().sum();
        let qw: f64 = wide.matvec(&beta).iter().sum();
        assert!(qw > qn, "wide {qw} <= narrow {qn}");
    }

    #[test]
    fn parallel_matvec_and_predict_are_bit_identical() {
        let (n, d, m) = (300, 4, 64);
        let x = random_x(17, n, d);
        let sk = Arc::new(WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 18));
        let mut rng = Pcg64::new(19, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let want = sk.matvec_serial(&beta);
        for threads in [1usize, 2, 8] {
            assert_eq!(sk.matvec_threads(&beta, threads), want, "threads={threads}");
        }
        let q = random_x(20, 600, d);
        let pred = sk.clone().predictor(&beta);
        let want_p = pred.predict_threads(&q, 1);
        for threads in [2usize, 8] {
            assert_eq!(pred.predict_threads(&q, threads), want_p, "threads={threads}");
        }
    }

    #[test]
    fn fused_matches_unfused_to_reassociation_error() {
        // Same per-instance terms, different summation grouping: the fused
        // block path and the pre-fusion instance path must agree to
        // floating-point reassociation error, at every thread count.
        let (n, d, m) = (257, 5, 77); // deliberately not multiples of block sizes
        let x = random_x(23, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 24);
        let mut rng = Pcg64::new(25, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let fused = sk.matvec_serial(&beta);
        for threads in [1usize, 2, 8] {
            let unfused = sk.matvec_unfused(&beta, threads);
            for i in 0..n {
                assert!(
                    (fused[i] - unfused[i]).abs() < 1e-11 * (1.0 + fused[i].abs()),
                    "row {i} (threads={threads}): fused {} vs unfused {}",
                    fused[i],
                    unfused[i]
                );
            }
        }
    }

    #[test]
    fn diag_matches_materialized_diagonal() {
        let (n, d, m) = (48, 3, 12);
        let x = random_x(29, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 30);
        let k = materialize(&sk);
        let diag = sk.diag_values();
        for i in 0..n {
            assert!(
                (diag[i] - k[i][i]).abs() < 1e-10 * (1.0 + k[i][i].abs()),
                "diag[{i}] {} vs K_ii {}",
                diag[i],
                k[i][i]
            );
        }
        // the trait accessor exposes the same values
        assert_eq!(KrrOperator::diag(&sk), Some(diag));
    }

    #[test]
    fn range_builds_reproduce_the_full_build_exactly() {
        // Shard constructor: instances [lo, hi) of a range build must be
        // bit-identical to the same slice of the full build, including at
        // non-block-aligned cuts.
        let (n, d, m) = (120, 4, 20);
        let x = random_x(31, n, d);
        let src = crate::data::MatrixSource::new("mem", &x, d);
        let spec: BucketSpec = "smooth2".parse().unwrap();
        let full =
            WlshSketch::build_source(&src, m, &spec, 7.0, 1.0, 32, IdMode::U64, 50, 2).unwrap();
        for (lo, hi) in [(0usize, 7usize), (7, 16), (16, 20), (0, 20), (8, 16)] {
            let part = WlshSketch::build_source_range(
                &src,
                m,
                lo,
                hi,
                &spec,
                7.0,
                1.0,
                32,
                IdMode::U64,
                17,
                3,
            )
            .unwrap();
            assert_eq!(part.m(), hi - lo);
            for (k, inst) in part.instances.iter().enumerate() {
                let want = &full.instances[lo + k];
                assert_eq!(inst.weights, want.weights, "instance {} weights", lo + k);
                assert_eq!(
                    inst.table.bucket_of,
                    want.table.bucket_of,
                    "instance {} buckets",
                    lo + k
                );
            }
        }
    }

    #[test]
    fn block_partials_reassemble_into_the_exact_matvec() {
        // Coordinator-side reduction contract: accumulate the raw block
        // partials in global block order, then normalize once — must be
        // bit-identical to matvec_threads at any thread count.
        let (n, d, m) = (150, 3, 37); // m not a multiple of FUSE_BLOCK
        let x = random_x(33, n, d);
        let sk = WlshSketch::build(&x, n, d, m, "smooth2", 7.0, 1.0, 34);
        let mut rng = Pcg64::new(35, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let want = sk.matvec_serial(&beta);
        for threads in [1usize, 3] {
            let partials = sk.block_partials(&beta, threads);
            assert_eq!(partials.len(), m.div_ceil(FUSE_BLOCK));
            let mut out = vec![0.0f64; n];
            for p in &partials {
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
            let inv_m = 1.0 / m as f64;
            for v in out.iter_mut() {
                *v *= inv_m;
            }
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn predict_terms_reassemble_into_the_exact_prediction() {
        let (n, d, m) = (90, 4, 11);
        let x = random_x(37, n, d);
        let sk = Arc::new(WlshSketch::build(&x, n, d, m, "rect", 2.0, 1.0, 38));
        let mut rng = Pcg64::new(39, 0);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // include a far query so at least one row has all-miss terms
        let mut q = random_x(40, 12, d);
        q[0] = 1e6;
        let want = sk.clone().predictor(&beta).predict_threads(&q, 1);
        let loads = sk.loads_all(&beta, 1);
        let terms = sk.predict_terms(&loads, &q);
        assert_eq!(terms.len(), 12);
        let inv_m = 1.0 / m as f64;
        for (qi, row) in terms.iter().enumerate() {
            assert_eq!(row.len(), m);
            let mut acc = 0.0f64;
            for t in row.iter().flatten() {
                acc += *t;
            }
            assert_eq!(acc * inv_m, want[qi], "query {qi}");
        }
    }

    #[test]
    fn prop_matvec_linear() {
        // K̃(aα + bβ) = a K̃α + b K̃β
        prop_check(13, 10, |r| {
            let n = gens::size(r, 8, 40);
            let d = gens::size(r, 1, 5);
            let x = gens::vec_normal_f32(r, n * d);
            let alpha = gens::vec_f64(r, n, -2.0, 2.0);
            let beta = gens::vec_f64(r, n, -2.0, 2.0);
            (n, d, x, alpha, beta)
        }, |(n, d, x, alpha, beta)| {
            let sk = WlshSketch::build(x, *n, *d, 4, "smooth2", 7.0, 1.0, 21);
            let mixed: Vec<f64> = alpha
                .iter()
                .zip(beta)
                .map(|(a, b)| 2.0 * a - 0.5 * b)
                .collect();
            let lhs = sk.matvec(&mixed);
            let ya = sk.matvec(alpha);
            let yb = sk.matvec(beta);
            for i in 0..*n {
                let want = 2.0 * ya[i] - 0.5 * yb[i];
                if (lhs[i] - want).abs() > 1e-8 * (1.0 + want.abs()) {
                    return Err(format!("row {i}: {} vs {want}", lhs[i]));
                }
            }
            Ok(())
        });
    }
}
