//! Kernel-matrix operators for KRR: the paper's WLSH sketch (§4), the RFF
//! and Nyström baselines, and the exact kernel operator. All expose the
//! same [`KrrOperator`] interface so the solver/trainer/benches are
//! method-agnostic, and each operator freezes its solved β into a
//! [`Predictor`] handle for serving.

use std::sync::Arc;

use crate::data::SparseChunk;

mod exact;
mod nystrom;
mod rff;
mod wlsh;

pub use exact::ExactKernelOp;
pub use nystrom::{NystromPrecond, NystromSketch};
pub use rff::RffSketch;
pub(crate) use wlsh::SERIAL_QUERY_CHUNK;
pub use wlsh::{SamplingInfo, WlshBuildParams, WlshPredictor, WlshSketch};

/// A frozen serving handle: the β-dependent state an operator needs at
/// predict time — WLSH bucket loads (paper §4.2), RFF's θ = Zᵀβ, the
/// Nyström landmark core — owned by the handle so a prediction never
/// recomputes O(n) work. Obtained from [`KrrOperator::predictor`].
pub trait Predictor: Send + Sync {
    /// Feature count d expected per query row.
    fn dim(&self) -> usize;

    /// η̃(q_i) for each row of `queries` (row-major q×d), written into
    /// `out` (`out.len()` must equal the number of query rows) — the
    /// allocation-free batch-serving path.
    fn predict_into(&self, queries: &[f32], out: &mut [f64]);

    /// Allocating convenience over [`predict_into`](Self::predict_into).
    fn predict(&self, queries: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; queries.len() / self.dim()];
        self.predict_into(queries, &mut out);
        out
    }

    /// η̃(q_i) **and** the sketched posterior variance σ̃²(q_i) for each row
    /// of `queries`, written into `out`/`var` (both `queries.len()/dim()`
    /// long). Variance semantics, determinism, and tolerance are documented
    /// on `online::VarianceEstimator`, which backs every implementation.
    /// Default: `None` — the handle was frozen without an estimator.
    fn predict_with_var(&self, queries: &[f32], out: &mut [f64], var: &mut [f64]) -> Option<()> {
        let _ = (queries, out, var);
        None
    }

    /// η̃(q_i) for each CSR row of `queries` (`out.len()` must equal
    /// `queries.nrows()`). The default densifies one row at a time into an
    /// O(d) scratch buffer and defers to
    /// [`predict_into`](Self::predict_into); operators with a native sparse
    /// kernel (WLSH, RFF) override it to skip the scatter entirely.
    fn predict_sparse_into(&self, queries: &SparseChunk<'_>, out: &mut [f64]) {
        let d = self.dim();
        assert_eq!(out.len(), queries.nrows(), "output length mismatch");
        let mut row = vec![0.0f32; d];
        for (i, o) in out.iter_mut().enumerate() {
            let (idx, vals) = queries.row(i);
            for v in row.iter_mut() {
                *v = 0.0;
            }
            for (&j, &v) in idx.iter().zip(vals) {
                row[j as usize] = v;
            }
            self.predict_into(&row, std::slice::from_mut(o));
        }
    }
}

/// An (approximate) kernel matrix K̃ plus its out-of-sample extension —
/// everything KRR needs: products K̃β during CG, and k̃(q, X)β at predict
/// time.
pub trait KrrOperator: Send + Sync {
    /// Number of training points (K̃ is n×n).
    fn n(&self) -> usize;

    /// y = K̃ β.
    fn matvec(&self, beta: &[f64]) -> Vec<f64>;

    /// η̃(q_i) = Σ_j k̃(q_i, x_j) β_j for each row of `queries` (row-major
    /// q×d, same feature space as the training rows). One-shot path; for
    /// repeated serving use [`predictor`](Self::predictor).
    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64>;

    /// Freeze the solved β into a serving handle, precomputing the
    /// β-dependent state once (so a query costs O(m·d) for WLSH, O(D·d)
    /// for RFF, O(k·d) for Nyström).
    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor>;

    /// diag(K̃), when the operator can produce it in o(n²) time (feeds the
    /// solver's Jacobi preconditioner). Default: `None` — callers must fall
    /// back to an unpreconditioned solve or a different preconditioner.
    fn diag(&self) -> Option<Vec<f64>> {
        None
    }

    /// Cross-covariance of one query row against the training set in the
    /// operator's (sketched) geometry: `(k̃(x,x), [k̃(x, x_i)]_i)` — the
    /// ingredients of the posterior-variance estimate
    /// σ²(x) = k̃(x,x) − k̃ₓᵀ(K̃+λI)⁻¹k̃ₓ (see `online::VarianceEstimator`).
    /// Default: `None` — the operator does not support variance estimation.
    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        let _ = query;
        None
    }

    /// Human-readable method name for reports.
    fn name(&self) -> String;

    /// Approximate resident memory of the operator in bytes.
    fn memory_bytes(&self) -> usize;

    /// Importance-sampling provenance, when the operator's instances were
    /// selected out of a larger pool (leverage/stein WLSH builds): the
    /// pool size plus the kept `(index, weight)` pairs, which checkpoint
    /// headers persist verbatim so a reload replays the exact selection.
    /// Default: `None` — uniformly sampled or not a sketch.
    fn sampling_header(&self) -> Option<&SamplingInfo> {
        None
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::kernels::Kernel;
    use crate::util::rng::Pcg64;

    /// All operators must agree with a brute-force quadratic form on PSD-ness
    /// and with their own predict on the training points (self-consistency).
    fn check_operator(op: &dyn KrrOperator, x: &[f32], d: usize, tol: f64) {
        let n = op.n();
        let mut rng = Pcg64::new(99, 0);
        // PSD quadratic form
        for _ in 0..5 {
            let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = op.matvec(&beta);
            let q: f64 = beta.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!(q >= -tol, "{}: quadratic form {q}", op.name());
        }
        // predict on training rows == matvec rows
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = op.matvec(&beta);
        let p = op.predict(x, &beta);
        for i in 0..n {
            assert!(
                (y[i] - p[i]).abs() < tol * (1.0 + y[i].abs()),
                "{}: row {i}: matvec {} vs predict {}",
                op.name(),
                y[i],
                p[i]
            );
        }
        let _ = d;
    }

    #[test]
    fn operators_are_self_consistent() {
        let mut rng = Pcg64::new(5, 0);
        let (n, d) = (96, 4);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();

        let wlsh = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(n, d, 16).bucket_str("rect").gamma_shape(2.0).seed(7),
        );
        check_operator(&wlsh, &x, d, 1e-6);

        let wlsh_s = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(n, d, 16).bucket_str("smooth2").gamma_shape(7.0).seed(8),
        );
        check_operator(&wlsh_s, &x, d, 1e-5);

        let rff = RffSketch::build(&x, n, d, 128, 1.0, 9);
        check_operator(&rff, &x, d, 1e-5);

        let exact = ExactKernelOp::new(&x, n, d, Kernel::laplace(1.0));
        check_operator(&exact, &x, d, 1e-8);

        let nys = NystromSketch::build(&x, n, d, 24, Kernel::squared_exp(1.0), 11).unwrap();
        check_operator(&nys, &x, d, 1e-6);
    }

    #[test]
    fn predictor_handles_match_one_shot_predict() {
        let mut rng = Pcg64::new(6, 0);
        let (n, d) = (64, 3);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let q: Vec<f32> = (0..20 * d).map(|_| rng.normal() as f32).collect();
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ops: Vec<Arc<dyn KrrOperator>> = vec![
            Arc::new(WlshSketch::build_mem(
                &x,
                &WlshBuildParams::new(n, d, 12).bucket_str("smooth2").gamma_shape(7.0).seed(3),
            )),
            Arc::new(RffSketch::build(&x, n, d, 96, 1.0, 4)),
            Arc::new(ExactKernelOp::new(&x, n, d, Kernel::matern52(1.0))),
            Arc::new(NystromSketch::build(&x, n, d, 16, Kernel::squared_exp(1.0), 5).unwrap()),
        ];
        for op in ops {
            let want = op.predict(&q, &beta);
            let handle = Arc::clone(&op).predictor(&beta);
            assert_eq!(handle.dim(), d, "{}", op.name());
            assert_eq!(handle.predict(&q), want, "{}", op.name());
            // the allocation-free path fills a caller buffer identically
            let mut buf = vec![f64::NAN; want.len()];
            handle.predict_into(&q, &mut buf);
            assert_eq!(buf, want, "{} predict_into", op.name());
        }
    }
}
