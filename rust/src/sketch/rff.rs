//! Random Fourier Features baseline (Rahimi–Recht 2007), as benchmarked in
//! the paper's Table 2: K̃ = Z Zᵀ with Z = sqrt(2/D) cos(X Ω + b),
//! Ω columns ~ N(0, 2γ I), estimating k(x,y) = exp(-γ‖x-y‖²).

use std::sync::Arc;

use super::{KrrOperator, Predictor};
use crate::api::KrrError;
use crate::data::{Chunk, DataSource, SparseChunk};
use crate::linalg::dot_f32;
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::simd;

/// Rows per thread task when featurizing a block in parallel. Fixed (never
/// derived from the thread count) so the work decomposition — and hence
/// the output — is machine-independent; featurization is pure per row, so
/// any decomposition is bit-identical to the serial loop anyway.
const FEAT_BLOCK: usize = 256;

/// RFF sketch of the squared-exponential kernel exp(-‖x-y‖²/s²).
/// `Clone` supports the online-update path's copy-on-write
/// (`Arc::make_mut`).
#[derive(Clone)]
pub struct RffSketch {
    /// n×D row-major feature matrix.
    z: Vec<f32>,
    /// d×D row-major frequency matrix.
    omega: Vec<f32>,
    /// D phase offsets.
    b: Vec<f32>,
    n: usize,
    d: usize,
    pub dd: usize,
    feat_scale: f32,
}

impl RffSketch {
    /// Featurize the training rows: D features for bandwidth `scale`
    /// (γ = 1/scale²).
    pub fn build(x: &[f32], n: usize, d: usize, dd: usize, scale: f64, seed: u64) -> RffSketch {
        assert_eq!(x.len(), n * d);
        let mut sk = Self::empty(d, dd, scale, seed);
        sk.z = sk.featurize(x);
        sk.n = n;
        sk
    }

    /// Draw Ω and b for the bandwidth, with no rows featurized yet.
    fn empty(d: usize, dd: usize, scale: f64, seed: u64) -> RffSketch {
        let mut rng = Pcg64::new(seed, 0);
        let gamma = 1.0 / (scale * scale);
        let sd = (2.0 * gamma).sqrt();
        let omega: Vec<f32> = (0..d * dd).map(|_| (rng.normal() * sd) as f32).collect();
        let b: Vec<f32> = (0..dd)
            .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI) as f32)
            .collect();
        let feat_scale = (2.0 / dd as f64).sqrt() as f32;
        RffSketch { z: Vec::new(), omega, b, n: 0, d, dd, feat_scale }
    }

    /// Streaming build: featurize the source chunk by chunk (rows within a
    /// chunk fanned out over `workers` in fixed `FEAT_BLOCK`-row blocks),
    /// appending to the n×D feature matrix. Featurization is pure per row,
    /// so the result is bit-identical to [`build`](Self::build) on the
    /// materialized rows for every chunk size and worker count; peak
    /// transient memory is one O(chunk·d) block — the feature matrix
    /// itself *is* the sketch.
    pub fn build_source(
        src: &dyn DataSource,
        dd: usize,
        scale: f64,
        seed: u64,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<RffSketch, KrrError> {
        let d = src.dim();
        let mut sk = Self::empty(d, dd, scale, seed);
        if let Some(n) = src.len_hint() {
            sk.z.reserve(n * dd);
        }
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            match chunk {
                Chunk::Dense(rows) => sk.append_rows(rows, workers),
                Chunk::Sparse(sp) => sk.append_rows_sparse(&sp, workers),
            }
            sk.n += ys.len();
            Ok(())
        })?;
        Ok(sk)
    }

    /// Online append: featurize further rows from `src` and extend the n×D
    /// feature matrix under the already-drawn Ω and b (no RNG is consumed).
    /// Featurization is pure per row, so the grown sketch is bit-identical
    /// to a from-scratch [`build_source`](Self::build_source) over the
    /// concatenated data at every chunk size and worker count. Returns the
    /// number of rows appended.
    pub fn append_source(
        &mut self,
        src: &dyn DataSource,
        chunk_rows: usize,
        workers: usize,
    ) -> Result<usize, KrrError> {
        if src.dim() != self.d {
            return Err(KrrError::Dataset(format!(
                "append expects {} features per row, got {}",
                self.d,
                src.dim()
            )));
        }
        let before = self.n;
        src.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            match chunk {
                Chunk::Dense(rows) => self.append_rows(rows, workers),
                Chunk::Sparse(sp) => self.append_rows_sparse(&sp, workers),
            }
            self.n += ys.len();
            Ok(())
        })?;
        Ok(self.n - before)
    }

    /// Featurize a row block and append it to `z`, threading over fixed
    /// `FEAT_BLOCK`-row sub-blocks and stitching results in order.
    fn append_rows(&mut self, rows: &[f32], workers: usize) {
        let q = rows.len() / self.d;
        if workers <= 1 || q <= FEAT_BLOCK {
            let feats = self.featurize(rows);
            self.z.extend_from_slice(&feats);
            return;
        }
        let n_blocks = q.div_ceil(FEAT_BLOCK);
        let pieces = par::fan_out(n_blocks, workers, |b| {
            let lo = b * FEAT_BLOCK;
            let hi = ((b + 1) * FEAT_BLOCK).min(q);
            self.featurize(&rows[lo * self.d..hi * self.d])
        });
        for p in pieces {
            self.z.extend_from_slice(&p);
        }
    }

    /// Featurize a CSR row block and append it to `z` — the sparse
    /// analogue of [`append_rows`](Self::append_rows), threading over the
    /// same fixed `FEAT_BLOCK`-row sub-blocks (sub-views slice `indptr`
    /// only; offsets are absolute into the block's `indices`/`values`).
    fn append_rows_sparse(&mut self, sp: &SparseChunk<'_>, workers: usize) {
        let q = sp.nrows();
        if workers <= 1 || q <= FEAT_BLOCK {
            let feats = self.featurize_sparse(sp);
            self.z.extend_from_slice(&feats);
            return;
        }
        let n_blocks = q.div_ceil(FEAT_BLOCK);
        let pieces = par::fan_out(n_blocks, workers, |b| {
            let lo = b * FEAT_BLOCK;
            let hi = ((b + 1) * FEAT_BLOCK).min(q);
            let sub = SparseChunk {
                indptr: &sp.indptr[lo..=hi],
                indices: sp.indices,
                values: sp.values,
            };
            self.featurize_sparse(&sub)
        });
        for p in pieces {
            self.z.extend_from_slice(&p);
        }
    }

    /// The n×D feature matrix Z (row-major) — exposed for equivalence
    /// tests and diagnostics.
    pub fn features(&self) -> &[f32] {
        &self.z
    }

    /// φ(rows) for row-major input (q×d) → q×D features.
    pub fn featurize(&self, rows: &[f32]) -> Vec<f32> {
        let q = rows.len() / self.d;
        let mut out = vec![0.0f32; q * self.dd];
        for i in 0..q {
            let xi = &rows[i * self.d..(i + 1) * self.d];
            let zi = &mut out[i * self.dd..(i + 1) * self.dd];
            zi.copy_from_slice(&self.b);
            // zi += xiᵀ Ω, streaming over the d rows of Ω (SIMD axpy — one
            // mul + one add per element, bit-identical to the scalar loop)
            for (l, &xl) in xi.iter().enumerate() {
                if xl == 0.0 {
                    continue;
                }
                let orow = &self.omega[l * self.dd..(l + 1) * self.dd];
                simd::axpy_f32(xl, orow, zi);
            }
            simd::scale_cos(self.feat_scale, zi);
        }
        out
    }

    /// φ(rows) for CSR input (q rows) → q×D features.
    ///
    /// Bit-identical to [`featurize`](Self::featurize) on the densified
    /// rows: the dense kernel accumulates `z += x_l · Ω_l` over dims in
    /// ascending order skipping `x_l == 0.0`, and a CSR row walks exactly
    /// those dims in the same order (indices are ascending and unique;
    /// explicitly stored zeros are skipped the same way) — so the f32
    /// accumulation sequence per feature is identical, in O(nnz·D) per
    /// row instead of O(d·D).
    pub fn featurize_sparse(&self, rows: &SparseChunk<'_>) -> Vec<f32> {
        let q = rows.nrows();
        let mut out = vec![0.0f32; q * self.dd];
        for i in 0..q {
            let (idx, vals) = rows.row(i);
            let zi = &mut out[i * self.dd..(i + 1) * self.dd];
            zi.copy_from_slice(&self.b);
            for (&l, &xl) in idx.iter().zip(vals) {
                if xl == 0.0 {
                    continue;
                }
                let orow = &self.omega[l as usize * self.dd..(l as usize + 1) * self.dd];
                simd::axpy_f32(xl, orow, zi);
            }
            simd::scale_cos(self.feat_scale, zi);
        }
        out
    }

    /// Cross-covariance of one query against the training set in the
    /// sketched geometry: `(k̃(x,x), k̃ₓ)` with k̃(x,x) = ‖z(x)‖² and
    /// (k̃ₓ)_i = z(x_i)ᵀz(x) — one featurize plus one pass over Z.
    pub fn cross_vector(&self, query: &[f32]) -> (f64, Vec<f64>) {
        assert_eq!(query.len(), self.d, "query must have d features");
        let zq = self.featurize(query);
        let kxx = zq.iter().map(|&v| v as f64 * v as f64).sum();
        let v = (0..self.n)
            .map(|i| dot_f32(&self.z[i * self.dd..(i + 1) * self.dd], &zq) as f64)
            .collect();
        (kxx, v)
    }

    /// θ = Zᵀ β (feature-space coefficients; predict is φ(q)ᵀθ).
    pub fn theta(&self, beta: &[f64]) -> Vec<f64> {
        let mut theta = vec![0.0f64; self.dd];
        for i in 0..self.n {
            let zi = &self.z[i * self.dd..(i + 1) * self.dd];
            let bi = beta[i];
            if bi == 0.0 {
                continue;
            }
            simd::axpy_f32_f64(bi, zi, &mut theta);
        }
        theta
    }
}

impl KrrOperator for RffSketch {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        assert_eq!(beta.len(), self.n);
        let theta = self.theta(beta);
        let theta32: Vec<f32> = theta.iter().map(|&t| t as f32).collect();
        (0..self.n)
            .map(|i| dot_f32(&self.z[i * self.dd..(i + 1) * self.dd], &theta32))
            .collect()
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let theta32: Vec<f32> = self.theta(beta).iter().map(|&t| t as f32).collect();
        let zq = self.featurize(queries);
        let q = queries.len() / self.d;
        (0..q)
            .map(|i| dot_f32(&zq[i * self.dd..(i + 1) * self.dd], &theta32))
            .collect()
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        let theta32: Vec<f32> = self.theta(beta).iter().map(|&t| t as f32).collect();
        Box::new(RffPredictor { sketch: self, theta32 })
    }

    fn diag(&self) -> Option<Vec<f64>> {
        // diag(Z Zᵀ)_ii = ‖z_i‖² — one pass over the feature matrix.
        Some(
            (0..self.n)
                .map(|i| {
                    self.z[i * self.dd..(i + 1) * self.dd]
                        .iter()
                        .map(|&v| v as f64 * v as f64)
                        .sum()
                })
                .collect(),
        )
    }

    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        Some(RffSketch::cross_vector(self, query))
    }

    fn name(&self) -> String {
        format!("rff(D={})", self.dd)
    }

    fn memory_bytes(&self) -> usize {
        (self.z.len() + self.omega.len() + self.b.len()) * 4
    }
}

/// Frozen RFF serving handle: θ = Zᵀβ in f32, so a prediction is one
/// featurize + dot per query.
pub struct RffPredictor {
    sketch: Arc<RffSketch>,
    theta32: Vec<f32>,
}

impl Predictor for RffPredictor {
    fn dim(&self) -> usize {
        self.sketch.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        let dd = self.sketch.dd;
        let zq = self.sketch.featurize(queries);
        assert_eq!(out.len(), queries.len() / self.sketch.d);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot_f32(&zq[i * dd..(i + 1) * dd], &self.theta32);
        }
    }

    /// Native sparse serve path: featurize CSR rows directly (bit-identical
    /// to densifying first — see [`RffSketch::featurize_sparse`]) and dot
    /// against θ.
    fn predict_sparse_into(&self, queries: &SparseChunk<'_>, out: &mut [f64]) {
        let dd = self.sketch.dd;
        assert_eq!(out.len(), queries.nrows(), "one output slot per query row");
        let zq = self.sketch.featurize_sparse(queries);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot_f32(&zq[i * dd..(i + 1) * dd], &self.theta32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;

    #[test]
    fn features_are_bounded() {
        let mut rng = Pcg64::new(1, 0);
        let (n, d, dd) = (20, 3, 64);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let sk = RffSketch::build(&x, n, d, dd, 1.0, 2);
        let bound = (2.0 / dd as f64).sqrt() as f32 + 1e-6;
        assert!(sk.z.iter().all(|&v| v.abs() <= bound));
    }

    #[test]
    fn inner_products_approximate_se_kernel() {
        let mut rng = Pcg64::new(3, 0);
        let (n, d, dd) = (30, 4, 16384);
        let x: Vec<f32> = (0..n * d).map(|_| (rng.normal() * 0.5) as f32).collect();
        let sk = RffSketch::build(&x, n, d, dd, 1.0, 4);
        let kern = Kernel::squared_exp(1.0);
        for i in 0..5 {
            for j in 0..5 {
                let zi = &sk.z[i * dd..(i + 1) * dd];
                let zj = &sk.z[j * dd..(j + 1) * dd];
                let k_hat = dot_f32(zi, zj);
                let k_true = kern.eval_f32(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]);
                assert!(
                    (k_hat - k_true).abs() < 0.04,
                    "pair ({i},{j}): {k_hat} vs {k_true}"
                );
            }
        }
    }

    #[test]
    fn matvec_equals_z_zt_beta() {
        let mut rng = Pcg64::new(5, 0);
        let (n, d, dd) = (16, 2, 32);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let sk = RffSketch::build(&x, n, d, dd, 1.0, 6);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = sk.matvec(&beta);
        for i in 0..n {
            let mut want = 0.0f64;
            for j in 0..n {
                let kij = dot_f32(&sk.z[i * dd..(i + 1) * dd], &sk.z[j * dd..(j + 1) * dd]);
                want += kij * beta[j];
            }
            assert!((y[i] - want).abs() < 1e-4 * (1.0 + want.abs()), "row {i}");
        }
    }

    #[test]
    fn diag_matches_matvec_columns() {
        // diag(ZZᵀ) from row norms must equal the materialized diagonal.
        let mut rng = Pcg64::new(11, 0);
        let (n, d, dd) = (18, 3, 48);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let sk = RffSketch::build(&x, n, d, dd, 1.1, 12);
        let diag = KrrOperator::diag(&sk).unwrap();
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = sk.matvec(&e);
            assert!(
                (diag[j] - col[j]).abs() < 1e-5 * (1.0 + col[j].abs()),
                "diag[{j}] {} vs K_jj {}",
                diag[j],
                col[j]
            );
        }
    }

    #[test]
    fn sparse_featurize_is_bit_identical_to_dense() {
        let (d, dd) = (7, 32);
        let sk = RffSketch::empty(d, dd, 1.0, 13);
        // four CSR rows: a generic row, an empty row, a row holding an
        // explicit 0.0, and a full row
        let indptr = [0usize, 3, 3, 5, 9];
        let indices: Vec<u32> = vec![0, 2, 6, 1, 4, 0, 3, 5, 6];
        let values: Vec<f32> = vec![0.5, -1.25, 2.0, 1.5, 0.0, -0.75, 0.25, 3.5, -2.0];
        let sp = SparseChunk { indptr: &indptr, indices: &indices, values: &values };
        let mut dense = Vec::new();
        sp.densify_into(d, &mut dense);
        assert_eq!(sk.featurize_sparse(&sp), sk.featurize(&dense));
    }

    #[test]
    fn predict_on_train_matches_matvec() {
        let mut rng = Pcg64::new(7, 0);
        let (n, d, dd) = (24, 3, 64);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let sk = RffSketch::build(&x, n, d, dd, 1.3, 8);
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y = sk.matvec(&beta);
        let p = sk.predict(&x, &beta);
        for i in 0..n {
            assert!((y[i] - p[i]).abs() < 1e-5 * (1.0 + y[i].abs()));
        }
    }
}
