//! `wlsh-krr` CLI — train, evaluate, and serve WLSH-accelerated KRR models.
//!
//! Subcommands:
//!   info                         artifact + platform report
//!   train   [--dataset wine --method wlsh --budget 450 ...]
//!   serve   [--dataset wine --addr 127.0.0.1:7878 ...]
//!   ose     [--n 256 --m 64 --lambda 1.0]   OSE spectral check (Thm 11)
//!   gp      [--cov se --dim 5]              Table-1-style GP experiment
//!
//! All method/bucket/precond/kernel strings parse through the spec enums
//! in [`wlsh_krr::api`]; a typo prints one error line on stderr and exits
//! with code 2 (usage) — runtime failures exit with code 1.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use wlsh_krr::api::{BucketSpec, KernelSpec, KrrError, KrrModel, MethodSpec, PrecondSpec};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{
    checkpoint, run_worker, serve, ModelRegistry, ServerConfig, Trainer, DEFAULT_MODEL,
};
use wlsh_krr::data::{
    head_sample, head_sample_sparse, load_csv, rmse, synthetic_by_name, CsvSource, DataSource,
    DensifySource, LibsvmSource, Standardizer,
};
use wlsh_krr::kernels::Kernel;
use wlsh_krr::risk::ose_epsilon_dense;
use wlsh_krr::runtime::Runtime;
use wlsh_krr::sketch::{ExactKernelOp, WlshBuildParams, WlshSketch};
use wlsh_krr::solver::materialize;
use wlsh_krr::util::cli::Args;
use wlsh_krr::util::json::JsonWriter;
use wlsh_krr::util::rng::Pcg64;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "info" => {
            cmd_info(&args);
            Ok(())
        }
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "ose" => cmd_ose(&args),
        "gp" => cmd_gp(&args),
        "shard-worker" => {
            run_worker(args.get_or("addr", "127.0.0.1:0"), None)
        }
        other => {
            eprintln!(
                "wlsh-krr {} — Scaling up KRR via Locality Sensitive Hashing\n\
                 usage: wlsh-krr <info|train|serve|shard-worker|ose|gp> [--flags]\n\
                 \n\
                 train  --dataset wine|insurance|ctslices|covtype|<csv path>\n\
                        --method wlsh|rff|exact-laplace|exact-se|exact-matern|nystrom\n\
                        --budget M --scale S --lambda L --n-max N --seed K\n\
                        --precond none|jacobi|nystrom --precond-rank R\n\
                        --cg-verbose=true  (per-iteration CG progress on stderr)\n\
                        --data-format csv|libsvm --chunk-rows R  (streamed\n\
                        out-of-core training from --dataset <path>)\n\
                        --libsvm-base auto|0|1  (LIBSVM feature-index base;\n\
                        auto = 0-based iff an index 0 appears)\n\
                        --sparse auto|true|false  (stream native CSR chunks;\n\
                        auto = whatever the source emits)\n\
                        --sampling uniform|leverage(pilot=P,keep=K)|stein\n\
                        (importance-sample the m-instance WLSH pool:\n\
                        leverage keeps the K highest-leverage instances,\n\
                        reweighted; stein keeps all m with leverage-\n\
                        proportional weights)\n\
                        --checkpoint-out PATH  (save the trained model)\n\
                        --topology local|shards(n=N)|remote(addr=H:P,...)\n\
                        (shard the m WLSH instances over worker processes;\n\
                        beta is bit-identical at every shard count)\n\
                 serve  same dataset/method flags plus --addr HOST:PORT\n\
                        --workers N --queue-depth Q --max-batch B --linger-us U\n\
                        --model name=ckpt[,name=ckpt...]  (serve saved\n\
                        checkpoints instead of training; same dataset flags\n\
                        as the `train` run that wrote them)\n\
                        wlsh/rff models serve with online appends enabled:\n\
                        the wire accepts {\"cmd\":\"append\",...} updates and\n\
                        \"var\":true uncertainty-flagged predictions\n\
                 shard-worker  --addr HOST:PORT  (one shard of a\n\
                        distributed topology; spawned automatically by\n\
                        shards(n=N), run by hand for remote(...))\n\
                 ose    --n N --m M --lambda L --bucket rect|smooth2\n\
                 gp     --cov laplace|se|matern --dim D --n N\n\
                 \n\
                 env    WLSH_THREADS=N  worker threads (default: all cores)\n\
                        WLSH_SIMD=auto|on|off  vectorized kernels (default\n\
                        auto-detect; off = scalar reference — results are\n\
                        bit-identical either way)",
                wlsh_krr::version()
            );
            // asking for help is fine; an unknown subcommand is misuse
            if other != "help" && other != "--help" {
                std::process::exit(2);
            }
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(e.exit_code());
    }
}

/// `--key spec-string` parsed through the spec's `FromStr`, defaulting
/// when the flag is absent — the same grammar the TOML reader and
/// checkpoint headers use.
fn spec_flag<T>(args: &Args, key: &str, default: T) -> Result<T, KrrError>
where
    T: std::str::FromStr<Err = KrrError>,
{
    match args.get(key) {
        Some(s) => s.parse(),
        None => Ok(default),
    }
}

fn load_dataset(args: &Args) -> Result<wlsh_krr::data::Dataset, KrrError> {
    let name = args.get_or("dataset", "wine");
    let n_max = match args.get("n-max") {
        Some(v) => Some(v.parse().map_err(|_| {
            KrrError::BadParam(format!("--n-max wants an integer, got {v:?}"))
        })?),
        None => None,
    };
    let seed = args.get_usize("seed", 42) as u64;
    let mut ds = if name.ends_with(".csv") {
        load_csv(name, -1, name)?
    } else {
        synthetic_by_name(name, n_max, seed)
            .ok_or_else(|| KrrError::UnknownDataset(name.to_string()))?
    };
    ds.standardize();
    Ok(ds)
}

/// Assemble a [`KrrConfig`] from CLI flags. Every fallback value defers to
/// the one [`KrrConfig::default`] impl — the CLI has no defaults of its
/// own.
fn config_from(args: &Args) -> Result<KrrConfig, KrrError> {
    let d = KrrConfig::default();
    let raw_precond = args.get("precond");
    let mut precond = spec_flag(args, "precond", d.precond)?;
    // --precond-rank fills in a bare `nystrom`; an explicit
    // nystrom(rank=R) spec wins over the separate flag
    if raw_precond == Some("nystrom") {
        if let PrecondSpec::Nystrom { rank } = &mut precond {
            *rank = args.get_usize("precond-rank", *rank);
        }
    }
    Ok(KrrConfig {
        method: spec_flag(args, "method", d.method)?,
        budget: args.get_usize("budget", d.budget),
        bucket: spec_flag(args, "bucket", d.bucket)?,
        gamma_shape: args.get_f64("gamma-shape", d.gamma_shape),
        scale: args.get_f64("scale", d.scale),
        lambda: args.get_f64("lambda", d.lambda),
        cg_max_iters: args.get_usize("cg-max-iters", d.cg_max_iters),
        cg_tol: args.get_f64("cg-tol", d.cg_tol),
        precond,
        cg_verbose: args.get_bool("cg-verbose"),
        workers: args.get_usize("workers", d.workers),
        chunk_rows: args.get_usize("chunk-rows", d.chunk_rows),
        seed: args.get_usize("seed", d.seed as usize) as u64,
        topology: spec_flag(args, "topology", d.topology)?,
        sampling: spec_flag(args, "sampling", d.sampling)?,
    })
}

fn cmd_info(_args: &Args) {
    println!("wlsh-krr {}", wlsh_krr::version());
    println!(
        "simd: {} (detected: {}, override via WLSH_SIMD=auto|on|off)",
        wlsh_krr::util::simd::active_name(),
        wlsh_krr::util::simd::name(wlsh_krr::util::simd::detected()),
    );
    match Runtime::open_default() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            let mut names: Vec<_> = rt.manifest.entries.keys().collect();
            names.sort();
            println!("artifacts ({}):", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("runtime unavailable: {e} (native backend only)"),
    }
}

/// FNV-1a over the solved β's little-endian bytes — a cheap fingerprint
/// for the bit-identity contract (the CI shard smoke compares it between
/// single-process and sharded runs of the same config).
fn beta_hash(beta: &[f64]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in beta {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    format!("{h:016x}")
}

/// Append the shared [`TrainReport`] diagnostics fields to a JSON record
/// (one block for both the in-memory and streamed train outputs).
fn report_fields(w: JsonWriter, rep: &wlsh_krr::coordinator::TrainReport) -> JsonWriter {
    w.field_f64("build_secs", rep.build_secs)
        .field_f64("solve_secs", rep.solve_secs)
        .field_usize("cg_iters", rep.cg_iters)
        .field_f64("cg_rel_residual", rep.cg_rel_residual)
        .field_str("precond", &rep.precond)
        .field_usize("memory_bytes", rep.memory_bytes)
        .field_f64("rows_per_sec", rep.rows_per_sec)
        .field_usize("peak_rss_bytes", rep.peak_rss_bytes)
}

fn cmd_train(args: &Args) -> Result<(), KrrError> {
    if let Some(format) = args.get("data-format") {
        return cmd_train_streamed(args, format);
    }
    let ds = load_dataset(args)?;
    let cfg = config_from(args)?;
    let n_train = args.get_usize("n-train", (ds.n * 3) / 4);
    let (tr, te) = ds.split(n_train.min(ds.n - 1), cfg.seed);
    eprintln!(
        "training {} on {} (n={}, d={}, test={})",
        cfg.method, ds.name, tr.n, tr.d, te.n
    );
    let model = Trainer::new(cfg).train(&tr)?;
    if let Some(path) = args.get("checkpoint-out") {
        checkpoint::save(&model, std::path::Path::new(path))
            .map_err(|e| KrrError::Io(format!("{path}: {e}")))?;
        eprintln!("checkpoint written to {path}");
    }
    let pred = model.predict(&te.x);
    let err = rmse(&pred, &te.y);
    let rep = &model.report;
    let record = JsonWriter::object()
        .field_str("dataset", &ds.name)
        .field_str("operator", &rep.operator)
        .field_str("method", &model.config.method.to_string())
        .field_str("topology", &model.config.topology.to_string())
        .field_str("beta_hash", &beta_hash(&model.beta))
        .field_f64("rmse", err);
    println!("{}", report_fields(record, rep).finish());
    Ok(())
}

/// Open a file-backed chunked source by format name. The format and
/// `--libsvm-base` checks run before any filesystem access so a typo
/// exits 2 without touching the path.
fn open_source(args: &Args, path: &str, format: &str) -> Result<Box<dyn DataSource>, KrrError> {
    match format {
        "csv" => Ok(Box::new(CsvSource::open(path, -1)?)),
        "libsvm" => {
            // pin the index base explicitly when the convention is known —
            // the auto heuristic decodes a 0-based file that never mentions
            // index 0 shifted one column left
            let base = match args.get_or("libsvm-base", "auto") {
                "auto" => None,
                "0" => Some(true),
                "1" => Some(false),
                other => {
                    return Err(KrrError::BadParam(format!(
                        "--libsvm-base wants auto|0|1, got {other:?}"
                    )))
                }
            };
            let src = match base {
                None => LibsvmSource::open(path)?,
                Some(zero_based) => LibsvmSource::open_with_base(path, zero_based)?,
            };
            Ok(Box::new(src))
        }
        other => Err(KrrError::BadParam(format!(
            "--data-format wants csv|libsvm, got {other:?}"
        ))),
    }
}

/// Streamed out-of-core training: fit a Welford standardizer on the file
/// (pass 1), then train chunk by chunk through the standardized view —
/// the n×d matrix is never materialized. Sparse-native sources (LIBSVM)
/// stream CSR chunks end to end unless `--sparse=false` forces the dense
/// path; see the data-module docs for the scale-only standardization
/// sparse streams use. The reported RMSE is over a held-in-memory sample
/// of the first `--eval-rows` *training* rows (streamed runs keep no
/// split).
fn cmd_train_streamed(args: &Args, format: &str) -> Result<(), KrrError> {
    let cfg = config_from(args)?;
    // surface --chunk-rows 0 etc. as usage errors before touching the file
    cfg.validate()?;
    let sparse_flag = args.get_or("sparse", "auto");
    if !matches!(sparse_flag, "auto" | "true" | "false") {
        return Err(KrrError::BadParam(format!(
            "--sparse wants auto|true|false, got {sparse_flag:?}"
        )));
    }
    let path = args.get("dataset").ok_or_else(|| {
        KrrError::BadParam("--data-format needs --dataset <path>".to_string())
    })?;
    let src = open_source(args, path, format)?;
    let sparse = match sparse_flag {
        "auto" => src.is_sparse(),
        "true" => {
            if !src.is_sparse() {
                return Err(KrrError::BadParam(format!(
                    "--sparse=true needs a sparse-capable source; {format} streams dense rows"
                )));
            }
            true
        }
        _ => false,
    };
    let densified;
    let src_ref: &dyn DataSource = if sparse {
        src.as_ref()
    } else {
        // force Chunk::Dense (and the centered standardization that goes
        // with it) even when the file is sparse-native
        densified = DensifySource::new(src.as_ref());
        &densified
    };
    let standardizer = Standardizer::fit(src_ref, cfg.chunk_rows)?;
    let view = standardizer.source(src_ref);
    eprintln!(
        "training {} streamed from {} (d={}, rows={}, chunk={}, {})",
        cfg.method,
        path,
        view.dim(),
        view.len_hint().unwrap_or(0),
        cfg.chunk_rows,
        if sparse { "sparse CSR chunks" } else { "dense chunks" }
    );
    let chunk_rows = cfg.chunk_rows;
    let model = Trainer::new(cfg).train_source(&view)?;
    let eval_rows = args.get_usize("eval-rows", 1000);
    let err = if sparse {
        let sample = head_sample_sparse(&view, eval_rows, chunk_rows)?;
        let mut pred = vec![0.0f64; sample.n()];
        model.predict_sparse_into(&sample.view(), &mut pred);
        rmse(&pred, &sample.y)
    } else {
        let sample = head_sample(&view, eval_rows, chunk_rows)?;
        rmse(&model.predict(&sample.x), &sample.y)
    };
    let rep = &model.report;
    let record = JsonWriter::object()
        .field_str("dataset", path)
        .field_str("data_format", format)
        .field_raw("sparse", if sparse { "true" } else { "false" })
        .field_str("operator", &rep.operator)
        .field_str("method", &model.config.method.to_string())
        .field_usize("n_train", model.beta.len())
        .field_usize("chunk_rows", chunk_rows)
        .field_str("beta_hash", &beta_hash(&model.beta))
        .field_f64("train_sample_rmse", err);
    println!("{}", report_fields(record, rep).finish());
    Ok(())
}

/// Parse `--model name=path[,name=path...]` (usage errors surface before
/// any dataset or checkpoint I/O).
fn parse_model_specs(spec: &str) -> Result<Vec<(String, String)>, KrrError> {
    spec.split(',')
        .map(|part| {
            let (name, path) = part.split_once('=').ok_or_else(|| {
                KrrError::BadParam(format!("--model wants name=path, got {part:?}"))
            })?;
            if name.is_empty() || path.is_empty() {
                return Err(KrrError::BadParam(format!(
                    "--model wants name=path, got {part:?}"
                )));
            }
            Ok((name.to_string(), path.to_string()))
        })
        .collect()
}

fn cmd_serve(args: &Args) -> Result<(), KrrError> {
    // validate the model specs before touching data or training anything
    let model_specs = match args.get("model") {
        Some(spec) => Some(parse_model_specs(spec)?),
        None => None,
    };
    let ds = load_dataset(args)?;
    let cfg = config_from(args)?;
    let n_train = args.get_usize("n-train", (ds.n * 3) / 4);
    let (tr, _) = ds.split(n_train.min(ds.n - 1), cfg.seed);
    // checkpoints rebuild their sketch against the training split, so the
    // loader (used by --model and the `reload` protocol command) closes
    // over it
    let tr = Arc::new(tr);
    let loader_tr = tr.clone();
    let registry = Arc::new(ModelRegistry::with_loader(Box::new(move |path: &str| {
        checkpoint::load(std::path::Path::new(path), &loader_tr).map(Arc::new)
    })));
    match model_specs {
        Some(specs) => {
            for (name, path) in &specs {
                let model = checkpoint::load(std::path::Path::new(path), &tr)?;
                // beta_hash lets the CI checkpoint smoke assert the reload
                // reproduced the trained coefficients bit-for-bit
                eprintln!(
                    "loaded model {name:?} from {path} ({}, beta_hash {})",
                    model.report.operator,
                    beta_hash(&model.beta)
                );
                registry.insert(name, Arc::new(model));
            }
        }
        None => {
            // attach the online-update handle when the method has an
            // incremental formulation (wlsh/rff, non-nystrom precond), so
            // `{"cmd":"append",...}` works out of the box; other methods
            // serve a frozen model through the identical train path
            let supports_online = matches!(cfg.method, MethodSpec::Wlsh | MethodSpec::Rff)
                && !matches!(cfg.precond, PrecondSpec::Nystrom { .. })
                && cfg.validate().is_ok();
            if supports_online {
                let online = KrrModel::builder().config(cfg).fit_online(&tr)?;
                let model = online.model();
                eprintln!(
                    "model trained ({}); serving as {DEFAULT_MODEL:?} with online appends",
                    model.report.operator
                );
                registry.insert(DEFAULT_MODEL, model);
                registry.attach_online(DEFAULT_MODEL, Arc::new(Mutex::new(online)))?;
            } else {
                let model = Trainer::new(cfg).train(&tr)?;
                eprintln!(
                    "model trained ({}); serving as {DEFAULT_MODEL:?}",
                    model.report.operator
                );
                registry.insert(DEFAULT_MODEL, Arc::new(model));
            }
        }
    }
    let scfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:7878").to_string(),
        max_batch: args.get_usize("max-batch", 64),
        linger: Duration::from_micros(args.get_usize("linger-us", 500) as u64),
        workers: args.get_usize("workers", wlsh_krr::util::par::num_threads()),
        queue_depth: args.get_usize("queue-depth", 1024),
    };
    // serve() on a thread so the bound address (port 0 resolves at bind
    // time) can be announced on stderr for scripts/tests to scrape
    let (tx, rx) = std::sync::mpsc::channel();
    let workers = scfg.workers;
    let depth = scfg.queue_depth;
    let handle = std::thread::spawn(move || serve(registry, scfg, Some(tx)));
    if let Ok(addr) = rx.recv() {
        eprintln!("listening on {addr} ({workers} workers, queue depth {depth})");
    }
    handle
        .join()
        .map_err(|_| KrrError::Io("server thread panicked".to_string()))?
        .map_err(|e| KrrError::Io(e.to_string()))?;
    Ok(())
}

fn cmd_ose(args: &Args) -> Result<(), KrrError> {
    let n = args.get_usize("n", 256);
    let m = args.get_usize("m", 64);
    let d = args.get_usize("dim", 2);
    let lambda = args.get_f64("lambda", 1.0);
    let bucket: BucketSpec = spec_flag(args, "bucket", BucketSpec::Rect)?;
    let shape = if bucket == BucketSpec::Rect { 2.0 } else { 7.0 };
    let seed = args.get_usize("seed", 1) as u64;
    let mut rng = Pcg64::new(seed, 0);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh_spec(&bucket, shape, 1.0));
    let k = materialize(&exact);
    let sk = WlshSketch::build_mem(
        &x,
        &WlshBuildParams::new(n, d, m).bucket(bucket).gamma_shape(shape).seed(seed + 1),
    );
    let rep = ose_epsilon_dense(&k, &sk, lambda);
    println!(
        "{}",
        JsonWriter::object()
            .field_usize("n", n)
            .field_usize("m", m)
            .field_f64("lambda", lambda)
            .field_str("bucket", &bucket.to_string())
            .field_f64("eps", rep.eps)
            .field_f64("lambda_min", rep.lambda_min)
            .field_f64("lambda_max", rep.lambda_max)
            .finish()
    );
    Ok(())
}

fn cmd_gp(args: &Args) -> Result<(), KrrError> {
    let cov = args.get_or("cov", "se");
    let d = args.get_usize("dim", 5);
    let n = args.get_usize("n", 800);
    let n_train = (n * 3) / 4;
    let seed = args.get_usize("seed", 1) as u64;
    let kernel_spec: KernelSpec = cov.parse()?;
    let kernel = kernel_spec.build();
    let mut rng = Pcg64::new(seed, 0);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32).collect();
    let path = wlsh_krr::gp::sample_gp_exact(&kernel, &pts, d, &mut rng)
        .map_err(KrrError::SolveFailed)?;
    let noisy: Vec<f64> = path.iter().map(|v| v + 0.1 * rng.normal()).collect();
    let ds = wlsh_krr::data::Dataset::new(&format!("gp-{cov}"), pts, noisy, d);
    let (tr, te) = ds.split(n_train, seed + 1);
    for method in ["exact-laplace", "exact-se", "exact-matern", "exact-wlsh"] {
        let method: MethodSpec = method.parse()?;
        let cfg = KrrConfig {
            method,
            bucket: BucketSpec::Smooth(2),
            gamma_shape: 7.0,
            scale: args.get_f64("scale", 1.0),
            lambda: args.get_f64("lambda", 0.05),
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr)?;
        let pred = model.predict(&te.x);
        println!(
            "{}",
            JsonWriter::object()
                .field_str("cov", cov)
                .field_usize("dim", d)
                .field_str("method", &method.to_string())
                .field_f64("rmse", rmse(&pred, &te.y))
                .finish()
        );
    }
    Ok(())
}
