//! # wlsh-krr
//!
//! Production-grade reproduction of *"Scaling up Kernel Ridge Regression
//! via Locality Sensitive Hashing"* (Kapralov, Nouri, Razenshteyn,
//! Velingker, Zandieh — AISTATS 2020).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the compute hot
//!   spots — WLSH hashing + bucket weights, RFF features, blockwise exact
//!   kernel mat-vecs — AOT-lowered to HLO text.
//! * **L2** (`python/compile/model.py`): JAX graphs composing the kernels
//!   (notably the O(n·m) WLSH sketch mat-vec of paper §4).
//! * **L3** (this crate): the coordinator — LSH bucket tables, CG-based KRR
//!   training, a batched prediction service, benchmarks reproducing every
//!   table in the paper, and the PJRT runtime describing the AOT artifacts
//!   (no execution backend is linked yet — the `pjrt` cargo feature is
//!   inert scaffolding — so every runtime consumer skips cleanly).
//!
//! Python never runs on the request path: the Rust binary is
//! self-contained, builds with **zero external crates** (the substrates
//! under [`util`] replace `rand`/`serde_json`/`clap`/`proptest`/
//! `criterion`/`rayon`), and its WLSH hot paths — sketch build, the K̃β
//! mat-vec inside CG, bucket-load preparation, and batch prediction — fan
//! out over scoped worker threads ([`util::par`]) with reductions in fixed
//! instance order, so parallel results are bit-identical to the serial
//! reference at every thread count (see `tests/parallel_determinism.rs`).
//! Thread budget: `WLSH_THREADS` env var, default = available cores.
//! The inner kernels of those hot paths (bucket-load CSR walks, the fused
//! mat-vec's gather pass, RFF featurization, hash-cell evaluation) are
//! runtime-dispatched SIMD ([`util::simd`]: AVX2 on x86_64, NEON on
//! aarch64, still zero external crates) behind the `WLSH_SIMD` env var —
//! `auto` (default) detects, `off` forces the scalar reference — and every
//! vectorized kernel is **bit-identical** to its scalar fallback (fixed
//! 4-lane-strided reductions, no FMA contraction, a shared deterministic
//! cosine), so `WLSH_SIMD` changes throughput, never results
//! (`tests/simd_equivalence.rs`).
//!
//! ## Entry points
//!
//! The front door is the typed builder in [`api`]:
//!
//! ```no_run
//! use wlsh_krr::api::{KrrModel, MethodSpec};
//! # let train = wlsh_krr::data::synthetic_by_name("wine", Some(500), 1).unwrap();
//! let model = KrrModel::builder()
//!     .method(MethodSpec::Wlsh)   // or .method("wlsh")
//!     .budget(450)
//!     .scale(3.0)
//!     .lambda(0.5)
//!     .fit(&train)?;              // Err(KrrError), never a panic
//! let preds = model.predict(&train.x);
//! # Ok::<(), wlsh_krr::api::KrrError>(())
//! ```
//!
//! Every method/bucket/preconditioner/kernel/sampling choice is a spec
//! enum ([`api::MethodSpec`], [`api::BucketSpec`], [`api::PrecondSpec`],
//! [`api::KernelSpec`], [`api::SamplingSpec`]) with one
//! `FromStr`/`Display` grammar shared by the CLI, the TOML subset, and
//! checkpoint headers — misspelled strings surface as [`api::KrrError`]
//! values. A trained model serves through a frozen [`api::Predictor`]
//! handle (`predict` / allocation-free `predict_into`), which is what the
//! TCP server and the benches use. `fit_online` is the same builder's
//! door into continuous learning: it returns an
//! [`online::OnlineTrainer`] instead of a frozen model.
//!
//! Sketch construction is one typed params struct:
//! [`sketch::WlshBuildParams`] + `WlshSketch::build(&params, &source)`
//! (or `build_mem` for slices) replaced the old positional-constructor
//! zoo — the survivors are `#[deprecated]` shims. `.sampling(...)` on
//! the params (or the builder/CLI/TOML `sampling` key) importance-samples
//! the instance pool: `leverage(pilot=P,keep=K)` keeps the top-K
//! instances by Lanczos-estimated ridge leverage, reweighted
//! trace-preservingly, so mat-vecs and predictions cost O(K·d) instead
//! of O(m·d) at matched accuracy; selection is deterministic and
//! bit-identical across threads, shards, and reruns
//! (`tests/sampling_equivalence.rs`), and checkpoints replay the kept
//! set verbatim. See the README's "Feature sampling" section for the
//! accuracy-vs-m methodology.
//!
//! ## Streaming / out-of-core training
//!
//! Training never needs the n×d matrix in RAM: every operator build
//! consumes a chunked, re-iterable [`data::DataSource`] — the in-memory
//! [`data::Dataset`], a buffered [`data::CsvSource`], a sparse-text
//! [`data::LibsvmSource`], or an on-the-fly [`data::SyntheticSource`] —
//! so peak memory is O(chunk + sketch). Fit a single-pass Welford
//! [`data::Standardizer`] on the training stream, view the source through
//! it, and train with `fit_source`:
//!
//! ```no_run
//! use wlsh_krr::api::KrrModel;
//! use wlsh_krr::data::{CsvSource, Standardizer};
//! let src = CsvSource::open("train.csv", -1)?;            // target = last column
//! let std = Standardizer::fit(&src, 8192)?;               // one streaming pass
//! let model = KrrModel::builder()
//!     .method("wlsh")
//!     .chunk_rows(8192)
//!     .fit_source(&std.source(&src))?;                    // chunked build + CG
//! let mut q = vec![0.0f32; model.dim()];
//! std.transform_rows(&mut q);                             // train-time semantics
//! let pred = std.unscale_target(model.predict(&q)[0]);
//! # Ok::<(), wlsh_krr::api::KrrError>(())
//! ```
//!
//! Chunking is bit-transparent: streamed training produces coefficients
//! identical to the in-memory path at every chunk size and thread count
//! (`tests/stream_equivalence.rs`). The CLI exposes the same pipeline via
//! `train --data-format csv|libsvm --chunk-rows R`, and
//! `examples/streaming.rs` trains from an on-disk CSV larger than the
//! process memory budget.
//!
//! Sparse-native sources stay sparse end to end: a
//! [`data::LibsvmSource`] streams CSR chunks ([`data::SparseChunk`])
//! through standardization (scale-only for features — centering would
//! fill the zeros — targets centered as usual), the WLSH/RFF sketch
//! builds, evaluation sampling ([`data::head_sample_sparse`]), and
//! serving ([`api::Predictor::predict_sparse_into`], the server's
//! `{"sparse": [[idx, val], ...]}` request). Peak training memory scales
//! with nnz rather than n·d, and results are bit-identical to densifying
//! first; wrap a source in [`data::DensifySource`] (CLI:
//! `--sparse=false`) to force the dense pipeline.
//!
//! ## Serving
//!
//! The request path is a worker-pool engine: [`coordinator::serve`]
//! feeds a bounded shared queue into `workers` batcher threads
//! ([`coordinator::WorkerPool`]), each fusing concurrent requests into
//! one allocation-free `predict_into` call, with admission control (a
//! full queue answers `{"error":"overloaded"}`) instead of unbounded
//! latency. A [`coordinator::ModelRegistry`] routes requests to named
//! models and hot-swaps checkpoints atomically without dropping
//! connections. Predictions are bit-identical at every worker count,
//! queue depth, and batch boundary (`tests/serve_pool.rs`).
//!
//! ## Distributed solve & serving
//!
//! The m sketch instances shard across worker processes: set a
//! [`api::TopologySpec`] on the builder (`.topology("shards(n=3)")` to
//! spawn local workers, `.topology("remote(addr=host:port, ...)")` to
//! use running ones — start them with `wlsh-krr shard-worker`, or
//! in-process via [`coordinator::run_worker`]). The CG loop stays on the
//! coordinator; each iteration's fused mat-vec fans out over the typed
//! wire protocol ([`coordinator::proto`]), shards return raw per-block
//! partials, and the fixed-order reduction makes the N-shard β
//! **bit-identical to the local solve** at every shard and thread count
//! (`tests/shard_equivalence.rs`). A sharded model's [`api::Predictor`]
//! fans queries out the same way, so it serves through the registry /
//! worker pool unchanged. Shard failures surface as typed
//! [`api::KrrError::Shard`] values — never a hang, never a partial
//! result. See the README's "Distributed solve & serving" runbook.
//!
//! ## Online learning & uncertainty
//!
//! A served model can keep learning without a rebuild:
//! [`online::OnlineTrainer`] hashes newly arrived rows into the existing
//! per-instance bucket tables (bit-identical to retraining from scratch
//! on the concatenated data — `tests/online_equivalence.rs`), re-solves
//! the ridge system with a warm-started CG (previous β as the initial
//! iterate; the report states the iterations saved), and hands back a
//! model the registry hot-swaps atomically. Every WLSH/RFF/exact model
//! also reports *sketched posterior variance* alongside its predictions
//! ([`online::VarianceEstimator`], served via
//! [`api::Predictor::predict_with_var`] and the protocol's `"var":true`
//! flag) — a deterministic rank-r Gauss–Lanczos estimate of
//! k̃(q,q) − k̃_qᵀ(K̃+λI)⁻¹k̃_q that never understates the model's
//! uncertainty. Over the wire, `{"cmd":"append", ...}` routes rows to the
//! slot's trainer and each swap bumps the registry's `generation`
//! counter, surfaced in the `stats` reply.
//!
//! Lower layers, for direct use: [`sketch::WlshSketch`] (the paper's
//! estimator), [`solver::solve_krr`] (CG on `K̃ + λI`), and
//! [`coordinator::Trainer`] / [`coordinator::serve`] (the
//! training/serving framework). See `examples/quickstart.rs` for the
//! canonical walkthrough.

pub mod api;
pub mod bucketfn;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod lsh;
pub mod metrics;
pub mod online;
pub mod quadrature;
pub mod risk;
pub mod runtime;
pub mod sketch;
pub mod solver;
pub mod util;

/// Crate version (for the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
