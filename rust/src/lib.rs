//! # wlsh-krr
//!
//! Production-grade reproduction of *"Scaling up Kernel Ridge Regression
//! via Locality Sensitive Hashing"* (Kapralov, Nouri, Razenshteyn,
//! Velingker, Zandieh — AISTATS 2020).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the compute hot
//!   spots — WLSH hashing + bucket weights, RFF features, blockwise exact
//!   kernel mat-vecs — AOT-lowered to HLO text.
//! * **L2** (`python/compile/model.py`): JAX graphs composing the kernels
//!   (notably the O(n·m) WLSH sketch mat-vec of paper §4).
//! * **L3** (this crate): the coordinator — LSH bucket tables, CG-based KRR
//!   training, a batched prediction service, benchmarks reproducing every
//!   table in the paper, and the PJRT runtime describing the AOT artifacts
//!   (no execution backend is linked yet — the `pjrt` cargo feature is
//!   inert scaffolding — so every runtime consumer skips cleanly).
//!
//! Python never runs on the request path: the Rust binary is
//! self-contained, builds with **zero external crates** (the substrates
//! under [`util`] replace `rand`/`serde_json`/`clap`/`proptest`/
//! `criterion`/`rayon`), and its WLSH hot paths — sketch build, the K̃β
//! mat-vec inside CG, bucket-load preparation, and batch prediction — fan
//! out over scoped worker threads ([`util::par`]) with reductions in fixed
//! instance order, so parallel results are bit-identical to the serial
//! reference at every thread count (see `tests/parallel_determinism.rs`).
//! Thread budget: `WLSH_THREADS` env var, default = available cores.
//!
//! Entry points: [`sketch::WlshSketch`] (the paper's estimator),
//! [`solver::solve_krr`] (CG on `K̃ + λI`), [`coordinator::Trainer`] /
//! [`coordinator::serve`] (the training/serving framework), and
//! `examples/quickstart.rs`.

pub mod bucketfn;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod lsh;
pub mod metrics;
pub mod quadrature;
pub mod risk;
pub mod runtime;
pub mod sketch;
pub mod solver;
pub mod util;

/// Crate version (for the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
