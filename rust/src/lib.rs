//! # wlsh-krr
//!
//! Production-grade reproduction of *"Scaling up Kernel Ridge Regression
//! via Locality Sensitive Hashing"* (Kapralov, Nouri, Razenshteyn,
//! Velingker, Zandieh — AISTATS 2020).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the compute hot
//!   spots — WLSH hashing + bucket weights, RFF features, blockwise exact
//!   kernel mat-vecs — AOT-lowered to HLO text.
//! * **L2** (`python/compile/model.py`): JAX graphs composing the kernels
//!   (notably the O(n·m) WLSH sketch mat-vec of paper §4).
//! * **L3** (this crate): the coordinator — LSH bucket tables, CG-based KRR
//!   training, a batched prediction service, benchmarks reproducing every
//!   table in the paper, and the PJRT runtime describing the AOT artifacts
//!   (no execution backend is linked yet — the `pjrt` cargo feature is
//!   inert scaffolding — so every runtime consumer skips cleanly).
//!
//! Python never runs on the request path: the Rust binary is
//! self-contained, builds with **zero external crates** (the substrates
//! under [`util`] replace `rand`/`serde_json`/`clap`/`proptest`/
//! `criterion`/`rayon`), and its WLSH hot paths — sketch build, the K̃β
//! mat-vec inside CG, bucket-load preparation, and batch prediction — fan
//! out over scoped worker threads ([`util::par`]) with reductions in fixed
//! instance order, so parallel results are bit-identical to the serial
//! reference at every thread count (see `tests/parallel_determinism.rs`).
//! Thread budget: `WLSH_THREADS` env var, default = available cores.
//!
//! ## Entry points
//!
//! The front door is the typed builder in [`api`]:
//!
//! ```no_run
//! use wlsh_krr::api::{KrrModel, MethodSpec};
//! # let train = wlsh_krr::data::synthetic_by_name("wine", Some(500), 1).unwrap();
//! let model = KrrModel::builder()
//!     .method(MethodSpec::Wlsh)   // or .method("wlsh")
//!     .budget(450)
//!     .scale(3.0)
//!     .lambda(0.5)
//!     .fit(&train)?;              // Err(KrrError), never a panic
//! let preds = model.predict(&train.x);
//! # Ok::<(), wlsh_krr::api::KrrError>(())
//! ```
//!
//! Every method/bucket/preconditioner/kernel choice is a spec enum
//! ([`api::MethodSpec`], [`api::BucketSpec`], [`api::PrecondSpec`],
//! [`api::KernelSpec`]) with one `FromStr`/`Display` grammar shared by the
//! CLI, the TOML subset, and checkpoint headers — misspelled strings
//! surface as [`api::KrrError`] values. A trained model serves through a
//! frozen [`api::Predictor`] handle (`predict` / allocation-free
//! `predict_into`), which is what the TCP server and the benches use.
//!
//! Lower layers, for direct use: [`sketch::WlshSketch`] (the paper's
//! estimator), [`solver::solve_krr`] (CG on `K̃ + λI`), and
//! [`coordinator::Trainer`] / [`coordinator::serve`] (the
//! training/serving framework). See `examples/quickstart.rs` for the
//! canonical walkthrough.

pub mod api;
pub mod bucketfn;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod lsh;
pub mod metrics;
pub mod quadrature;
pub mod risk;
pub mod runtime;
pub mod sketch;
pub mod solver;
pub mod util;

/// Crate version (for the CLI banner).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
