//! Dense bucket renumbering — the "lists L_j" data structure of paper §4:
//! O(dn) preprocessing, O(n) memory, O(1) bucket lookup.
//!
//! Layout: in addition to the per-point dense index (`bucket_of`, the
//! renumbering map), the table stores the inverted lists in **CSR form** —
//! one flat `offsets` array (bucket j's members live at
//! `members[offsets[j]..offsets[j+1]]`) plus one flat `members` array,
//! built by a stable counting sort over `bucket_of`. The CSR arrays are
//! what make the WLSH mat-vec's bucket-load accumulation a contiguous walk
//! (cf. Wu et al., "Revisiting Random Binning Features", KDD 2018, on
//! cache-friendly flat binning layouts) instead of a random scatter, and
//! the stable sort keeps members in ascending point order inside each
//! bucket, so per-bucket floating-point reductions replay the exact
//! point-order accumulation of the scatter formulation (bit-identical).

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher for u64 keys (FxHash-style; the std SipHash is ~4×
/// slower on this hot path and we control the keys).
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.write_u64(v as u64)
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = (self.state.rotate_left(5) ^ v).wrapping_mul(0x517cc1b727220a95);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64)
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Renumbered bucket assignment for one LSH instance, with the inverted
/// bucket lists stored flat (CSR).
#[derive(Clone, Debug)]
pub struct BucketTable {
    /// Dense bucket index of each point, in [0, n_buckets).
    pub bucket_of: Vec<u32>,
    /// Number of distinct non-empty buckets.
    pub n_buckets: usize,
    /// CSR row pointers: bucket j's members are
    /// `members[offsets[j] as usize..offsets[j+1] as usize]`.
    /// Length `n_buckets + 1`, `offsets[0] == 0`, monotone non-decreasing.
    pub offsets: Vec<u32>,
    /// CSR column indices: point ids grouped by bucket, in ascending point
    /// order within each bucket (stable counting sort). Length n.
    pub members: Vec<u32>,
    /// Raw id → dense index (query-time lookups).
    map: HashMap<u64, u32, FxBuildHasher>,
}

/// Incremental [`BucketTable`] assembly for chunked/streaming builds:
/// raw ids are pushed in point order (any chunking), the dense
/// renumbering map grows by first appearance — exactly the order the
/// whole-array constructor assigns — and [`finish`](Self::finish) runs
/// the same counting sort, so a table built from N pushes is
/// bit-identical to `BucketTable::build` over the concatenated ids.
#[derive(Default)]
pub struct BucketTableBuilder {
    map: HashMap<u64, u32, FxBuildHasher>,
    bucket_of: Vec<u32>,
}

impl BucketTableBuilder {
    pub fn new() -> BucketTableBuilder {
        BucketTableBuilder::default()
    }

    /// Pre-size the renumbering map for an expected point count.
    pub fn with_capacity(n: usize) -> BucketTableBuilder {
        BucketTableBuilder {
            map: HashMap::with_capacity_and_hasher(n / 2 + 1, FxBuildHasher::default()),
            bucket_of: Vec::with_capacity(n),
        }
    }

    /// Append the next point's raw id (points arrive in order).
    #[inline]
    pub fn push(&mut self, id: u64) {
        let next = self.map.len() as u32;
        let b = *self.map.entry(id).or_insert(next);
        self.bucket_of.push(b);
    }

    /// Points pushed so far.
    pub fn len(&self) -> usize {
        self.bucket_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bucket_of.is_empty()
    }

    /// Counting sort: histogram → exclusive prefix sum → stable placement.
    pub fn finish(self) -> BucketTable {
        let BucketTableBuilder { map, bucket_of } = self;
        let n_buckets = map.len();
        let mut offsets = vec![0u32; n_buckets + 1];
        for &b in &bucket_of {
            offsets[b as usize + 1] += 1;
        }
        for j in 0..n_buckets {
            offsets[j + 1] += offsets[j];
        }
        let mut cursor: Vec<u32> = offsets[..n_buckets].to_vec();
        let mut members = vec![0u32; bucket_of.len()];
        for (i, &b) in bucket_of.iter().enumerate() {
            let slot = &mut cursor[b as usize];
            members[*slot as usize] = i as u32;
            *slot += 1;
        }
        BucketTable { bucket_of, n_buckets, offsets, members, map }
    }
}

impl BucketTable {
    /// Reopen a finished table as a builder positioned exactly where the
    /// original build left off: the renumbering map and per-point indices
    /// are the builder's whole state, so pushing further ids and calling
    /// [`BucketTableBuilder::finish`] again yields a table bit-identical
    /// to one built from the concatenated id stream in a single pass —
    /// the incremental-append path of the online subsystem.
    pub fn into_builder(self) -> BucketTableBuilder {
        let BucketTable { bucket_of, map, .. } = self;
        BucketTableBuilder { map, bucket_of }
    }

    /// Build from raw ids: one hash pass for the dense renumbering, then a
    /// counting sort into the CSR arrays (O(n) total). Delegates to
    /// [`BucketTableBuilder`], the same assembly path the streaming
    /// builds push chunks through.
    pub fn build(ids: &[u64]) -> BucketTable {
        let mut b = BucketTableBuilder::with_capacity(ids.len());
        for &id in ids {
            b.push(id);
        }
        b.finish()
    }

    /// Dense index of a raw id, if that bucket is non-empty.
    #[inline]
    pub fn lookup(&self, raw_id: u64) -> Option<u32> {
        self.map.get(&raw_id).copied()
    }

    /// The points hashed into bucket `j` (ascending point order).
    #[inline]
    pub fn bucket_members(&self, j: usize) -> &[u32] {
        &self.members[self.offsets[j] as usize..self.offsets[j + 1] as usize]
    }

    /// Bucket histogram (sizes of each bucket), read off the CSR offsets.
    pub fn sizes(&self) -> Vec<u32> {
        (0..self.n_buckets)
            .map(|j| self.offsets[j + 1] - self.offsets[j])
            .collect()
    }

    /// Memory footprint estimate in bytes (paper Lemma 27: O(n) words):
    /// the dense index and CSR members (4 bytes/point each), the CSR
    /// offsets (4 bytes/bucket + 4), and the raw-id map (16 bytes/bucket).
    pub fn memory_bytes(&self) -> usize {
        self.bucket_of.len() * 4
            + self.members.len() * 4
            + self.offsets.len() * 4
            + self.map.len() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renumbering_is_dense_and_consistent() {
        let ids = vec![42u64, 7, 42, 99, 7, 42];
        let t = BucketTable::build(&ids);
        assert_eq!(t.n_buckets, 3);
        assert_eq!(t.bucket_of.len(), 6);
        assert_eq!(t.bucket_of[0], t.bucket_of[2]);
        assert_eq!(t.bucket_of[0], t.bucket_of[5]);
        assert_eq!(t.bucket_of[1], t.bucket_of[4]);
        assert!(t.bucket_of.iter().all(|&b| (b as usize) < 3));
    }

    #[test]
    fn lookup_roundtrip() {
        let ids = vec![10u64, 20, 10];
        let t = BucketTable::build(&ids);
        assert_eq!(t.lookup(10), Some(t.bucket_of[0]));
        assert_eq!(t.lookup(20), Some(t.bucket_of[1]));
        assert_eq!(t.lookup(30), None);
    }

    #[test]
    fn sizes_sum_to_n() {
        let ids: Vec<u64> = (0..1000).map(|i| (i % 37) as u64).collect();
        let t = BucketTable::build(&ids);
        assert_eq!(t.n_buckets, 37);
        assert_eq!(t.sizes().iter().sum::<u32>(), 1000);
    }

    #[test]
    fn memory_is_linear() {
        let ids: Vec<u64> = (0..10_000).map(|i| i as u64 % 509).collect();
        let t = BucketTable::build(&ids);
        assert!(t.memory_bytes() < 10_000 * 24);
    }

    #[test]
    fn csr_inverts_bucket_of() {
        let ids = vec![42u64, 7, 42, 99, 7, 42];
        let t = BucketTable::build(&ids);
        assert_eq!(t.offsets.len(), t.n_buckets + 1);
        assert_eq!(t.offsets[0], 0);
        assert_eq!(*t.offsets.last().unwrap() as usize, ids.len());
        // bucket of id 42 is 0 (first appearance), members {0, 2, 5}
        assert_eq!(t.bucket_members(0), &[0, 2, 5]);
        assert_eq!(t.bucket_members(1), &[1, 4]);
        assert_eq!(t.bucket_members(2), &[3]);
    }

    #[test]
    fn csr_members_are_sorted_within_buckets_and_cover_all_points() {
        let ids: Vec<u64> = (0..777).map(|i| (i * 31 % 97) as u64).collect();
        let t = BucketTable::build(&ids);
        let mut seen = vec![false; ids.len()];
        for j in 0..t.n_buckets {
            let ms = t.bucket_members(j);
            assert!(!ms.is_empty(), "bucket {j} empty");
            for w in ms.windows(2) {
                assert!(w[0] < w[1], "bucket {j} not in ascending point order");
            }
            for &i in ms {
                assert_eq!(t.bucket_of[i as usize] as usize, j);
                assert!(!seen[i as usize], "point {i} in two buckets");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "CSR lost a point");
    }

    #[test]
    fn empty_input_builds_empty_table() {
        let t = BucketTable::build(&[]);
        assert_eq!(t.n_buckets, 0);
        assert_eq!(t.offsets, vec![0]);
        assert!(t.members.is_empty());
        assert!(t.sizes().is_empty());
    }

    #[test]
    fn resumed_builder_matches_concatenated_build_at_any_split() {
        let ids: Vec<u64> = (0..600).map(|i| (i * 41 % 131) as u64).collect();
        let want = BucketTable::build(&ids);
        for split in [0usize, 1, 59, 300, 599, 600] {
            let first = BucketTable::build(&ids[..split]);
            let mut b = first.into_builder();
            for &id in &ids[split..] {
                b.push(id);
            }
            let t = b.finish();
            assert_eq!(t.bucket_of, want.bucket_of, "split={split}");
            assert_eq!(t.offsets, want.offsets, "split={split}");
            assert_eq!(t.members, want.members, "split={split}");
            assert_eq!(t.n_buckets, want.n_buckets, "split={split}");
        }
    }

    #[test]
    fn incremental_builder_matches_whole_array_build_for_any_chunking() {
        let ids: Vec<u64> = (0..500).map(|i| (i * 37 % 113) as u64).collect();
        let want = BucketTable::build(&ids);
        for chunk in [1usize, 7, 64, 500] {
            let mut b = BucketTableBuilder::new();
            assert!(b.is_empty());
            for block in ids.chunks(chunk) {
                for &id in block {
                    b.push(id);
                }
            }
            assert_eq!(b.len(), ids.len());
            let t = b.finish();
            assert_eq!(t.bucket_of, want.bucket_of, "chunk={chunk}");
            assert_eq!(t.offsets, want.offsets, "chunk={chunk}");
            assert_eq!(t.members, want.members, "chunk={chunk}");
            assert_eq!(t.n_buckets, want.n_buckets, "chunk={chunk}");
            assert_eq!(t.lookup(ids[3]), want.lookup(ids[3]));
        }
    }
}
