//! Dense bucket renumbering — the "lists L_j" data structure of paper §4:
//! O(dn) preprocessing, O(n) memory, O(1) bucket lookup.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher for u64 keys (FxHash-style; the std SipHash is ~4×
/// slower on this hot path and we control the keys).
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.write_u64(v as u64)
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = (self.state.rotate_left(5) ^ v).wrapping_mul(0x517cc1b727220a95);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64)
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Renumbered bucket assignment for one LSH instance.
#[derive(Clone, Debug)]
pub struct BucketTable {
    /// Dense bucket index of each point, in [0, n_buckets).
    pub bucket_of: Vec<u32>,
    /// Number of distinct non-empty buckets.
    pub n_buckets: usize,
    /// Raw id → dense index (query-time lookups).
    map: HashMap<u64, u32, FxBuildHasher>,
}

impl BucketTable {
    /// Build from raw ids (O(n)).
    pub fn build(ids: &[u64]) -> BucketTable {
        let mut map: HashMap<u64, u32, FxBuildHasher> =
            HashMap::with_capacity_and_hasher(ids.len() / 2 + 1, FxBuildHasher::default());
        let mut bucket_of = Vec::with_capacity(ids.len());
        for &id in ids {
            let next = map.len() as u32;
            let b = *map.entry(id).or_insert(next);
            bucket_of.push(b);
        }
        BucketTable { bucket_of, n_buckets: map.len(), map }
    }

    /// Dense index of a raw id, if that bucket is non-empty.
    #[inline]
    pub fn lookup(&self, raw_id: u64) -> Option<u32> {
        self.map.get(&raw_id).copied()
    }

    /// Bucket histogram (sizes of each bucket).
    pub fn sizes(&self) -> Vec<u32> {
        let mut s = vec![0u32; self.n_buckets];
        for &b in &self.bucket_of {
            s[b as usize] += 1;
        }
        s
    }

    /// Memory footprint estimate in bytes (paper Lemma 27: O(n) words).
    pub fn memory_bytes(&self) -> usize {
        self.bucket_of.len() * 4 + self.map.len() * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renumbering_is_dense_and_consistent() {
        let ids = vec![42u64, 7, 42, 99, 7, 42];
        let t = BucketTable::build(&ids);
        assert_eq!(t.n_buckets, 3);
        assert_eq!(t.bucket_of.len(), 6);
        assert_eq!(t.bucket_of[0], t.bucket_of[2]);
        assert_eq!(t.bucket_of[0], t.bucket_of[5]);
        assert_eq!(t.bucket_of[1], t.bucket_of[4]);
        assert!(t.bucket_of.iter().all(|&b| (b as usize) < 3));
    }

    #[test]
    fn lookup_roundtrip() {
        let ids = vec![10u64, 20, 10];
        let t = BucketTable::build(&ids);
        assert_eq!(t.lookup(10), Some(t.bucket_of[0]));
        assert_eq!(t.lookup(20), Some(t.bucket_of[1]));
        assert_eq!(t.lookup(30), None);
    }

    #[test]
    fn sizes_sum_to_n() {
        let ids: Vec<u64> = (0..1000).map(|i| (i % 37) as u64).collect();
        let t = BucketTable::build(&ids);
        assert_eq!(t.n_buckets, 37);
        assert_eq!(t.sizes().iter().sum::<u32>(), 1000);
    }

    #[test]
    fn memory_is_linear() {
        let ids: Vec<u64> = (0..10_000).map(|i| i as u64 % 509).collect();
        let t = BucketTable::build(&ids);
        assert!(t.memory_bytes() < 10_000 * 24);
    }
}
