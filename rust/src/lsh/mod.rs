//! The LSH family of Def. 5 and the bucket data structure of §4.
//!
//! An LSH function is `h_{w,z}(x)_l = round((x_l - z_l)/w_l)` with grid
//! widths `w_l ~ Gamma(shape, 1)` iid and shift `z ~ Unif[0, w]`. Points
//! are hashed per coordinate and the d-dim bucket coordinate is collapsed
//! to a scalar id by a random odd-multiplier mix:
//!
//! * `u64` mix (native default) — collision probability ≈ 2⁻⁶⁴, negligible.
//! * `i32` mix — bit-compatible with the HLO Pallas kernel (wrap-around
//!   i32 arithmetic), used by the XLA backend and the parity tests.
//!
//! `BucketTable` renumbers raw ids into dense `[0, B)` indices (the "lists
//! L_j" of §4) and stores the inverted lists flat in CSR form
//! (`offsets` + `members`, built by a stable counting sort), enabling the
//! O(n) mat-vec as two contiguous array walks and O(1) query lookups.

mod table;

pub use table::{BucketTable, BucketTableBuilder, FxBuildHasher};

use crate::api::BucketSpec;
use crate::bucketfn::BucketEval;
use crate::data::SparseChunk;
use crate::util::rng::Pcg64;
use crate::util::simd;

/// Shared parameters of the LSH family (Def. 5) + bucket shaping (Def. 6).
#[derive(Clone, Debug)]
pub struct LshFamily {
    pub d: usize,
    /// Gamma(shape, 1) law of the grid widths (2 ⇒ Laplace, 7 ⇒ paper's
    /// smooth Table-1 kernel).
    pub gamma_shape: f64,
    /// Bucket-shaping function f (compiled evaluator).
    pub bucket: BucketEval,
    /// The typed spec `bucket` was compiled from.
    pub bucket_spec: BucketSpec,
    /// i32 odd mixing multipliers (shared with the HLO kernel).
    pub mix32: Vec<i32>,
    /// u64 odd mixing multipliers (native default).
    pub mix64: Vec<u64>,
}

impl LshFamily {
    /// Build the family for a typed bucket spec — infallible: unknown
    /// bucket strings are rejected earlier, when parsed into a
    /// [`BucketSpec`].
    pub fn new(d: usize, gamma_shape: f64, bucket: &BucketSpec, rng: &mut Pcg64) -> LshFamily {
        LshFamily {
            d,
            gamma_shape,
            bucket: bucket.eval(),
            bucket_spec: *bucket,
            mix32: (0..d).map(|_| rng.odd_i32()).collect(),
            mix64: (0..d).map(|_| rng.odd_u64()).collect(),
        }
    }

    /// Draw one LSH instance (w ~ Gamma(shape,1)^d, z ~ Unif[0, w]).
    pub fn sample(&self, rng: &mut Pcg64) -> LshFunction {
        let w: Vec<f32> = (0..self.d)
            .map(|_| rng.gamma(self.gamma_shape) as f32)
            .collect();
        let z: Vec<f32> = w.iter().map(|&wl| (rng.uniform() * wl as f64) as f32).collect();
        LshFunction { w, z }
    }
}

/// One LSH instance: the grid widths and shift of Def. 5.
#[derive(Clone, Debug)]
pub struct LshFunction {
    pub w: Vec<f32>,
    pub z: Vec<f32>,
}

/// Precomputed per-instance state for hashing sparse CSR rows
/// **bit-identically** to the dense U64 [`hash_batch`](LshFunction::hash_batch)
/// loop, in O(nnz) id work per row.
///
/// The trick: the u64 id is a wrapping sum `Σ_l c_l·mix_l` over Z/2⁶⁴ — a
/// commutative group — so a sparse row's id is the all-zeros baseline
/// `id0 = Σ_l c⁰_l·mix_l` plus, per stored coordinate, the difference
/// `c_l·mix_l − c⁰_l·mix_l`. Every `c⁰_l` is computed with the *same*
/// reciprocal-multiply f32 arithmetic the dense plan uses on a literal
/// 0.0, so absent coordinates contribute exactly the cached term and the
/// group sum equals the dense one bit for bit.
///
/// Smooth-bucket weights are a *sequential f32 product* over all d dims —
/// non-associative, so they cannot be sparsified the same way. Instead
/// [`hash_sparse`](LshFunction::hash_sparse) replays the full-order
/// product, substituting the cached per-dim baseline factor `f0[l]` at
/// absent coordinates (O(d) multiplies per row — the documented smooth
/// trade-off). Rect buckets skip the product entirely, exactly like the
/// dense loop.
///
/// Two arithmetic flavors exist because the dense code has two:
/// [`sparse_plan`](LshFunction::sparse_plan) mirrors the batched build
/// loop's reciprocal multiply `(x−z)·(1/w)`, while
/// [`sparse_plan_point`](LshFunction::sparse_plan_point) mirrors
/// [`hash_point`](LshFunction::hash_point)'s division `(x−z)/w` (the
/// query path). Match the plan to the dense code being replaced, or the
/// floor can land one cell off near grid boundaries.
pub struct SparseHashPlan {
    /// 1/w per dim — the reciprocals the dense batch loop multiplies by
    /// (empty for point-arithmetic plans, which divide by `w` directly).
    inv_w: Vec<f32>,
    /// Per-dim mixed baseline `c⁰_l·mix_l` for x_l = 0.
    c0m: Vec<u64>,
    /// Id of the all-zeros row: wrapping `Σ_l c0m[l]`.
    id0: u64,
    /// Per-dim baseline bucket weight `f(c⁰−t⁰)` (empty for rect).
    f0: Vec<f32>,
    /// `true` ⇒ per-coordinate terms use `hash_point`'s division.
    point_arith: bool,
}

/// Which id-collapse arithmetic to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdMode {
    /// u64 wrap mix — native default (collisions ≈ never).
    U64,
    /// i32 wrap mix — bit-compatible with the Pallas/HLO kernel.
    I32,
}

impl LshFunction {
    /// Hash one point: returns (raw id, f^{⊗d} weight).
    ///
    /// f32 arithmetic mirrors the HLO kernel exactly: `t = (x-z)/w`,
    /// `c = floor(t + 0.5)`, residual `r = c - t`, weight `∏ f(r_l)`.
    #[inline]
    pub fn hash_point(
        &self,
        x: &[f32],
        family: &LshFamily,
        mode: IdMode,
    ) -> (u64, f32) {
        debug_assert_eq!(x.len(), family.d);
        let mut id64: u64 = 0;
        let mut id32: i32 = 0;
        let mut weight: f32 = 1.0;
        let rect = family.bucket.is_rect;
        for l in 0..family.d {
            let t = (x[l] - self.z[l]) / self.w[l];
            let c = (t + 0.5).floor();
            match mode {
                IdMode::U64 => {
                    id64 = id64
                        .wrapping_add((c as i64 as u64).wrapping_mul(family.mix64[l]));
                }
                IdMode::I32 => {
                    id32 = id32.wrapping_add((c as i32).wrapping_mul(family.mix32[l]));
                }
            }
            if !rect {
                weight *= family.bucket.eval(c - t);
            }
        }
        let id = match mode {
            IdMode::U64 => id64,
            IdMode::I32 => id32 as u32 as u64,
        };
        (id, weight)
    }

    /// Hash a row-major batch; appends into `ids`/`weights`.
    ///
    /// The U64/native path replaces the per-dim division with a reciprocal
    /// multiply and runs a branchless zipped inner loop (the O(n·d·m)
    /// preprocessing hot spot — see EXPERIMENTS.md §Perf). The I32 path
    /// defers to `hash_point` to stay bit-identical with the HLO kernel.
    pub fn hash_batch(
        &self,
        x: &[f32],
        family: &LshFamily,
        mode: IdMode,
        ids: &mut Vec<u64>,
        weights: &mut Vec<f32>,
    ) {
        let d = family.d;
        let n = x.len() / d;
        ids.reserve(n);
        weights.reserve(n);
        if mode == IdMode::I32 {
            for i in 0..n {
                let (id, w) = self.hash_point(&x[i * d..(i + 1) * d], family, mode);
                ids.push(id);
                weights.push(w);
            }
            return;
        }
        // Per-dim cells/residuals vectorize (`util::simd::hash_cells`,
        // identical f32 op order to the old zipped loop); the saturating
        // `c as i64` id mix and the order-sensitive f32 weight product stay
        // scalar reference code over the buffered lanes, so ids and weights
        // are bit-identical across WLSH_SIMD settings.
        let inv_w: Vec<f32> = self.w.iter().map(|&w| 1.0 / w).collect();
        let mix64 = &family.mix64;
        let rect = family.bucket.is_rect;
        let mut c_buf = vec![0.0f32; d];
        let mut r_buf = vec![0.0f32; d];
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            simd::hash_cells(row, &self.z, &inv_w, &mut c_buf, &mut r_buf);
            let mut id: u64 = 0;
            for (&c, &mx) in c_buf.iter().zip(mix64) {
                id = id.wrapping_add((c as i64 as u64).wrapping_mul(mx));
            }
            ids.push(id);
            if rect {
                weights.push(1.0);
            } else {
                let mut weight: f32 = 1.0;
                for &r in r_buf.iter() {
                    weight *= family.bucket.eval(r);
                }
                weights.push(weight);
            }
        }
    }

    /// Precompute the per-instance baseline terms for
    /// [`hash_sparse`](Self::hash_sparse) with the *batched build* loop's
    /// reciprocal-multiply arithmetic (O(d) time and space; build once
    /// per instance, reuse across every chunk).
    pub fn sparse_plan(&self, family: &LshFamily) -> SparseHashPlan {
        self.plan_impl(family, false)
    }

    /// As [`sparse_plan`](Self::sparse_plan) with
    /// [`hash_point`](Self::hash_point)'s division arithmetic — for
    /// query-side sparse hashing that must match dense per-point hashing
    /// bit for bit.
    pub fn sparse_plan_point(&self, family: &LshFamily) -> SparseHashPlan {
        self.plan_impl(family, true)
    }

    fn plan_impl(&self, family: &LshFamily, point_arith: bool) -> SparseHashPlan {
        let inv_w: Vec<f32> = if point_arith {
            Vec::new()
        } else {
            self.w.iter().map(|&w| 1.0 / w).collect()
        };
        let rect = family.bucket.is_rect;
        let mut c0m = Vec::with_capacity(family.d);
        let mut f0 = Vec::with_capacity(if rect { 0 } else { family.d });
        let mut id0: u64 = 0;
        for l in 0..family.d {
            // the exact dense arithmetic applied to a literal 0.0
            let t0 = if point_arith {
                (0.0f32 - self.z[l]) / self.w[l]
            } else {
                (0.0f32 - self.z[l]) * inv_w[l]
            };
            let c0 = (t0 + 0.5).floor();
            let m = (c0 as i64 as u64).wrapping_mul(family.mix64[l]);
            id0 = id0.wrapping_add(m);
            c0m.push(m);
            if !rect {
                f0.push(family.bucket.eval(c0 - t0));
            }
        }
        SparseHashPlan { inv_w, c0m, id0, f0, point_arith }
    }

    /// Hash one CSR row (U64 mode) — bit-identical to the dense loop the
    /// plan was built for ([`hash_batch`](Self::hash_batch) or
    /// [`hash_point`](Self::hash_point)). `idx` must be ascending and
    /// unique, which the loaders guarantee.
    #[inline]
    pub fn hash_sparse_row(
        &self,
        idx: &[u32],
        vals: &[f32],
        plan: &SparseHashPlan,
        family: &LshFamily,
    ) -> (u64, f32) {
        let mut id = plan.id0;
        if family.bucket.is_rect {
            for (&j, &xv) in idx.iter().zip(vals) {
                let l = j as usize;
                let t = if plan.point_arith {
                    (xv - self.z[l]) / self.w[l]
                } else {
                    (xv - self.z[l]) * plan.inv_w[l]
                };
                let c = (t + 0.5).floor();
                id = id
                    .wrapping_add((c as i64 as u64).wrapping_mul(family.mix64[l]))
                    .wrapping_sub(plan.c0m[l]);
            }
            (id, 1.0)
        } else {
            // replay the dense full-order f32 product, substituting the
            // cached baseline factor at absent coordinates (f32 products
            // don't reassociate, so the order must match the dense loop)
            let mut weight: f32 = 1.0;
            let mut p = 0usize; // cursor into idx (ascending)
            for l in 0..family.d {
                if p < idx.len() && idx[p] as usize == l {
                    let xv = vals[p];
                    let t = if plan.point_arith {
                        (xv - self.z[l]) / self.w[l]
                    } else {
                        (xv - self.z[l]) * plan.inv_w[l]
                    };
                    let c = (t + 0.5).floor();
                    id = id
                        .wrapping_add((c as i64 as u64).wrapping_mul(family.mix64[l]))
                        .wrapping_sub(plan.c0m[l]);
                    weight *= family.bucket.eval(c - t);
                    p += 1;
                } else {
                    weight *= plan.f0[l];
                }
            }
            (id, weight)
        }
    }

    /// Hash a CSR block (U64 mode), appending into `ids`/`weights` —
    /// bit-identical to [`hash_batch`](Self::hash_batch) on the densified
    /// rows when given a [`sparse_plan`](Self::sparse_plan) (see
    /// [`SparseHashPlan`]).
    pub fn hash_sparse(
        &self,
        chunk: &SparseChunk<'_>,
        plan: &SparseHashPlan,
        family: &LshFamily,
        ids: &mut Vec<u64>,
        weights: &mut Vec<f32>,
    ) {
        let n = chunk.nrows();
        ids.reserve(n);
        weights.reserve(n);
        for i in 0..n {
            let (idx, vals) = chunk.row(i);
            let (id, w) = self.hash_sparse_row(idx, vals, plan, family);
            ids.push(id);
            weights.push(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn family(d: usize, bucket: &str) -> (LshFamily, LshFunction) {
        let mut rng = Pcg64::new(7, 0);
        let fam = LshFamily::new(d, 2.0, &bucket.parse().unwrap(), &mut rng);
        let f = fam.sample(&mut rng);
        (fam, f)
    }

    #[test]
    fn hash_is_deterministic() {
        let (fam, f) = family(4, "rect");
        let x = [0.1f32, -0.7, 2.0, 0.0];
        let a = f.hash_point(&x, &fam, IdMode::U64);
        let b = f.hash_point(&x, &fam, IdMode::U64);
        assert_eq!(a, b);
    }

    #[test]
    fn nearby_points_collide_far_points_dont() {
        let (fam, f) = family(3, "rect");
        let x = [0.0f32, 0.0, 0.0];
        let y = [1e-4f32, -1e-4, 1e-4];
        let far = [50.0f32, -50.0, 50.0];
        // w ~ Gamma(2,1) is O(1), so 1e-4-close points almost surely collide
        assert_eq!(
            f.hash_point(&x, &fam, IdMode::U64).0,
            f.hash_point(&y, &fam, IdMode::U64).0
        );
        assert_ne!(
            f.hash_point(&x, &fam, IdMode::U64).0,
            f.hash_point(&far, &fam, IdMode::U64).0
        );
    }

    #[test]
    fn rect_weight_is_one_smooth_weight_in_range() {
        let (fam_r, fr) = family(5, "rect");
        let (fam_s, fs) = family(5, "smooth2");
        let x = [0.3f32, 1.0, -0.4, 0.0, 2.2];
        assert_eq!(fr.hash_point(&x, &fam_r, IdMode::U64).1, 1.0);
        let (_, w) = fs.hash_point(&x, &fam_s, IdMode::U64);
        let linf = fam_s.bucket.linf.powi(5);
        assert!(w.abs() <= linf + 1e-4, "w={w} linf^d={linf}");
    }

    #[test]
    fn collision_probability_matches_laplace() {
        // P[h(x)=h(y)] = e^{-|x-y|_1} for rect + Gamma(2,1) (Rahimi-Recht)
        let mut rng = Pcg64::new(3, 0);
        let fam = LshFamily::new(1, 2.0, &BucketSpec::Rect, &mut rng);
        let delta = 0.5f32;
        let trials = 40_000;
        let mut hits = 0;
        for _ in 0..trials {
            let f = fam.sample(&mut rng);
            let a = f.hash_point(&[0.0], &fam, IdMode::U64).0;
            let b = f.hash_point(&[delta], &fam, IdMode::U64).0;
            if a == b {
                hits += 1;
            }
        }
        let p_hat = hits as f64 / trials as f64;
        let p = (-delta as f64).exp();
        let sigma = (p * (1.0 - p) / trials as f64).sqrt();
        assert!((p_hat - p).abs() < 4.0 * sigma + 1e-9, "{p_hat} vs {p}");
    }

    #[test]
    fn i32_and_u64_modes_agree_on_collisions() {
        // different id values, but identical collision structure whp
        let (fam, f) = family(3, "rect");
        let mut rng = Pcg64::new(9, 0);
        let pts: Vec<[f32; 3]> = (0..200)
            .map(|_| {
                [
                    rng.normal() as f32,
                    rng.normal() as f32,
                    rng.normal() as f32,
                ]
            })
            .collect();
        let id64: Vec<u64> = pts
            .iter()
            .map(|p| f.hash_point(p, &fam, IdMode::U64).0)
            .collect();
        let id32: Vec<u64> = pts
            .iter()
            .map(|p| f.hash_point(p, &fam, IdMode::I32).0)
            .collect();
        for i in 0..pts.len() {
            for j in 0..i {
                assert_eq!(
                    id64[i] == id64[j],
                    id32[i] == id32[j],
                    "pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn sparse_hash_is_bit_identical_to_dense_on_densified_rows() {
        for bucket in ["rect", "smooth2"] {
            let (fam, f) = family(9, bucket);
            // sparse rows with gaps, a stored zero, and an empty row
            let indptr = [0usize, 3, 3, 5, 9];
            let indices = [1u32, 4, 7, 0, 8, 2, 3, 5, 6];
            let values = [0.7f32, -1.3, 2.2, 0.0, -0.4, 1.0, -2.0, 0.25, 3.5];
            let chunk = SparseChunk { indptr: &indptr, indices: &indices, values: &values };
            let mut dense = Vec::new();
            chunk.densify_into(fam.d, &mut dense);
            let (mut want_ids, mut want_ws) = (Vec::new(), Vec::new());
            f.hash_batch(&dense, &fam, IdMode::U64, &mut want_ids, &mut want_ws);
            let plan = f.sparse_plan(&fam);
            let (mut ids, mut ws) = (Vec::new(), Vec::new());
            f.hash_sparse(&chunk, &plan, &fam, &mut ids, &mut ws);
            assert_eq!(ids, want_ids, "{bucket} ids");
            assert_eq!(ws, want_ws, "{bucket} weights");
            // the point-arithmetic plan matches hash_point per row
            let plan_p = f.sparse_plan_point(&fam);
            for i in 0..chunk.nrows() {
                let (idx, vals) = chunk.row(i);
                let got = f.hash_sparse_row(idx, vals, &plan_p, &fam);
                let want = f.hash_point(&dense[i * fam.d..(i + 1) * fam.d], &fam, IdMode::U64);
                assert_eq!(got, want, "{bucket} point row {i}");
            }
        }
    }

    #[test]
    fn batch_matches_pointwise() {
        let (fam, f) = family(2, "smooth2");
        let x = vec![0.1f32, 0.2, -0.5, 1.0, 3.0, -3.0];
        let mut ids = Vec::new();
        let mut ws = Vec::new();
        f.hash_batch(&x, &fam, IdMode::U64, &mut ids, &mut ws);
        for i in 0..3 {
            let (id, w) = f.hash_point(&x[i * 2..(i + 1) * 2], &fam, IdMode::U64);
            assert_eq!(ids[i], id);
            assert_eq!(ws[i], w);
        }
    }
}
