//! Experiment configuration: a TOML-subset parser (sections, `key = value`
//! with strings/numbers/bools) plus the named presets driving the CLI,
//! examples, and benches. No `toml`/`serde` offline — see DESIGN.md §5.

use std::collections::BTreeMap;

/// Parsed config: section → key → raw value string.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    /// Parse TOML-subset text. Supported: `[section]`, `key = value`,
    /// `#` comments, bare/quoted strings, numbers, booleans.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }
}

/// Everything needed to train one KRR model.
#[derive(Clone, Debug)]
pub struct KrrConfig {
    /// "wlsh" | "rff" | "exact-laplace" | "exact-se" | "exact-matern" | "nystrom"
    pub method: String,
    /// WLSH: number of LSH instances (m). RFF: feature count D. Nyström:
    /// landmark count.
    pub budget: usize,
    /// Bucket-shaping function for WLSH.
    pub bucket: String,
    /// Gamma shape of the width law.
    pub gamma_shape: f64,
    /// Kernel bandwidth.
    pub scale: f64,
    /// Ridge λ.
    pub lambda: f64,
    /// CG iteration cap and tolerance.
    pub cg_max_iters: usize,
    pub cg_tol: f64,
    /// CG preconditioner: "none" | "jacobi" | "nystrom".
    pub precond: String,
    /// Landmark count (rank) of the Nyström preconditioner.
    pub precond_rank: usize,
    /// Emit per-iteration CG progress lines to stderr.
    pub cg_verbose: bool,
    /// Sketch workers (instance shards) for the trainer.
    pub workers: usize,
    pub seed: u64,
}

impl Default for KrrConfig {
    fn default() -> Self {
        KrrConfig {
            method: "wlsh".into(),
            budget: 64,
            bucket: "rect".into(),
            gamma_shape: 2.0,
            scale: 1.0,
            lambda: 1.0,
            cg_max_iters: 100,
            cg_tol: 1e-4,
            precond: "none".into(),
            precond_rank: 64,
            cg_verbose: false,
            workers: 1,
            seed: 42,
        }
    }
}

impl KrrConfig {
    /// Read a `[krr]` section over the defaults.
    pub fn from_config(cfg: &Config) -> KrrConfig {
        let d = KrrConfig::default();
        KrrConfig {
            method: cfg.get_str("krr", "method", &d.method).to_string(),
            budget: cfg.get_usize("krr", "budget", d.budget),
            bucket: cfg.get_str("krr", "bucket", &d.bucket).to_string(),
            gamma_shape: cfg.get_f64("krr", "gamma_shape", d.gamma_shape),
            scale: cfg.get_f64("krr", "scale", d.scale),
            lambda: cfg.get_f64("krr", "lambda", d.lambda),
            cg_max_iters: cfg.get_usize("krr", "cg_max_iters", d.cg_max_iters),
            cg_tol: cfg.get_f64("krr", "cg_tol", d.cg_tol),
            precond: cfg.get_str("krr", "precond", &d.precond).to_string(),
            precond_rank: cfg.get_usize("krr", "precond_rank", d.precond_rank),
            cg_verbose: cfg.get_bool("krr", "cg_verbose", d.cg_verbose),
            workers: cfg.get_usize("krr", "workers", d.workers),
            seed: cfg.get_usize("krr", "seed", d.seed as usize) as u64,
        }
    }

    /// Paper Table-2 presets per dataset (m / D values from the table).
    pub fn paper_preset(dataset: &str, method: &str) -> KrrConfig {
        let mut c = KrrConfig { method: method.to_string(), ..Default::default() };
        match method {
            "wlsh" => {
                c.budget = match dataset {
                    "wine" => 450,
                    "insurance" => 250,
                    _ => 50,
                };
            }
            "rff" => {
                c.budget = match dataset {
                    "wine" => 7000,
                    "insurance" => 5000,
                    "ctslices" => 3500,
                    _ => 1500,
                };
            }
            _ => {}
        }
        // bandwidths: standardized features, moderate smoothing; λ per size
        c.scale = (match dataset {
            "wine" => 3.0,
            "insurance" => 6.0,
            "ctslices" => 8.0,
            "covtype" => 4.0,
            _ => 3.0,
        }) * 1.0;
        c.lambda = 0.5;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let cfg = Config::parse(
            "# comment\n[krr]\nmethod = \"wlsh\"\nbudget = 450\nlambda = 0.5\n\n[server]\nport = 7777\nbatch = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get_str("krr", "method", ""), "wlsh");
        assert_eq!(cfg.get_usize("krr", "budget", 0), 450);
        assert_eq!(cfg.get_f64("krr", "lambda", 0.0), 0.5);
        assert!(cfg.get_bool("server", "batch", false));
        assert_eq!(cfg.get_usize("server", "port", 0), 7777);
    }

    #[test]
    fn missing_keys_fall_back() {
        let cfg = Config::parse("[krr]\n").unwrap();
        assert_eq!(cfg.get_usize("krr", "budget", 7), 7);
        assert_eq!(cfg.get_str("nope", "x", "d"), "d");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[krr]\nnot a kv\n").is_err());
    }

    #[test]
    fn krr_config_roundtrip() {
        let cfg = Config::parse(
            "[krr]\nmethod = rff\nbudget = 5000\nseed = 9\nprecond = jacobi\nprecond_rank = 32\ncg_verbose = true\n",
        )
        .unwrap();
        let k = KrrConfig::from_config(&cfg);
        assert_eq!(k.method, "rff");
        assert_eq!(k.budget, 5000);
        assert_eq!(k.seed, 9);
        assert_eq!(k.precond, "jacobi");
        assert_eq!(k.precond_rank, 32);
        assert!(k.cg_verbose);
        assert_eq!(k.cg_max_iters, KrrConfig::default().cg_max_iters);
    }

    #[test]
    fn precond_defaults_are_off() {
        let k = KrrConfig::default();
        assert_eq!(k.precond, "none");
        assert_eq!(k.precond_rank, 64);
        assert!(!k.cg_verbose);
    }

    #[test]
    fn paper_presets_match_table2() {
        assert_eq!(KrrConfig::paper_preset("wine", "wlsh").budget, 450);
        assert_eq!(KrrConfig::paper_preset("insurance", "wlsh").budget, 250);
        assert_eq!(KrrConfig::paper_preset("covtype", "wlsh").budget, 50);
        assert_eq!(KrrConfig::paper_preset("wine", "rff").budget, 7000);
        assert_eq!(KrrConfig::paper_preset("covtype", "rff").budget, 1500);
    }
}
