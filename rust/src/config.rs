//! Experiment configuration: a TOML-subset parser (sections, `key = value`
//! with strings/numbers/bools) plus the typed [`KrrConfig`] driving the
//! CLI, examples, and benches. No `toml`/`serde` offline — see DESIGN.md
//! §5. Method/bucket/preconditioner values parse through the spec enums in
//! [`crate::api`], so an unknown string is a [`KrrError`], not a panic.

use std::collections::BTreeMap;

use crate::api::{BucketSpec, KrrError, MethodSpec, PrecondSpec, SamplingSpec, TopologySpec};

/// Parsed config: section → key → raw value string.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

/// Strip a `#` comment, but only outside double-quoted values — so
/// `name = "issue #42"` keeps its fragment. The TOML subset has no escape
/// sequences inside strings, so quote state is a simple toggle.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

impl Config {
    /// Parse TOML-subset text. Supported: `[section]`, `key = value`,
    /// `#` comments (outside quoted strings), bare/quoted strings, numbers,
    /// booleans.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }
}

/// Everything needed to train one KRR model. All method/bucket/precond
/// choices are typed specs (see [`crate::api`]); numeric knobs are
/// validated by [`KrrConfig::validate`] before training.
#[derive(Clone, Debug, PartialEq)]
pub struct KrrConfig {
    /// Estimator family.
    pub method: MethodSpec,
    /// WLSH: number of LSH instances (m). RFF: feature count D. Nyström:
    /// landmark count. Ignored by the exact methods.
    pub budget: usize,
    /// Bucket-shaping function for WLSH.
    pub bucket: BucketSpec,
    /// Gamma shape of the width law.
    pub gamma_shape: f64,
    /// Kernel bandwidth.
    pub scale: f64,
    /// Ridge λ.
    pub lambda: f64,
    /// CG iteration cap and tolerance.
    pub cg_max_iters: usize,
    pub cg_tol: f64,
    /// CG preconditioner (the Nyström variant carries its rank).
    pub precond: PrecondSpec,
    /// Emit per-iteration CG progress lines to stderr.
    pub cg_verbose: bool,
    /// Sketch workers (instance shards) for the trainer.
    pub workers: usize,
    /// Rows per block when streaming data through the chunked sketch
    /// builds (peak transient memory is O(chunk_rows · d); results are
    /// bit-identical at every chunk size).
    pub chunk_rows: usize,
    pub seed: u64,
    /// Where the m WLSH instances live during solve/serving: this
    /// process, locally spawned shard workers, or remote addresses.
    /// Distributed topologies require `method = wlsh` (the instance
    /// average is what shards).
    pub topology: TopologySpec,
    /// How the m WLSH instances are sampled: `uniform` keeps the full
    /// budget at unit weight; `leverage(pilot=P,keep=K)` keeps the top-K
    /// by estimated ridge leverage; `stein` reweights the full budget.
    /// Non-uniform sampling requires `method = wlsh`.
    pub sampling: SamplingSpec,
}

impl Default for KrrConfig {
    /// The single source of fallback values: the CLI, the TOML reader, the
    /// builder, and the presets all defer to this impl.
    fn default() -> Self {
        KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 64,
            bucket: BucketSpec::Rect,
            gamma_shape: 2.0,
            scale: 3.0,
            lambda: 0.5,
            cg_max_iters: 100,
            cg_tol: 1e-4,
            precond: PrecondSpec::None,
            cg_verbose: false,
            workers: 1,
            chunk_rows: 8192,
            seed: 42,
            topology: TopologySpec::Local,
            sampling: SamplingSpec::Uniform,
        }
    }
}

impl KrrConfig {
    /// Read a `[krr]` section over the defaults. Unknown
    /// method/bucket/precond strings are errors; absent keys fall back to
    /// [`KrrConfig::default`].
    pub fn from_config(cfg: &Config) -> Result<KrrConfig, KrrError> {
        let d = KrrConfig::default();
        let method = match cfg.get("krr", "method") {
            Some(s) => s.parse()?,
            None => d.method,
        };
        let bucket = match cfg.get("krr", "bucket") {
            Some(s) => s.parse()?,
            None => d.bucket,
        };
        let raw_precond = cfg.get("krr", "precond");
        let mut precond: PrecondSpec = match raw_precond {
            Some(s) => s.parse()?,
            None => d.precond,
        };
        // legacy key: a separate `precond_rank` fills in a bare `nystrom`;
        // an explicit nystrom(rank=R) wins over the legacy key
        if raw_precond == Some("nystrom") {
            if let PrecondSpec::Nystrom { rank } = &mut precond {
                *rank = cfg.get_usize("krr", "precond_rank", *rank);
            }
        }
        let topology = match cfg.get("krr", "topology") {
            Some(s) => s.parse()?,
            None => d.topology,
        };
        let sampling = match cfg.get("krr", "sampling") {
            Some(s) => s.parse()?,
            None => d.sampling,
        };
        Ok(KrrConfig {
            method,
            budget: cfg.get_usize("krr", "budget", d.budget),
            bucket,
            gamma_shape: cfg.get_f64("krr", "gamma_shape", d.gamma_shape),
            scale: cfg.get_f64("krr", "scale", d.scale),
            lambda: cfg.get_f64("krr", "lambda", d.lambda),
            cg_max_iters: cfg.get_usize("krr", "cg_max_iters", d.cg_max_iters),
            cg_tol: cfg.get_f64("krr", "cg_tol", d.cg_tol),
            precond,
            cg_verbose: cfg.get_bool("krr", "cg_verbose", d.cg_verbose),
            workers: cfg.get_usize("krr", "workers", d.workers),
            chunk_rows: cfg.get_usize("krr", "chunk_rows", d.chunk_rows),
            seed: cfg.get_usize("krr", "seed", d.seed as usize) as u64,
            topology,
            sampling,
        })
    }

    /// Range-check the numeric knobs (the enums are correct by
    /// construction). Called by the builder and by
    /// [`Trainer::train`](crate::coordinator::Trainer::train), so every
    /// entry point shares one validation path.
    pub fn validate(&self) -> Result<(), KrrError> {
        if !(self.scale.is_finite() && self.scale > 0.0) {
            return Err(KrrError::BadParam(format!("scale must be > 0, got {}", self.scale)));
        }
        if !(self.lambda.is_finite() && self.lambda >= 0.0) {
            return Err(KrrError::BadParam(format!("lambda must be ≥ 0, got {}", self.lambda)));
        }
        if !(self.gamma_shape.is_finite() && self.gamma_shape > 0.0) {
            return Err(KrrError::BadParam(format!(
                "gamma_shape must be > 0, got {}",
                self.gamma_shape
            )));
        }
        if !(self.cg_tol.is_finite() && self.cg_tol > 0.0) {
            return Err(KrrError::BadParam(format!("cg_tol must be > 0, got {}", self.cg_tol)));
        }
        if self.budget == 0 && !self.method.is_exact() {
            return Err(KrrError::BadParam(format!(
                "method {} needs budget ≥ 1",
                self.method
            )));
        }
        if self.chunk_rows == 0 {
            return Err(KrrError::BadParam("chunk_rows must be ≥ 1".to_string()));
        }
        if self.topology.is_distributed() && self.method != MethodSpec::Wlsh {
            return Err(KrrError::BadParam(format!(
                "topology {} requires method wlsh (only the m-instance average shards)",
                self.topology
            )));
        }
        if !self.sampling.is_uniform() && self.method != MethodSpec::Wlsh {
            return Err(KrrError::BadParam(format!(
                "sampling {} requires method wlsh (only WLSH instances are importance-sampled)",
                self.sampling
            )));
        }
        if let SamplingSpec::Leverage { pilot, keep } = self.sampling {
            if pilot == 0 || keep == 0 {
                return Err(KrrError::BadParam(format!(
                    "leverage sampling needs pilot ≥ 1 and keep ≥ 1, got pilot={pilot} keep={keep}"
                )));
            }
            if pilot > self.budget || keep > self.budget {
                return Err(KrrError::BadParam(format!(
                    "leverage sampling needs pilot ≤ budget and keep ≤ budget, got pilot={pilot} keep={keep} budget={}",
                    self.budget
                )));
            }
        }
        Ok(())
    }

    /// Paper Table-2 presets per dataset (m / D values from the table).
    pub fn paper_preset(dataset: &str, method: MethodSpec) -> KrrConfig {
        let mut c = KrrConfig { method, ..Default::default() };
        match method {
            MethodSpec::Wlsh => {
                c.budget = match dataset {
                    "wine" => 450,
                    "insurance" => 250,
                    _ => 50,
                };
            }
            MethodSpec::Rff => {
                c.budget = match dataset {
                    "wine" => 7000,
                    "insurance" => 5000,
                    "ctslices" => 3500,
                    _ => 1500,
                };
            }
            _ => {}
        }
        // bandwidths: standardized features, moderate smoothing; λ per size
        c.scale = match dataset {
            "wine" => 3.0,
            "insurance" => 6.0,
            "ctslices" => 8.0,
            "covtype" => 4.0,
            _ => 3.0,
        };
        c.lambda = 0.5;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let cfg = Config::parse(
            "# comment\n[krr]\nmethod = \"wlsh\"\nbudget = 450\nlambda = 0.5\n\n[server]\nport = 7777\nbatch = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get_str("krr", "method", ""), "wlsh");
        assert_eq!(cfg.get_usize("krr", "budget", 0), 450);
        assert_eq!(cfg.get_f64("krr", "lambda", 0.0), 0.5);
        assert!(cfg.get_bool("server", "batch", false));
        assert_eq!(cfg.get_usize("server", "port", 0), 7777);
    }

    #[test]
    fn hash_inside_quoted_value_is_not_a_comment() {
        let cfg = Config::parse(
            "[meta]\ntag = \"issue #42\"  # trailing comment\nplain = \"#all\"\n",
        )
        .unwrap();
        assert_eq!(cfg.get_str("meta", "tag", ""), "issue #42");
        assert_eq!(cfg.get_str("meta", "plain", ""), "#all");
    }

    #[test]
    fn missing_keys_fall_back() {
        let cfg = Config::parse("[krr]\n").unwrap();
        assert_eq!(cfg.get_usize("krr", "budget", 7), 7);
        assert_eq!(cfg.get_str("nope", "x", "d"), "d");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[krr]\nnot a kv\n").is_err());
    }

    #[test]
    fn krr_config_roundtrip() {
        let cfg = Config::parse(
            "[krr]\nmethod = rff\nbudget = 5000\nseed = 9\nprecond = jacobi\ncg_verbose = true\nchunk_rows = 4096\n",
        )
        .unwrap();
        let k = KrrConfig::from_config(&cfg).unwrap();
        assert_eq!(k.method, MethodSpec::Rff);
        assert_eq!(k.budget, 5000);
        assert_eq!(k.seed, 9);
        assert_eq!(k.precond, PrecondSpec::Jacobi);
        assert!(k.cg_verbose);
        assert_eq!(k.chunk_rows, 4096);
        assert_eq!(k.cg_max_iters, KrrConfig::default().cg_max_iters);
    }

    #[test]
    fn legacy_precond_rank_key_overrides_bare_nystrom() {
        let cfg = Config::parse("[krr]\nprecond = nystrom\nprecond_rank = 32\n").unwrap();
        let k = KrrConfig::from_config(&cfg).unwrap();
        assert_eq!(k.precond, PrecondSpec::Nystrom { rank: 32 });
        // the parameterized form needs no extra key
        let cfg2 = Config::parse("[krr]\nprecond = nystrom(rank=12)\n").unwrap();
        let k2 = KrrConfig::from_config(&cfg2).unwrap();
        assert_eq!(k2.precond, PrecondSpec::Nystrom { rank: 12 });
        // and an explicit rank wins over a stray legacy key
        let cfg3 =
            Config::parse("[krr]\nprecond = nystrom(rank=12)\nprecond_rank = 32\n").unwrap();
        assert_eq!(
            KrrConfig::from_config(&cfg3).unwrap().precond,
            PrecondSpec::Nystrom { rank: 12 }
        );
    }

    #[test]
    fn unknown_spec_strings_error_instead_of_panicking() {
        let cfg = Config::parse("[krr]\nmethod = wlshh\n").unwrap();
        assert_eq!(
            KrrConfig::from_config(&cfg),
            Err(KrrError::UnknownMethod("wlshh".into()))
        );
        let cfg = Config::parse("[krr]\nbucket = round\n").unwrap();
        assert!(matches!(
            KrrConfig::from_config(&cfg),
            Err(KrrError::UnknownBucket(_))
        ));
        let cfg = Config::parse("[krr]\nprecond = ssor\n").unwrap();
        assert!(matches!(
            KrrConfig::from_config(&cfg),
            Err(KrrError::UnknownPrecond(_))
        ));
    }

    #[test]
    fn topology_parses_from_toml_and_defaults_local() {
        let cfg = Config::parse("[krr]\ntopology = \"shards(n=3)\"\n").unwrap();
        let k = KrrConfig::from_config(&cfg).unwrap();
        assert_eq!(k.topology, TopologySpec::Shards { n: 3 });
        let bare = KrrConfig::from_config(&Config::parse("[krr]\n").unwrap()).unwrap();
        assert_eq!(bare.topology, TopologySpec::Local);
        let bad = Config::parse("[krr]\ntopology = ring\n").unwrap();
        assert!(matches!(KrrConfig::from_config(&bad), Err(KrrError::BadParam(_))));
        // distributed topologies are WLSH-only
        let k = KrrConfig {
            method: MethodSpec::Rff,
            topology: TopologySpec::Shards { n: 2 },
            ..KrrConfig::default()
        };
        assert!(matches!(k.validate(), Err(KrrError::BadParam(_))));
    }

    #[test]
    fn sampling_parses_from_toml_and_defaults_uniform() {
        let cfg = Config::parse("[krr]\nsampling = \"leverage(pilot=16, keep=48)\"\n").unwrap();
        let k = KrrConfig::from_config(&cfg).unwrap();
        assert_eq!(k.sampling, SamplingSpec::Leverage { pilot: 16, keep: 48 });
        // legacy configs (no key) stay uniform
        let bare = KrrConfig::from_config(&Config::parse("[krr]\n").unwrap()).unwrap();
        assert_eq!(bare.sampling, SamplingSpec::Uniform);
        let bad = Config::parse("[krr]\nsampling = importance\n").unwrap();
        assert!(matches!(KrrConfig::from_config(&bad), Err(KrrError::BadParam(_))));
        // non-uniform sampling is WLSH-only
        let k = KrrConfig {
            method: MethodSpec::Rff,
            sampling: SamplingSpec::Stein,
            ..KrrConfig::default()
        };
        assert!(matches!(k.validate(), Err(KrrError::BadParam(_))));
        // pilot/keep must fit inside the budget
        let k = KrrConfig {
            budget: 32,
            sampling: SamplingSpec::Leverage { pilot: 8, keep: 48 },
            ..KrrConfig::default()
        };
        assert!(matches!(k.validate(), Err(KrrError::BadParam(_))));
        let k = KrrConfig {
            budget: 32,
            sampling: SamplingSpec::Leverage { pilot: 0, keep: 8 },
            ..KrrConfig::default()
        };
        assert!(matches!(k.validate(), Err(KrrError::BadParam(_))));
        let ok = KrrConfig {
            budget: 64,
            sampling: SamplingSpec::Leverage { pilot: 16, keep: 48 },
            ..KrrConfig::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn precond_defaults_are_off() {
        let k = KrrConfig::default();
        assert_eq!(k.precond, PrecondSpec::None);
        assert!(!k.cg_verbose);
    }

    #[test]
    fn validate_rejects_out_of_range_params() {
        let ok = KrrConfig::default();
        assert!(ok.validate().is_ok());
        assert!(KrrConfig { scale: 0.0, ..ok.clone() }.validate().is_err());
        assert!(KrrConfig { lambda: -1.0, ..ok.clone() }.validate().is_err());
        assert!(KrrConfig { cg_tol: 0.0, ..ok.clone() }.validate().is_err());
        assert!(KrrConfig { budget: 0, ..ok.clone() }.validate().is_err());
        assert!(KrrConfig { chunk_rows: 0, ..ok.clone() }.validate().is_err());
        // exact methods ignore the budget
        let exact = KrrConfig {
            method: "exact-se".parse().unwrap(),
            budget: 0,
            ..ok
        };
        assert!(exact.validate().is_ok());
    }

    #[test]
    fn paper_presets_match_table2() {
        assert_eq!(KrrConfig::paper_preset("wine", MethodSpec::Wlsh).budget, 450);
        assert_eq!(KrrConfig::paper_preset("insurance", MethodSpec::Wlsh).budget, 250);
        assert_eq!(KrrConfig::paper_preset("covtype", MethodSpec::Wlsh).budget, 50);
        assert_eq!(KrrConfig::paper_preset("wine", MethodSpec::Rff).budget, 7000);
        assert_eq!(KrrConfig::paper_preset("covtype", MethodSpec::Rff).budget, 1500);
    }
}
