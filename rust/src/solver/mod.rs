//! KRR solvers: conjugate gradients on (K̃ + λI)β = y (the paper's method,
//! footnote 2), preconditioned CG ([`solve_krr_pcg`]) with pluggable
//! [`Preconditioner`]s (Jacobi from the sketch diagonal, rank-r Nyström
//! via the Woodbury identity — cf. Avron et al., "Random Fourier Features
//! for Kernel Ridge Regression", 2017, on why preconditioning is what
//! makes sketched KRR competitive at small λ), plus a dense direct solve
//! for small n / ground-truthing.

use crate::api::KrrError;
use crate::linalg::{axpy, dot, norm2, CholeskyFactor, Matrix};
use crate::sketch::{KrrOperator, NystromPrecond};

/// CG configuration.
#[derive(Clone, Debug)]
pub struct CgOptions {
    pub max_iters: usize,
    /// Relative residual target ‖r‖/‖y‖.
    pub tol: f64,
    /// When set, the solver prints one progress line per iteration
    /// (`iter`, `rel_res`) to stderr.
    pub verbose: bool,
    /// Warm-start iterate β₀. `None` starts from zero (the historic path,
    /// byte-identical to before this field existed). `Some(x0)` seeds the
    /// solve at x0 with r₀ = y − (K̃+λI)x0 — the online re-solve path seeds
    /// this with the previous β padded with zeros for the appended rows.
    pub x0: Option<Vec<f64>>,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { max_iters: 200, tol: 1e-5, verbose: false, x0: None }
    }
}

/// CG solve result.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub beta: Vec<f64>,
    pub iters: usize,
    pub rel_residual: f64,
    pub converged: bool,
    /// Relative residual after each iteration (convergence curve).
    pub history: Vec<f64>,
}

/// Initial iterate and residual for a (P)CG solve. `x0 = None` reproduces
/// the historic cold start (β = 0, r = y — no operator application, no
/// float ops, so the path is byte-identical to before warm starts
/// existed); `x0 = Some(v)` starts at v with r = y − (K̃+λI)v.
fn warm_start<F: Fn(&[f64]) -> Vec<f64>>(
    n: usize,
    y: &[f64],
    opts: &CgOptions,
    apply: &F,
) -> (Vec<f64>, Vec<f64>) {
    match &opts.x0 {
        None => (vec![0.0f64; n], y.to_vec()),
        Some(x0) => {
            assert_eq!(x0.len(), n, "x0 length must match the operator size");
            let ax = apply(x0);
            let r = y.iter().zip(&ax).map(|(yv, av)| yv - av).collect();
            (x0.clone(), r)
        }
    }
}

/// Solve (K̃ + λI) β = y by conjugate gradients; K̃ is PSD by Claim 10, so
/// the shifted system is SPD and CG applies.
pub fn solve_krr(op: &dyn KrrOperator, y: &[f64], lambda: f64, opts: &CgOptions) -> CgResult {
    let n = op.n();
    assert_eq!(y.len(), n);
    let apply = |v: &[f64]| -> Vec<f64> {
        let mut out = op.matvec(v);
        axpy(lambda, v, &mut out);
        out
    };
    let y_norm = norm2(y).max(1e-300);
    let (mut beta, mut r) = warm_start(n, y, opts, &apply);
    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);
    let mut history = Vec::new();
    let mut iters = 0;
    let mut rel = rs_old.sqrt() / y_norm;
    while iters < opts.max_iters && rel > opts.tol {
        let ap = apply(&p);
        let p_ap = dot(&p, &ap);
        if p_ap <= 0.0 {
            // numerically lost positive-definiteness; stop with best iterate
            break;
        }
        let alpha = rs_old / p_ap;
        axpy(alpha, &p, &mut beta);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        rel = rs_new.sqrt() / y_norm;
        history.push(rel);
        if opts.verbose {
            eprintln!("  cg iter {:>4}  rel_res {rel:.3e}", iters + 1);
        }
        let ratio = rs_new / rs_old;
        for (pv, rv) in p.iter_mut().zip(&r) {
            *pv = rv + ratio * *pv;
        }
        rs_old = rs_new;
        iters += 1;
    }
    CgResult { beta, iters, rel_residual: rel, converged: rel <= opts.tol, history }
}

/// An explicit preconditioner M ≈ K̃ + λI for [`solve_krr_pcg`]: one
/// application computes z = M⁻¹r.
pub enum Preconditioner {
    /// M = I — reduces PCG to plain CG (identical iterates).
    Identity,
    /// M = diag(K̃) + λ. `inv_diag` stores 1/(K̃_ii + λ); cheap (O(n) per
    /// application) and effective whenever the diagonal is skewed.
    Jacobi { inv_diag: Vec<f64> },
    /// M = K̃_nys + λI, applied in O(n·r) via the Woodbury factorization
    /// from [`crate::sketch::NystromSketch::ridge_precond`].
    Nystrom(NystromPrecond),
}

impl Preconditioner {
    /// Jacobi preconditioner from diag(K̃) (e.g. `KrrOperator::diag`) and
    /// the ridge λ. Requires `diag[i] + lambda > 0` for every i (true for
    /// any PSD operator with λ > 0).
    pub fn jacobi(diag: &[f64], lambda: f64) -> Preconditioner {
        let inv_diag = diag
            .iter()
            .map(|&d| {
                assert!(d + lambda > 0.0, "non-positive Jacobi pivot {}", d + lambda);
                1.0 / (d + lambda)
            })
            .collect();
        Preconditioner::Jacobi { inv_diag }
    }

    /// z = M⁻¹ r.
    pub fn apply(&self, r: &[f64]) -> Vec<f64> {
        match self {
            Preconditioner::Identity => r.to_vec(),
            Preconditioner::Jacobi { inv_diag } => {
                debug_assert_eq!(inv_diag.len(), r.len());
                r.iter().zip(inv_diag).map(|(a, b)| a * b).collect()
            }
            Preconditioner::Nystrom(p) => p.apply(r),
        }
    }

    /// Stable name for configs/reports.
    pub fn name(&self) -> &'static str {
        match self {
            Preconditioner::Identity => "none",
            Preconditioner::Jacobi { .. } => "jacobi",
            Preconditioner::Nystrom(_) => "nystrom",
        }
    }
}

/// Preconditioned CG on (K̃ + λI)β = y with an explicit [`Preconditioner`]
/// M: each iteration applies the operator once and M⁻¹ once, and converges
/// in O(√κ(M⁻¹(K̃+λI))) iterations — the better M approximates K̃ + λI,
/// the flatter the iteration count as λ shrinks (where plain CG blows up).
pub fn solve_krr_pcg(
    op: &dyn KrrOperator,
    y: &[f64],
    lambda: f64,
    opts: &CgOptions,
    precond: &Preconditioner,
) -> CgResult {
    let n = op.n();
    assert_eq!(y.len(), n);
    let apply = |v: &[f64]| -> Vec<f64> {
        let mut out = op.matvec(v);
        axpy(lambda, v, &mut out);
        out
    };
    let y_norm = norm2(y).max(1e-300);
    let (mut beta, mut r) = warm_start(n, y, opts, &apply);
    let mut z = precond.apply(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut history = Vec::new();
    let mut iters = 0;
    let mut rel = norm2(&r) / y_norm;
    while iters < opts.max_iters && rel > opts.tol {
        let ap = apply(&p);
        let denom = dot(&p, &ap);
        if denom <= 0.0 {
            // numerically lost positive-definiteness; stop with best iterate
            break;
        }
        let alpha = rz / denom;
        axpy(alpha, &p, &mut beta);
        axpy(-alpha, &ap, &mut r);
        rel = norm2(&r) / y_norm;
        history.push(rel);
        if opts.verbose {
            eprintln!("  pcg[{}] iter {:>4}  rel_res {rel:.3e}", precond.name(), iters + 1);
        }
        z = precond.apply(&r);
        let rz_new = dot(&r, &z);
        if rz_new <= 0.0 {
            iters += 1;
            break;
        }
        let ratio = rz_new / rz;
        for (pv, zv) in p.iter_mut().zip(&z) {
            *pv = zv + ratio * *pv;
        }
        rz = rz_new;
        iters += 1;
    }
    CgResult { beta, iters, rel_residual: rel, converged: rel <= opts.tol, history }
}

/// Preconditioned CG: solve (K + λI)β = y using the WLSH sketch as the
/// preconditioner — the paper's headline *algorithmic implication* of the
/// OSE property (§1: "K̃+λI can be used as an effective preconditioner").
///
/// The preconditioner application M⁻¹r = (K̃+λI)⁻¹r is itself computed by
/// an inner CG on the sketch (O(n·m) per inner iteration, so the
/// preconditioner is cheap relative to the exact O(n²·d) outer mat-vec).
/// By Thm 11, with m = Õ(n/λ) the preconditioned system has condition
/// number (1+ε)/(1-ε) ⇒ outer CG converges in O(log 1/tol) iterations.
pub fn solve_krr_preconditioned(
    op: &dyn KrrOperator,
    precond: &dyn KrrOperator,
    y: &[f64],
    lambda: f64,
    opts: &CgOptions,
    inner_iters: usize,
) -> CgResult {
    let n = op.n();
    assert_eq!(precond.n(), n);
    assert_eq!(y.len(), n);
    let apply = |v: &[f64]| -> Vec<f64> {
        let mut out = op.matvec(v);
        axpy(lambda, v, &mut out);
        out
    };
    // inner solve (K̃+λI) z = r by fixed-iteration CG
    let apply_m = |r: &[f64]| -> Vec<f64> {
        let mut z = vec![0.0f64; n];
        let mut rr = r.to_vec();
        let mut p = rr.clone();
        let mut rs = dot(&rr, &rr);
        for _ in 0..inner_iters {
            if rs.sqrt() < 1e-14 {
                break;
            }
            let mut ap = precond.matvec(&p);
            axpy(lambda, &p, &mut ap);
            let denom = dot(&p, &ap);
            if denom <= 0.0 {
                break;
            }
            let alpha = rs / denom;
            axpy(alpha, &p, &mut z);
            axpy(-alpha, &ap, &mut rr);
            let rs2 = dot(&rr, &rr);
            let ratio = rs2 / rs;
            for (pv, rv) in p.iter_mut().zip(&rr) {
                *pv = rv + ratio * *pv;
            }
            rs = rs2;
        }
        z
    };
    let y_norm = norm2(y).max(1e-300);
    let mut beta = vec![0.0f64; n];
    let mut r = y.to_vec();
    let mut z = apply_m(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut history = Vec::new();
    let mut iters = 0;
    let mut rel = norm2(&r) / y_norm;
    while iters < opts.max_iters && rel > opts.tol {
        let ap = apply(&p);
        let denom = dot(&p, &ap);
        if denom <= 0.0 {
            break;
        }
        let alpha = rz / denom;
        axpy(alpha, &p, &mut beta);
        axpy(-alpha, &ap, &mut r);
        rel = norm2(&r) / y_norm;
        history.push(rel);
        if opts.verbose {
            eprintln!("  pcg iter {:>4}  rel_res {rel:.3e}", iters + 1);
        }
        z = apply_m(&r);
        let rz_new = dot(&r, &z);
        let ratio = rz_new / rz;
        for (pv, zv) in p.iter_mut().zip(&z) {
            *pv = zv + ratio * *pv;
        }
        rz = rz_new;
        iters += 1;
    }
    CgResult { beta, iters, rel_residual: rel, converged: rel <= opts.tol, history }
}

/// Dense direct KRR solve (Cholesky of K + λI) — ground truth for tests
/// and the small-n fast path. A non-SPD matrix surfaces as
/// [`KrrError::SolveFailed`], like every other solver entry point.
pub fn solve_krr_direct(k: &Matrix, y: &[f64], lambda: f64) -> Result<Vec<f64>, KrrError> {
    let mut a = k.clone();
    a.add_diag(lambda);
    let ch = CholeskyFactor::new(&a, 0.0).map_err(KrrError::SolveFailed)?;
    Ok(ch.solve(y))
}

/// Materialize K̃ from an operator (test helper; O(n²) memory).
pub fn materialize(op: &dyn KrrOperator) -> Matrix {
    let n = op.n();
    let mut k = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let col = op.matvec(&e);
        for i in 0..n {
            k[(i, j)] = col[i];
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::sketch::ExactKernelOp;
    use crate::util::rng::Pcg64;

    fn toy_problem(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f64>) {
        let mut rng = Pcg64::new(seed, 0);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (x, y)
    }

    #[test]
    fn cg_matches_direct_solve() {
        let (n, d) = (50, 3);
        let (x, y) = toy_problem(n, d, 1);
        let op = ExactKernelOp::new(&x, n, d, Kernel::squared_exp(1.0));
        let lambda = 0.1;
        let cg = solve_krr(&op, &y, lambda, &CgOptions { max_iters: 500, tol: 1e-12, verbose: false, x0: None });
        let k = materialize(&op);
        let direct = solve_krr_direct(&k, &y, lambda).unwrap();
        for i in 0..n {
            assert!(
                (cg.beta[i] - direct[i]).abs() < 1e-7 * (1.0 + direct[i].abs()),
                "i={i}: {} vs {}",
                cg.beta[i],
                direct[i]
            );
        }
        assert!(cg.converged);
    }

    #[test]
    fn residual_decreases_monotonically_enough() {
        let (n, d) = (64, 4);
        let (x, y) = toy_problem(n, d, 2);
        let op = ExactKernelOp::new(&x, n, d, Kernel::laplace(1.0));
        let cg = solve_krr(&op, &y, 0.5, &CgOptions::default());
        assert!(cg.history.len() >= 2);
        // CG residuals are not strictly monotone, but the last must be the
        // smallest up to small slack
        let last = *cg.history.last().unwrap();
        let min = cg.history.iter().cloned().fold(f64::MAX, f64::min);
        assert!(last <= 10.0 * min);
    }

    #[test]
    fn lambda_controls_shrinkage() {
        let (n, d) = (40, 2);
        let (x, y) = toy_problem(n, d, 3);
        let op = ExactKernelOp::new(&x, n, d, Kernel::squared_exp(1.0));
        let small = solve_krr(&op, &y, 1e-3, &CgOptions::default());
        let large = solve_krr(&op, &y, 100.0, &CgOptions::default());
        let ns: f64 = norm2(&small.beta);
        let nl: f64 = norm2(&large.beta);
        assert!(nl < ns, "large-λ norm {nl} should shrink below {ns}");
    }

    #[test]
    fn preconditioned_cg_matches_plain_cg_solution() {
        let (n, d) = (60, 3);
        let (x, y) = toy_problem(n, d, 5);
        let op = ExactKernelOp::new(&x, n, d, Kernel::laplace(1.0));
        let lambda = 0.05;
        let opts = CgOptions { max_iters: 400, tol: 1e-10, verbose: false, x0: None };
        let plain = solve_krr(&op, &y, lambda, &opts);
        let sketch = crate::sketch::WlshSketch::build_mem(
            &x,
            &crate::sketch::WlshBuildParams::new(n, d, 256).seed(9),
        );
        let pcg = solve_krr_preconditioned(&op, &sketch, &y, lambda, &opts, 30);
        for i in 0..n {
            assert!(
                (plain.beta[i] - pcg.beta[i]).abs() < 1e-6 * (1.0 + plain.beta[i].abs()),
                "i={i}"
            );
        }
    }

    #[test]
    fn preconditioning_reduces_iterations_on_illconditioned_system() {
        // small λ ⇒ ill-conditioned (K+λI); a good sketch preconditioner
        // must cut the outer iteration count.
        let (n, d) = (150, 2);
        let (x, y) = toy_problem(n, d, 6);
        let op = ExactKernelOp::new(&x, n, d, Kernel::laplace(0.3));
        let lambda = 1e-3;
        let opts = CgOptions { max_iters: 500, tol: 1e-8, verbose: false, x0: None };
        let plain = solve_krr(&op, &y, lambda, &opts);
        let sketch = crate::sketch::WlshSketch::build_mem(
            &x,
            &crate::sketch::WlshBuildParams::new(n, d, 2048).scale(0.3).seed(11),
        );
        let pcg = solve_krr_preconditioned(&op, &sketch, &y, lambda, &opts, 60);
        assert!(
            pcg.iters * 2 <= plain.iters,
            "pcg {} iters vs plain {} — preconditioner ineffective",
            pcg.iters,
            plain.iters
        );
    }

    #[test]
    fn identity_pcg_reproduces_plain_cg() {
        // With M = I the PCG recursion collapses to plain CG: same inner
        // products, same iterates.
        let (n, d) = (48, 3);
        let (x, y) = toy_problem(n, d, 7);
        let op = ExactKernelOp::new(&x, n, d, Kernel::squared_exp(1.0));
        let opts = CgOptions { max_iters: 200, tol: 1e-9, verbose: false, x0: None };
        let plain = solve_krr(&op, &y, 0.05, &opts);
        let pcg = solve_krr_pcg(&op, &y, 0.05, &opts, &Preconditioner::Identity);
        assert_eq!(plain.iters, pcg.iters);
        for i in 0..n {
            assert!(
                (plain.beta[i] - pcg.beta[i]).abs() < 1e-12 * (1.0 + plain.beta[i].abs()),
                "i={i}: {} vs {}",
                plain.beta[i],
                pcg.beta[i]
            );
        }
    }

    #[test]
    fn jacobi_pcg_matches_direct_solve() {
        let (n, d) = (40, 2);
        let (x, y) = toy_problem(n, d, 8);
        let op = ExactKernelOp::new(&x, n, d, Kernel::laplace(1.0));
        let lambda = 0.2;
        let diag = op.diag().unwrap();
        let pre = Preconditioner::jacobi(&diag, lambda);
        let opts = CgOptions { max_iters: 500, tol: 1e-12, verbose: false, x0: None };
        let pcg = solve_krr_pcg(&op, &y, lambda, &opts, &pre);
        let k = materialize(&op);
        let direct = solve_krr_direct(&k, &y, lambda).unwrap();
        assert!(pcg.converged);
        for i in 0..n {
            assert!(
                (pcg.beta[i] - direct[i]).abs() < 1e-7 * (1.0 + direct[i].abs()),
                "i={i}: {} vs {}",
                pcg.beta[i],
                direct[i]
            );
        }
    }

    #[test]
    fn nystrom_pcg_matches_direct_solve() {
        let (n, d) = (60, 3);
        let (x, y) = toy_problem(n, d, 9);
        let kernel = Kernel::squared_exp(1.0);
        let op = ExactKernelOp::new(&x, n, d, kernel.clone());
        let lambda = 0.05;
        let nys = crate::sketch::NystromSketch::build(&x, n, d, 24, kernel, 10).unwrap();
        let pre = Preconditioner::Nystrom(nys.ridge_precond(lambda).unwrap());
        let opts = CgOptions { max_iters: 500, tol: 1e-11, verbose: false, x0: None };
        let pcg = solve_krr_pcg(&op, &y, lambda, &opts, &pre);
        let k = materialize(&op);
        let direct = solve_krr_direct(&k, &y, lambda).unwrap();
        assert!(pcg.converged);
        for i in 0..n {
            assert!(
                (pcg.beta[i] - direct[i]).abs() < 1e-6 * (1.0 + direct[i].abs()),
                "i={i}: {} vs {}",
                pcg.beta[i],
                direct[i]
            );
        }
    }

    #[test]
    fn preconditioner_names_are_stable() {
        assert_eq!(Preconditioner::Identity.name(), "none");
        assert_eq!(Preconditioner::jacobi(&[1.0, 2.0], 0.5).name(), "jacobi");
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let (n, d) = (10, 2);
        let (x, _) = toy_problem(n, d, 4);
        let op = ExactKernelOp::new(&x, n, d, Kernel::matern52(1.0));
        let cg = solve_krr(&op, &vec![0.0; n], 1.0, &CgOptions::default());
        assert!(cg.beta.iter().all(|&b| b == 0.0));
        assert_eq!(cg.iters, 0);
    }
}
