//! Offline-substrate utilities: PRNG, JSON, CLI parsing, property testing,
//! and wall-clock instrumentation. These replace crates (`rand`,
//! `serde_json`, `clap`, `proptest`, `criterion`) that are not available in
//! the offline vendored registry — see DESIGN.md §5.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
