//! Offline-substrate utilities: PRNG, JSON, CLI parsing, property testing,
//! scoped-thread fan-out, and wall-clock instrumentation. These replace
//! crates (`rand`, `serde_json`, `clap`, `proptest`, `criterion`, `rayon`)
//! that are not available in the offline vendored registry — see
//! DESIGN.md §5.

pub mod cli;
pub mod json;
pub mod mem;
pub mod par;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod timer;
