//! Wall-clock instrumentation + a micro-bench runner (criterion substitute).

use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Result of a micro-benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>10}  min {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            human(self.mean_secs),
            human(self.min_secs),
            human(self.p50_secs),
            human(self.p99_secs),
        )
    }
}

/// Human-readable duration.
pub fn human(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}us", secs * 1e6)
    }
}

/// Run `f` repeatedly for ~`budget_secs` (after one warmup) and report
/// timing percentiles. The closure's return value is black-boxed to keep
/// the optimizer honest.
pub fn bench<F, R>(name: &str, budget_secs: f64, mut f: F) -> BenchStats
where
    F: FnMut() -> R,
{
    std::hint::black_box(f()); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_secs || times.is_empty() {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
        if times.len() >= 10_000 {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_secs: times.iter().sum::<f64>() / n as f64,
        min_secs: times[0],
        p50_secs: times[n / 2],
        p99_secs: times[(n * 99 / 100).min(n - 1)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop-ish", 0.02, || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 1);
        assert!(s.min_secs <= s.p50_secs && s.p50_secs <= s.p99_secs);
        assert!(s.mean_secs > 0.0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(2.0), "2.000s");
        assert_eq!(human(0.002), "2.000ms");
        assert_eq!(human(2e-6), "2.000us");
    }
}
