//! Wall-clock instrumentation + a micro-bench runner (criterion substitute).

use crate::util::stats;
use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Result of a micro-benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>10}  min {:>10}  p50 {:>10}  p99 {:>10}",
            self.name,
            self.iters,
            human(self.mean_secs),
            human(self.min_secs),
            human(self.p50_secs),
            human(self.p99_secs),
        )
    }
}

/// Human-readable duration.
pub fn human(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}us", secs * 1e6)
    }
}

/// Run `f` repeatedly for ~`budget_secs` (after one warmup) and report
/// timing percentiles. The closure's return value is black-boxed to keep
/// the optimizer honest.
pub fn bench<F, R>(name: &str, budget_secs: f64, mut f: F) -> BenchStats
where
    F: FnMut() -> R,
{
    std::hint::black_box(f()); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_secs || times.is_empty() {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
        if times.len() >= 10_000 {
            break;
        }
    }
    summarize(name, times)
}

/// Collapse raw iteration timings into `BenchStats` using the shared
/// `util::stats` definitions: a total-order sort (a NaN timing cannot
/// abort a bench run) and nearest-rank percentiles — the same rule the
/// serving histogram uses, replacing the old truncating `times[n/2]` /
/// `times[n*99/100]` indexing that over-reported at small iteration
/// counts.
fn summarize(name: &str, mut times: Vec<f64>) -> BenchStats {
    stats::sort_samples(&mut times);
    let n = times.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_secs: times.iter().sum::<f64>() / n as f64,
        min_secs: times[0],
        p50_secs: stats::percentile(&times, 0.50),
        p99_secs: stats::percentile(&times, 0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop-ish", 0.02, || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 1);
        assert!(s.min_secs <= s.p50_secs && s.p50_secs <= s.p99_secs);
        assert!(s.mean_secs > 0.0);
    }

    #[test]
    fn summarize_uses_nearest_rank_percentiles() {
        // n=2: nearest-rank p50 is the LOWER sample (rank ceil(0.5·2)=1);
        // the old `times[n / 2]` indexing returned the upper one.
        let s = summarize("two", vec![2.0, 1.0]);
        assert_eq!(s.p50_secs, 1.0);
        assert_eq!(s.p99_secs, 2.0);
        assert_eq!(s.min_secs, 1.0);
        // n=4: p50 → rank 2 (old rule said index 2 → third element).
        let s = summarize("four", vec![40.0, 10.0, 30.0, 20.0]);
        assert_eq!(s.p50_secs, 20.0);
        assert_eq!(s.p99_secs, 40.0);
        // n=100: p99 → rank 99, not the max.
        let s = summarize("hundred", (1..=100).map(|i| i as f64).collect());
        assert_eq!(s.p50_secs, 50.0);
        assert_eq!(s.p99_secs, 99.0);
    }

    #[test]
    fn summarize_survives_a_nan_timing() {
        // A poisoned timing must not abort the whole bench run; NaN sorts
        // past the finite samples and the low quantiles stay finite.
        let s = summarize("nan", vec![3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.min_secs, 1.0);
        assert_eq!(s.p50_secs, 2.0);
        assert!(s.iters == 4);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(2.0), "2.000s");
        assert_eq!(human(0.002), "2.000ms");
        assert_eq!(human(2e-6), "2.000us");
    }
}
