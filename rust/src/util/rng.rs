//! Deterministic PRNG + distributions (the `rand` crate is unavailable
//! offline; this is a self-contained substrate).
//!
//! Generator: PCG XSL-RR 128/64 (O'Neill 2014) — 128-bit LCG state, 64-bit
//! output, passes BigCrush, trivially seedable/forkable for per-worker
//! streams. Distributions: uniform, Box–Muller normal, Marsaglia–Tsang
//! gamma (the paper samples LSH grid widths w ~ Gamma(k, 1): k=2 for the
//! Laplace/rect configuration, k=7 for the smooth Table-1 kernel),
//! exponential and Cauchy (spectral sampling of Laplace-kernel GPs).

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

/// PCG XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    spare_normal: Option<f64>,
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id (distinct streams are
    /// statistically independent — used to fork per-instance/worker RNGs).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Fork an independent stream derived from this generator.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // widening-multiply rejection-free mapping (Lemire); bias < 2^-64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal (Box–Muller with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare_normal.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Exponential with rate 1.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.uniform()).ln()
    }

    /// Standard Cauchy (spectral density of the Laplace kernel, per dim).
    pub fn cauchy(&mut self) -> f64 {
        (std::f64::consts::PI * (self.uniform() - 0.5)).tan()
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang squeeze (shape >= 1 direct,
    /// shape < 1 via the boosting identity).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * (x * x) * (x * x)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Random odd 32-bit mixing multiplier (for the i32 bucket-id collapse).
    pub fn odd_i32(&mut self) -> i32 {
        (self.next_u32() | 1) as i32
    }

    /// Random odd 64-bit mixing multiplier (native u64 bucket ids).
    pub fn odd_u64(&mut self) -> u64 {
        self.next_u64() | 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_dependent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        let mut c = Pcg64::new(42, 2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(7, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Pcg64::new(3, 0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11, 0);
        let n = 200_000;
        let (mut s, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
            s4 += x * x * x * x;
        }
        assert!((s / n as f64).abs() < 0.01);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s4 / n as f64 - 3.0).abs() < 0.1); // kurtosis
    }

    #[test]
    fn gamma_moments_shape2_and_7() {
        // Gamma(k,1): mean k, variance k — the paper's two width laws.
        let mut r = Pcg64::new(5, 0);
        for shape in [2.0_f64, 7.0] {
            let n = 100_000;
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..n {
                let x = r.gamma(shape);
                assert!(x > 0.0);
                s += x;
                s2 += x * x;
            }
            let mean = s / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - shape).abs() < 0.05 * shape, "mean {mean}");
            assert!((var - shape).abs() < 0.1 * shape, "var {var}");
        }
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Pcg64::new(9, 0);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let x = r.gamma(0.5);
            assert!(x >= 0.0 && x.is_finite());
            s += x;
        }
        assert!((s / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13, 0);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential()).sum();
        assert!((s / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn cauchy_median_zero() {
        let mut r = Pcg64::new(17, 0);
        let n = 100_000;
        let below = (0..n).filter(|_| r.cauchy() < 0.0).count();
        assert!((below as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Pcg64::new(19, 0);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }
}
