//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; collects unknown flags as errors with a usage hint.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes it as
        // the value, so boolean flags must use `--flag=true` or come last.
        let a = parse("train data.csv --n 100 --lambda=0.5 --verbose");
        assert_eq!(a.positional, vec!["train", "data.csv"]);
        assert_eq!(a.get_usize("n", 0), 100);
        assert_eq!(a.get_f64("lambda", 0.0), 0.5);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("--fast --m 8");
        assert!(a.get_bool("fast"));
        assert_eq!(a.get_usize("m", 0), 8);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("backend", "native"), "native");
        assert_eq!(a.get_usize("m", 64), 64);
    }
}
