//! Scoped-thread fan-out — the crate's one parallel-execution primitive
//! (rayon is unavailable in the offline registry; std::thread::scope is
//! enough for the embarrassingly-parallel loops this repo has: per-LSH-
//! instance sketch work and per-query-chunk prediction work).
//!
//! Determinism contract: `fan_out(n, threads, f)` returns exactly
//! `(0..n).map(f)` in index order, for every thread count. Each index is
//! evaluated once, by exactly one thread, and the results are stitched
//! back together in index order — so any caller that reduces the returned
//! vector sequentially gets a bit-identical result regardless of
//! parallelism. Callers must NOT make `f` depend on which thread runs it.

use std::sync::OnceLock;

/// Worker-thread budget: `WLSH_THREADS` env override, else the machine's
/// available parallelism. Cached after first read (called on hot paths).
pub fn num_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("WLSH_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Evaluate `f(0), f(1), ..., f(n-1)` across up to `threads` scoped worker
/// threads and return the results in index order.
///
/// Indices are split into contiguous chunks (one per worker, like
/// `coordinator/router.rs`); results are concatenated chunk-by-chunk, so
/// the output ordering — and therefore any order-sensitive reduction the
/// caller performs — is independent of `threads`.
pub fn fan_out<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = if threads > n { n } else { threads };
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let f = &f;
    let mut out: Vec<T> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            handles.push(scope.spawn(move || (lo..hi).map(f).collect::<Vec<T>>()));
        }
        for h in handles {
            out.extend(h.join().expect("fan_out worker panicked"));
        }
    });
    out
}

/// Apply `f(i, &mut states[i])` for every index, splitting the slice into
/// contiguous per-worker blocks. The streaming sketch builders use this to
/// advance m independent per-instance accumulators over one shared data
/// chunk without collecting intermediate results.
///
/// Determinism contract: each state is visited exactly once, by exactly one
/// thread, and `f` must depend only on `(i, states[i])` plus captured
/// immutable context — never on which thread runs it — so the final states
/// are identical for every thread count.
pub fn fan_out_mut<S, F>(states: &mut [S], threads: usize, f: F)
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    let n = states.len();
    let workers = if threads > n { n } else { threads };
    if workers <= 1 {
        for (i, s) in states.iter_mut().enumerate() {
            f(i, s);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    let f = &f;
    std::thread::scope(|scope| {
        for (w, block) in states.chunks_mut(chunk).enumerate() {
            scope.spawn(move || {
                for (k, s) in block.iter_mut().enumerate() {
                    f(w * chunk + k, s);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_for_every_thread_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i + 1).collect();
        for threads in [1, 2, 3, 8, 200] {
            let got = fan_out(97, threads, |i| i * i + 1);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_tiny_inputs() {
        assert_eq!(fan_out(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(fan_out(1, 4, |i| i + 10), vec![10]);
        assert_eq!(fan_out(2, 16, |i| i), vec![0, 1]);
    }

    #[test]
    fn every_index_evaluated_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let out = fan_out(64, 8, |i| {
            calls[i].fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
        assert!(calls.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn ordered_reduction_is_thread_count_invariant() {
        // The contract the WLSH mat-vec relies on: summing the returned
        // per-index vectors in index order is bit-identical for any
        // thread count.
        let term = |i: usize| 1.0f64 / (i as f64 + 0.37);
        let reduce = |parts: Vec<f64>| parts.iter().fold(0.0f64, |a, &b| a + b);
        let want = reduce(fan_out(1000, 1, term));
        for threads in [2, 5, 8] {
            let got = reduce(fan_out(1000, threads, term));
            assert!(got == want, "threads={threads}: {got} vs {want}");
        }
    }

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn fan_out_mut_visits_every_state_once_in_place() {
        for threads in [1usize, 2, 3, 8, 200] {
            let mut states: Vec<(usize, usize)> = (0..97).map(|i| (i, 0)).collect();
            fan_out_mut(&mut states, threads, |i, s| {
                assert_eq!(s.0, i, "index/state mismatch");
                s.1 += i * i + 1;
            });
            for (i, s) in states.iter().enumerate() {
                assert_eq!(s.1, i * i + 1, "threads={threads} state {i}");
            }
        }
    }

    #[test]
    fn fan_out_mut_handles_empty_and_tiny_slices() {
        let mut empty: Vec<usize> = Vec::new();
        fan_out_mut(&mut empty, 4, |_, _| unreachable!());
        let mut one = vec![7usize];
        fan_out_mut(&mut one, 4, |_, s| *s += 1);
        assert_eq!(one, vec![8]);
    }
}
