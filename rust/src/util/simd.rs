//! Runtime-dispatched SIMD kernels for the compute hot paths — `std::arch`
//! AVX2 on x86_64 and NEON on aarch64, zero external crates, selected once
//! per process and overridable via the `WLSH_SIMD` environment variable
//! (`auto` — the default — detects the ISA at startup; `on` is a synonym;
//! `off` forces the scalar reference kernels).
//!
//! **Bit-identity contract.** Every kernel here has exactly one numeric
//! behavior: the scalar fallback *is* the reference implementation, and
//! each vectorized variant reproduces it bit for bit —
//!
//! * element-wise kernels ([`axpy_f32`], [`axpy_f32_f64`],
//!   [`scaled_gather_add`], [`hash_cells`], [`scale_cos`]) perform the
//!   same IEEE-754 operation per element in both paths (no FMA
//!   contraction anywhere), so lanes and scalars round identically;
//! * reduction kernels ([`dot_f32`], [`weighted_gather_sum`]) commit to a
//!   **fixed 4-lane-strided order**: logical lane `j` accumulates the
//!   elements with index ≡ `j` (mod 4), the tail past the last multiple
//!   of 4 accumulates separately, and the five partials always collapse
//!   as `tail + lane0 + lane1 + lane2 + lane3`. The scalar reference
//!   walks the same order with four independent accumulators, so a
//!   256-bit SIMD register (or two 128-bit NEON registers) reproduces it
//!   exactly;
//! * [`scale_cos`] replaces libm's `cosf` with a deterministic f64
//!   Cody–Waite + Taylor kernel shared verbatim by both paths (libm is
//!   platform-varying *and* unvectorizable; the shared polynomial is
//!   neither). Accuracy is ~1e-10 absolute, far below f32 rounding.
//!
//! Consequently `WLSH_SIMD=on` vs `off` changes wall-clock only — sketch
//! tables, bucket loads, mat-vecs, CG coefficients, and served
//! predictions are all bit-identical (the documented ULP tolerance on f32
//! serving paths is **0**; `tests/simd_equivalence.rs` pins this across
//! worker counts). Kernels may freely route short slices to the scalar
//! path — the answer cannot differ.
//!
//! aarch64 notes: NEON has no gather instruction and only 2-wide f64
//! lanes, so the gather kernels and [`scale_cos`] use the scalar
//! reference there; the element-wise f32 kernels and [`dot_f32`]
//! vectorize.

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set family the kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Reference implementation (also the `WLSH_SIMD=off` override).
    Scalar,
    /// x86_64 AVX2 (256-bit), detected via `is_x86_feature_detected!`.
    Avx2,
    /// aarch64 NEON (128-bit), baseline on every aarch64 target.
    Neon,
}

/// Cached dispatch state: 0 = uninitialized, else `code(Isa)`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn code(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 1,
        Isa::Avx2 => 2,
        Isa::Neon => 3,
    }
}

/// Best SIMD ISA this machine supports, ignoring the `WLSH_SIMD` override.
pub fn detected() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is part of the baseline aarch64 ABI — always present.
        return Isa::Neon;
    }
    #[allow(unreachable_code)]
    Isa::Scalar
}

/// The ISA the kernels currently dispatch to. First call resolves the
/// `WLSH_SIMD` env override (`off` forces [`Isa::Scalar`]; `auto`/`on`/
/// unset take [`detected`]) and caches the answer; later calls are one
/// relaxed atomic load (the kernels call this per invocation).
pub fn active() -> Isa {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => Isa::Scalar,
        2 => Isa::Avx2,
        3 => Isa::Neon,
        _ => {
            let isa = match std::env::var("WLSH_SIMD").as_deref() {
                Ok("off") | Ok("0") | Ok("scalar") => Isa::Scalar,
                _ => detected(),
            };
            ACTIVE.store(code(isa), Ordering::Relaxed);
            isa
        }
    }
}

/// Override the dispatch state in-process: `false` forces the scalar
/// reference, `true` restores the detected ISA. The equivalence tests and
/// `bench_matvec`'s SIMD section flip this to compare both paths in one
/// process without re-spawning under a different environment.
pub fn set_enabled(enabled: bool) {
    let isa = if enabled { detected() } else { Isa::Scalar };
    ACTIVE.store(code(isa), Ordering::Relaxed);
}

/// Drop any cached/overridden state; the next [`active`] re-reads
/// `WLSH_SIMD` and re-detects.
pub fn reset() {
    ACTIVE.store(0, Ordering::Relaxed);
}

/// Short display name of an ISA (`"avx2"` / `"neon"` / `"scalar"`).
pub fn name(isa: Isa) -> &'static str {
    match isa {
        Isa::Scalar => "scalar",
        Isa::Avx2 => "avx2",
        Isa::Neon => "neon",
    }
}

/// `name(active())` — what the kernels are dispatching to right now.
pub fn active_name() -> &'static str {
    name(active())
}

// ---------------------------------------------------------------------------
// dot product (f32 inputs, f64 accumulation)
// ---------------------------------------------------------------------------

/// Dot product over f32 slices with f64 accumulation, in the fixed
/// 4-lane-strided reduction order (see the module docs). This is the
/// serving hot path behind `linalg::dot_f32`.
pub fn dot_f32(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if x.len() >= 4 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        return unsafe { dot_f32_avx2(x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if x.len() >= 4 && active() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { dot_f32_neon(x, y) };
    }
    dot_f32_scalar(x, y)
}

/// Reference: 4 independent lane accumulators + a tail accumulator,
/// collapsed as `tail + a0 + a1 + a2 + a3`.
fn dot_f32_scalar(x: &[f32], y: &[f32]) -> f64 {
    let n = x.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i + 4 <= n {
        a0 += x[i] as f64 * y[i] as f64;
        a1 += x[i + 1] as f64 * y[i + 1] as f64;
        a2 += x[i + 2] as f64 * y[i + 2] as f64;
        a3 += x[i + 3] as f64 * y[i + 3] as f64;
        i += 4;
    }
    let mut acc = 0.0f64;
    while i < n {
        acc += x[i] as f64 * y[i] as f64;
        i += 1;
    }
    acc + a0 + a1 + a2 + a3
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_f32_avx2(x: &[f32], y: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let n = x.len();
    // One f64 SIMD lane per logical lane: lane j accumulates index ≡ j
    // (mod 4), exactly like the scalar a0..a3.
    let mut acc4 = _mm256_setzero_pd();
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let yv = _mm256_cvtps_pd(_mm_loadu_ps(y.as_ptr().add(i)));
        acc4 = _mm256_add_pd(acc4, _mm256_mul_pd(xv, yv));
        i += 4;
    }
    let mut acc = 0.0f64;
    while i < n {
        acc += x[i] as f64 * y[i] as f64;
        i += 1;
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc4);
    acc + lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_f32_neon(x: &[f32], y: &[f32]) -> f64 {
    use std::arch::aarch64::*;
    let n = x.len();
    // Two f64x2 registers hold logical lanes {0,1} and {2,3}.
    let mut acc01 = vdupq_n_f64(0.0);
    let mut acc23 = vdupq_n_f64(0.0);
    let mut i = 0;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let yv = vld1q_f32(y.as_ptr().add(i));
        let xlo = vcvt_f64_f32(vget_low_f32(xv));
        let xhi = vcvt_high_f64_f32(xv);
        let ylo = vcvt_f64_f32(vget_low_f32(yv));
        let yhi = vcvt_high_f64_f32(yv);
        acc01 = vaddq_f64(acc01, vmulq_f64(xlo, ylo));
        acc23 = vaddq_f64(acc23, vmulq_f64(xhi, yhi));
        i += 4;
    }
    let mut acc = 0.0f64;
    while i < n {
        acc += x[i] as f64 * y[i] as f64;
        i += 1;
    }
    acc + vgetq_lane_f64::<0>(acc01)
        + vgetq_lane_f64::<1>(acc01)
        + vgetq_lane_f64::<0>(acc23)
        + vgetq_lane_f64::<1>(acc23)
}

// ---------------------------------------------------------------------------
// CSR bucket-load reduction (gather + weighted sum)
// ---------------------------------------------------------------------------

/// One bucket's load: `Σ_k w[k] · beta[members[k]]` in the fixed
/// 4-lane-strided reduction order. The WLSH CSR bucket-load pass calls
/// this once per bucket with that bucket's member range.
///
/// `members` values must index into `beta` (and, for the AVX2 gather,
/// `beta.len()` must fit in i32 — every caller indexes training rows, so
/// this holds by construction).
pub fn weighted_gather_sum(w: &[f32], members: &[u32], beta: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), members.len());
    debug_assert!(beta.len() <= i32::MAX as usize);
    #[cfg(target_arch = "x86_64")]
    if w.len() >= 4 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        return unsafe { weighted_gather_sum_avx2(w, members, beta) };
    }
    weighted_gather_sum_scalar(w, members, beta)
}

fn weighted_gather_sum_scalar(w: &[f32], members: &[u32], beta: &[f64]) -> f64 {
    let n = w.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i + 4 <= n {
        a0 += w[i] as f64 * beta[members[i] as usize];
        a1 += w[i + 1] as f64 * beta[members[i + 1] as usize];
        a2 += w[i + 2] as f64 * beta[members[i + 2] as usize];
        a3 += w[i + 3] as f64 * beta[members[i + 3] as usize];
        i += 4;
    }
    let mut acc = 0.0f64;
    while i < n {
        acc += w[i] as f64 * beta[members[i] as usize];
        i += 1;
    }
    acc + a0 + a1 + a2 + a3
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn weighted_gather_sum_avx2(w: &[f32], members: &[u32], beta: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = w.len();
    let mut acc4 = _mm256_setzero_pd();
    let mut i = 0;
    while i + 4 <= n {
        let idx = _mm_loadu_si128(members.as_ptr().add(i) as *const __m128i);
        let bv = _mm256_i32gather_pd::<8>(beta.as_ptr(), idx);
        let wv = _mm256_cvtps_pd(_mm_loadu_ps(w.as_ptr().add(i)));
        acc4 = _mm256_add_pd(acc4, _mm256_mul_pd(wv, bv));
        i += 4;
    }
    let mut acc = 0.0f64;
    while i < n {
        acc += w[i] as f64 * beta[members[i] as usize];
        i += 1;
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc4);
    acc + lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

// ---------------------------------------------------------------------------
// gather + scaled accumulate (the fused mat-vec's per-point pass)
// ---------------------------------------------------------------------------

/// Element-wise `out[i] += w[i] · loads[bucket_of[i]]` — the fused
/// mat-vec's "combine loads back into point space" pass. Pure per-element
/// arithmetic, so every dispatch path is trivially bit-identical.
pub fn scaled_gather_add(out: &mut [f64], w: &[f32], bucket_of: &[u32], loads: &[f64]) {
    debug_assert_eq!(out.len(), w.len());
    debug_assert_eq!(out.len(), bucket_of.len());
    #[cfg(target_arch = "x86_64")]
    if out.len() >= 4 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        unsafe { scaled_gather_add_avx2(out, w, bucket_of, loads) };
        return;
    }
    scaled_gather_add_scalar(out, w, bucket_of, loads);
}

fn scaled_gather_add_scalar(out: &mut [f64], w: &[f32], bucket_of: &[u32], loads: &[f64]) {
    for ((o, &wv), &b) in out.iter_mut().zip(w).zip(bucket_of) {
        *o += wv as f64 * loads[b as usize];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scaled_gather_add_avx2(out: &mut [f64], w: &[f32], bucket_of: &[u32], loads: &[f64]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let mut i = 0;
    while i + 4 <= n {
        let idx = _mm_loadu_si128(bucket_of.as_ptr().add(i) as *const __m128i);
        let lv = _mm256_i32gather_pd::<8>(loads.as_ptr(), idx);
        let wv = _mm256_cvtps_pd(_mm_loadu_ps(w.as_ptr().add(i)));
        let ov = _mm256_loadu_pd(out.as_ptr().add(i));
        _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_add_pd(ov, _mm256_mul_pd(wv, lv)));
        i += 4;
    }
    scaled_gather_add_scalar(&mut out[i..], &w[i..], &bucket_of[i..], &loads[..]);
}

// ---------------------------------------------------------------------------
// f32 axpy (RFF feature accumulation)
// ---------------------------------------------------------------------------

/// Element-wise `y[i] += alpha · x[i]` over f32 slices — RFF's
/// `z += x_l · Ω_l` row accumulation. One multiply and one add per
/// element in every path (no FMA), so lanes round exactly like scalars.
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if x.len() >= 8 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        unsafe { axpy_f32_avx2(alpha, x, y) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if x.len() >= 4 && active() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { axpy_f32_neon(alpha, x, y) };
        return;
    }
    axpy_f32_scalar(alpha, x, y);
}

fn axpy_f32_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let av = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
        i += 8;
    }
    axpy_f32_scalar(alpha, &x[i..], &mut y[i..]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_f32_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = x.len();
    let av = vdupq_n_f32(alpha);
    let mut i = 0;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let yv = vld1q_f32(y.as_ptr().add(i));
        vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
        i += 4;
    }
    axpy_f32_scalar(alpha, &x[i..], &mut y[i..]);
}

// ---------------------------------------------------------------------------
// f32 → f64 axpy (RFF θ = Zᵀβ accumulation)
// ---------------------------------------------------------------------------

/// Element-wise `y[i] += alpha · (x[i] as f64)` — RFF's θ accumulation.
/// The f32→f64 widening is exact, so every path rounds identically.
pub fn axpy_f32_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if x.len() >= 4 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        unsafe { axpy_f32_f64_avx2(alpha, x, y) };
        return;
    }
    axpy_f32_f64_scalar(alpha, x, y);
}

fn axpy_f32_f64_scalar(alpha: f64, x: &[f32], y: &mut [f64]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_f64_avx2(alpha: f64, x: &[f32], y: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let av = _mm256_set1_pd(alpha);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let yv = _mm256_loadu_pd(y.as_ptr().add(i));
        _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_add_pd(yv, _mm256_mul_pd(av, xv)));
        i += 4;
    }
    axpy_f32_f64_scalar(alpha, &x[i..], &mut y[i..]);
}

// ---------------------------------------------------------------------------
// LSH cell computation (hash evaluation)
// ---------------------------------------------------------------------------

/// Per-dimension LSH cells for one row: `t_l = (x_l − z_l) · inv_w_l`,
/// `c_l = floor(t_l + 0.5)`, residual `r_l = c_l − t_l`. Pure
/// element-wise f32 arithmetic (`floor` rounds toward −∞ in both paths),
/// so the cells — and therefore bucket ids and smooth weights derived
/// from them — are bit-identical under every dispatch.
pub fn hash_cells(x: &[f32], z: &[f32], inv_w: &[f32], c: &mut [f32], r: &mut [f32]) {
    debug_assert_eq!(x.len(), z.len());
    debug_assert_eq!(x.len(), inv_w.len());
    debug_assert_eq!(x.len(), c.len());
    debug_assert_eq!(x.len(), r.len());
    #[cfg(target_arch = "x86_64")]
    if x.len() >= 8 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        unsafe { hash_cells_avx2(x, z, inv_w, c, r) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if x.len() >= 4 && active() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { hash_cells_neon(x, z, inv_w, c, r) };
        return;
    }
    hash_cells_scalar(x, z, inv_w, c, r);
}

fn hash_cells_scalar(x: &[f32], z: &[f32], inv_w: &[f32], c: &mut [f32], r: &mut [f32]) {
    let n = x.len();
    let mut l = 0;
    while l < n {
        let t = (x[l] - z[l]) * inv_w[l];
        let cl = (t + 0.5).floor();
        c[l] = cl;
        r[l] = cl - t;
        l += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hash_cells_avx2(x: &[f32], z: &[f32], inv_w: &[f32], c: &mut [f32], r: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let half = _mm256_set1_ps(0.5);
    let mut l = 0;
    while l + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(l));
        let zv = _mm256_loadu_ps(z.as_ptr().add(l));
        let iw = _mm256_loadu_ps(inv_w.as_ptr().add(l));
        let t = _mm256_mul_ps(_mm256_sub_ps(xv, zv), iw);
        let cv = _mm256_floor_ps(_mm256_add_ps(t, half));
        _mm256_storeu_ps(c.as_mut_ptr().add(l), cv);
        _mm256_storeu_ps(r.as_mut_ptr().add(l), _mm256_sub_ps(cv, t));
        l += 8;
    }
    hash_cells_scalar(&x[l..], &z[l..], &inv_w[l..], &mut c[l..], &mut r[l..]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn hash_cells_neon(x: &[f32], z: &[f32], inv_w: &[f32], c: &mut [f32], r: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = x.len();
    let half = vdupq_n_f32(0.5);
    let mut l = 0;
    while l + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(l));
        let zv = vld1q_f32(z.as_ptr().add(l));
        let iw = vld1q_f32(inv_w.as_ptr().add(l));
        let t = vmulq_f32(vsubq_f32(xv, zv), iw);
        let cv = vrndmq_f32(vaddq_f32(t, half));
        vst1q_f32(c.as_mut_ptr().add(l), cv);
        vst1q_f32(r.as_mut_ptr().add(l), vsubq_f32(cv, t));
        l += 4;
    }
    hash_cells_scalar(&x[l..], &z[l..], &inv_w[l..], &mut c[l..], &mut r[l..]);
}

// ---------------------------------------------------------------------------
// deterministic cosine (RFF featurization finish)
// ---------------------------------------------------------------------------

// Cody–Waite split of π/2: PIO2_1 carries the first 33 mantissa bits, so
// n·PIO2_1 is exact for |n| < 2²⁰ and the reduction error collapses to
// the rounding of n·PIO2_1T (fdlibm's medium-path constants).
const TWO_OVER_PI: f64 = 6.36619772367581382433e-01;
const PIO2_1: f64 = 1.57079632673412561417e+00;
const PIO2_1T: f64 = 6.07710050650619224932e-11;

// Taylor kernels on |r| ≤ π/4: truncation ≲ 1.2e-10 (cos) / 1.8e-9·r
// (sin), far below f32 rounding at 2⁻²⁴.
const COS_C2: f64 = -0.5;
const COS_C4: f64 = 4.16666666666666666667e-2;
const COS_C6: f64 = -1.38888888888888888889e-3;
const COS_C8: f64 = 2.48015873015873015873e-5;
const COS_C10: f64 = -2.75573192239858906526e-7;
const SIN_S3: f64 = -1.66666666666666666667e-1;
const SIN_S5: f64 = 8.33333333333333333333e-3;
const SIN_S7: f64 = -1.98412698412698412698e-4;
const SIN_S9: f64 = 2.75573192239858906526e-6;

/// Shared deterministic cos kernel (f64 in/out). The SIMD variants run
/// this exact operation sequence lane-wise; every quadrant decision is
/// exact integer float arithmetic, so selection can never diverge.
fn cos_core(x: f64) -> f64 {
    let n = (x * TWO_OVER_PI + 0.5).floor();
    let r = x - n * PIO2_1 - n * PIO2_1T;
    let r2 = r * r;
    let mut c = COS_C8 + r2 * COS_C10;
    c = COS_C6 + r2 * c;
    c = COS_C4 + r2 * c;
    c = COS_C2 + r2 * c;
    c = 1.0 + r2 * c;
    let mut s = SIN_S7 + r2 * SIN_S9;
    s = SIN_S5 + r2 * s;
    s = SIN_S3 + r2 * s;
    s = 1.0 + r2 * s;
    s *= r;
    // quadrant k = n mod 4 via exact integer float arithmetic:
    // cos(r + k·π/2) = {cos r, −sin r, −cos r, sin r}[k]
    let m2 = n - 2.0 * (n * 0.5).floor();
    let m4 = n - 4.0 * (n * 0.25).floor();
    let v = if m2 == 1.0 { s } else { c };
    if m4 == 1.0 || m4 == 2.0 {
        -v
    } else {
        v
    }
}

/// `z[i] = scale · cos(z[i])` over f32, using the deterministic
/// [`cos_core`] kernel in every path (the cos evaluates in f64, rounds to
/// f32, then scales in f32 — bit-identical scalar vs SIMD).
pub fn scale_cos(scale: f32, z: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if z.len() >= 4 && active() == Isa::Avx2 {
        // SAFETY: Isa::Avx2 is only ever stored after runtime detection.
        unsafe { scale_cos_avx2(scale, z) };
        return;
    }
    scale_cos_scalar(scale, z);
}

fn scale_cos_scalar(scale: f32, z: &mut [f32]) {
    for v in z.iter_mut() {
        *v = scale * (cos_core(*v as f64) as f32);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_cos_avx2(scale: f32, z: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = z.len();
    let two_over_pi = _mm256_set1_pd(TWO_OVER_PI);
    let half = _mm256_set1_pd(0.5);
    let quarter = _mm256_set1_pd(0.25);
    let one = _mm256_set1_pd(1.0);
    let two = _mm256_set1_pd(2.0);
    let four = _mm256_set1_pd(4.0);
    let pio2_1 = _mm256_set1_pd(PIO2_1);
    let pio2_1t = _mm256_set1_pd(PIO2_1T);
    let sign = _mm256_set1_pd(-0.0);
    let scale4 = _mm_set1_ps(scale);
    let mut i = 0;
    while i + 4 <= n {
        let x = _mm256_cvtps_pd(_mm_loadu_ps(z.as_ptr().add(i)));
        let nv = _mm256_floor_pd(_mm256_add_pd(_mm256_mul_pd(x, two_over_pi), half));
        let r = _mm256_sub_pd(
            _mm256_sub_pd(x, _mm256_mul_pd(nv, pio2_1)),
            _mm256_mul_pd(nv, pio2_1t),
        );
        let r2 = _mm256_mul_pd(r, r);
        let c10 = _mm256_set1_pd(COS_C10);
        let mut c = _mm256_add_pd(_mm256_set1_pd(COS_C8), _mm256_mul_pd(r2, c10));
        c = _mm256_add_pd(_mm256_set1_pd(COS_C6), _mm256_mul_pd(r2, c));
        c = _mm256_add_pd(_mm256_set1_pd(COS_C4), _mm256_mul_pd(r2, c));
        c = _mm256_add_pd(_mm256_set1_pd(COS_C2), _mm256_mul_pd(r2, c));
        c = _mm256_add_pd(one, _mm256_mul_pd(r2, c));
        let s9 = _mm256_set1_pd(SIN_S9);
        let mut s = _mm256_add_pd(_mm256_set1_pd(SIN_S7), _mm256_mul_pd(r2, s9));
        s = _mm256_add_pd(_mm256_set1_pd(SIN_S5), _mm256_mul_pd(r2, s));
        s = _mm256_add_pd(_mm256_set1_pd(SIN_S3), _mm256_mul_pd(r2, s));
        s = _mm256_add_pd(one, _mm256_mul_pd(r2, s));
        s = _mm256_mul_pd(r, s);
        let m2 = _mm256_sub_pd(nv, _mm256_mul_pd(two, _mm256_floor_pd(_mm256_mul_pd(nv, half))));
        let m4f = _mm256_floor_pd(_mm256_mul_pd(nv, quarter));
        let m4 = _mm256_sub_pd(nv, _mm256_mul_pd(four, m4f));
        let use_sin = _mm256_cmp_pd::<_CMP_EQ_OQ>(m2, one);
        let v = _mm256_blendv_pd(c, s, use_sin);
        let neg = _mm256_or_pd(
            _mm256_cmp_pd::<_CMP_EQ_OQ>(m4, one),
            _mm256_cmp_pd::<_CMP_EQ_OQ>(m4, two),
        );
        let v = _mm256_xor_pd(v, _mm256_and_pd(neg, sign));
        let out = _mm_mul_ps(scale4, _mm256_cvtpd_ps(v));
        _mm_storeu_ps(z.as_mut_ptr().add(i), out);
        i += 4;
    }
    scale_cos_scalar(scale, &mut z[i..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 2.0) as f32).collect()
    }

    fn rand_f64(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    const LENS: [usize; 13] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 67];

    #[test]
    fn override_and_reset_round_trip() {
        // One test owns all dispatch-state assertions (global state; the
        // kernels themselves are bit-identical under every state, so other
        // tests racing a flipped state still see identical numbers).
        set_enabled(false);
        assert_eq!(active(), Isa::Scalar);
        set_enabled(true);
        assert_eq!(active(), detected());
        reset();
        let isa = active();
        assert!(matches!(isa, Isa::Scalar | Isa::Avx2 | Isa::Neon));
        assert!(!active_name().is_empty());
    }

    #[test]
    fn poly_cos_matches_libm_to_f32_precision() {
        let mut rng = Pcg64::new(7, 0);
        for k in 0..4000 {
            let x = match k % 4 {
                0 => rng.normal() * 3.0,
                1 => rng.uniform_in(-40.0, 40.0),
                2 => rng.uniform_in(-1000.0, 1000.0),
                _ => (k as f64 - 2000.0) * 0.01,
            };
            let got = cos_core(x);
            let want = x.cos();
            assert!((got - want).abs() < 5e-10, "cos_core({x}) = {got}, libm {want}");
        }
        // exact quadrant boundaries
        for x in [0.0f64, 0.5, -0.5, 1.0, -1.0, 2.0, 3.0, -3.0, 100.5] {
            assert!((cos_core(x) - x.cos()).abs() < 5e-10, "x={x}");
        }
    }

    #[test]
    fn scale_cos_matches_per_element_reference() {
        let mut rng = Pcg64::new(9, 0);
        for &n in &LENS {
            let z0 = rand_f32(&mut rng, n);
            let want: Vec<f32> =
                z0.iter().map(|&v| 0.17f32 * (cos_core(v as f64) as f32)).collect();
            let mut z = z0.clone();
            scale_cos(0.17, &mut z);
            assert_eq!(z, want, "n={n}");
            let mut zs = z0.clone();
            scale_cos_scalar(0.17, &mut zs);
            assert_eq!(zs, want, "scalar n={n}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_are_bit_identical_to_scalar() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let mut rng = Pcg64::new(42, 0);
        for &n in &LENS {
            let x = rand_f32(&mut rng, n);
            let y = rand_f32(&mut rng, n);
            let want = dot_f32_scalar(&x, &y);
            let got = unsafe { dot_f32_avx2(&x, &y) };
            assert_eq!(got.to_bits(), want.to_bits(), "dot_f32 n={n}");

            let beta = rand_f64(&mut rng, 64);
            let members: Vec<u32> = (0..n).map(|i| ((i * 37 + 11) % 64) as u32).collect();
            let want = weighted_gather_sum_scalar(&x, &members, &beta);
            let got = unsafe { weighted_gather_sum_avx2(&x, &members, &beta) };
            assert_eq!(got.to_bits(), want.to_bits(), "weighted_gather_sum n={n}");

            let loads = rand_f64(&mut rng, 32);
            let bucket_of: Vec<u32> = (0..n).map(|i| ((i * 13 + 5) % 32) as u32).collect();
            let mut want_out = rand_f64(&mut rng, n);
            let mut got_out = want_out.clone();
            scaled_gather_add_scalar(&mut want_out, &x, &bucket_of, &loads);
            unsafe { scaled_gather_add_avx2(&mut got_out, &x, &bucket_of, &loads) };
            assert_eq!(got_out, want_out, "scaled_gather_add n={n}");

            let mut want_y = y.clone();
            let mut got_y = y.clone();
            axpy_f32_scalar(0.37, &x, &mut want_y);
            unsafe { axpy_f32_avx2(0.37, &x, &mut got_y) };
            assert_eq!(got_y, want_y, "axpy_f32 n={n}");

            let mut want_t = rand_f64(&mut rng, n);
            let mut got_t = want_t.clone();
            axpy_f32_f64_scalar(-1.25, &x, &mut want_t);
            unsafe { axpy_f32_f64_avx2(-1.25, &x, &mut got_t) };
            assert_eq!(got_t, want_t, "axpy_f32_f64 n={n}");

            let z: Vec<f32> = (0..n).map(|_| rng.normal().abs() as f32 + 0.1).collect();
            let iw: Vec<f32> = z.iter().map(|&w| 1.0 / w).collect();
            let (mut wc, mut wr) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut gc, mut gr) = (vec![0.0f32; n], vec![0.0f32; n]);
            hash_cells_scalar(&x, &z, &iw, &mut wc, &mut wr);
            unsafe { hash_cells_avx2(&x, &z, &iw, &mut gc, &mut gr) };
            assert_eq!(gc, wc, "hash_cells c n={n}");
            assert_eq!(gr, wr, "hash_cells r n={n}");

            let mut want_z = x.clone();
            let mut got_z = x.clone();
            scale_cos_scalar(0.17, &mut want_z);
            unsafe { scale_cos_avx2(0.17, &mut got_z) };
            assert_eq!(got_z, want_z, "scale_cos n={n}");
        }
    }

    #[test]
    fn public_kernels_match_scalar_reference_under_any_dispatch() {
        // Whatever ISA is active, the public entry points must reproduce
        // the scalar reference bit for bit — the module's core contract.
        let mut rng = Pcg64::new(3, 0);
        for &n in &LENS {
            let x = rand_f32(&mut rng, n);
            let y = rand_f32(&mut rng, n);
            assert_eq!(dot_f32(&x, &y).to_bits(), dot_f32_scalar(&x, &y).to_bits(), "dot n={n}");
            let beta = rand_f64(&mut rng, 50);
            let members: Vec<u32> = (0..n).map(|i| ((i * 7 + 3) % 50) as u32).collect();
            assert_eq!(
                weighted_gather_sum(&x, &members, &beta).to_bits(),
                weighted_gather_sum_scalar(&x, &members, &beta).to_bits(),
                "gather-sum n={n}"
            );
        }
    }
}
