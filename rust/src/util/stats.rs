//! The one shared percentile definition: nearest-rank over a total-order
//! sort. Both the serving latency stats (`metrics.rs`) and the bench
//! harness (`util/timer.rs`) summarize through these helpers, so a p99
//! means the same thing in a histogram line and a BENCH_*.json artifact.

/// Sort samples into the total order (`f64::total_cmp`): NaNs sort to the
/// ends instead of aborting the run the way a `partial_cmp().unwrap()`
/// comparator does. A stray NaN sample therefore lands past the +inf end
/// of the positives and finite percentiles stay finite and meaningful.
pub fn sort_samples(xs: &mut [f64]) {
    xs.sort_by(f64::total_cmp);
}

/// Nearest-rank percentile on an already-sorted slice: the value at
/// 1-based rank `ceil(p · n)`, clamped into the slice. Unlike the
/// truncating `times[n * p]` rule this never over-reports at small `n`
/// (the p50 of `[a, b]` is `a`, not `b`) and agrees with the histogram
/// quantiles in `metrics.rs`.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample set");
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_exact_small_n() {
        // n=1: every percentile is the sample.
        assert_eq!(percentile(&[7.0], 0.50), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        // n=2: ceil(0.5·2)=1 → first element (the truncating rule said
        // index n/2 = 1 → second element, over-reporting the median).
        assert_eq!(percentile(&[1.0, 2.0], 0.50), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 0.99), 2.0);
        // n=4: p50 → rank 2; p75 → rank 3; p99 → rank 4.
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.50), 20.0);
        assert_eq!(percentile(&xs, 0.75), 30.0);
        assert_eq!(percentile(&xs, 0.99), 40.0);
        // n=100: p99 → rank 99 (index 98), not the max.
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
    }

    #[test]
    fn total_cmp_sort_survives_nan() {
        let mut xs = vec![3.0, f64::NAN, 1.0, 2.0];
        sort_samples(&mut xs);
        // +NaN sorts after every finite value; ranks below n stay finite.
        assert_eq!(&xs[..3], &[1.0, 2.0, 3.0]);
        assert!(xs[3].is_nan());
        assert_eq!(percentile(&xs, 0.50), 2.0);
        assert_eq!(percentile(&xs, 0.75), 3.0);
    }
}
