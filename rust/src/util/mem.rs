//! Process-memory introspection for training reports: a best-effort peak
//! resident-set probe. On Linux this reads `VmHWM` (the high-water mark of
//! the resident set) from `/proc/self/status`; elsewhere it returns `None`
//! and callers report 0. Streamed training uses it to demonstrate that
//! peak memory stays at O(chunk + sketch) rather than O(n·d).

/// Peak resident-set size of this process in bytes, if the platform
/// exposes it.
pub fn peak_rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: usize = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_plausible_when_available() {
        // On Linux the probe must report at least a few hundred KB (the
        // test binary itself); elsewhere None is the contract.
        if let Some(bytes) = peak_rss_bytes() {
            assert!(bytes > 100 * 1024, "suspicious peak RSS {bytes}");
        }
    }
}
