//! Dynamic micro-batcher: collects prediction requests until either the
//! batch-size or the linger-time bound is hit, then hands the whole batch
//! to the processing closure. Amortizes per-query hashing overhead on the
//! serving path (paper §4.2: a query costs O(m·d) after batch-hashing).

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// One queued request: a feature row and the channel to answer on.
pub struct BatchItem {
    pub features: Vec<f32>,
    pub reply: Sender<f64>,
}

/// Batching queue with a background dispatcher thread.
pub struct DynamicBatcher {
    tx: Sender<BatchItem>,
}

impl DynamicBatcher {
    /// Spawn the dispatcher. `process` receives the concatenated feature
    /// rows of a batch and writes one prediction per row into the output
    /// slice (the contract of
    /// [`Predictor::predict_into`](crate::sketch::Predictor::predict_into))
    /// — the dispatcher reuses its row/prediction buffers across batches,
    /// so steady-state serving allocates nothing per batch.
    pub fn spawn<F>(d: usize, max_batch: usize, linger: Duration, process: F) -> DynamicBatcher
    where
        F: Fn(&[f32], &mut [f64]) + Send + 'static,
    {
        let (tx, rx): (Sender<BatchItem>, Receiver<BatchItem>) = mpsc::channel();
        std::thread::Builder::new()
            .name("wlsh-batcher".into())
            .spawn(move || {
                let mut pending: Vec<BatchItem> = Vec::with_capacity(max_batch);
                let mut rows: Vec<f32> = Vec::with_capacity(max_batch * d);
                let mut preds: Vec<f64> = Vec::with_capacity(max_batch);
                loop {
                    // block for the first item
                    match rx.recv() {
                        Ok(item) => pending.push(item),
                        Err(_) => return, // all senders dropped
                    }
                    let deadline = Instant::now() + linger;
                    while pending.len() < max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(item) => pending.push(item),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // assemble and process into the reused buffers
                    rows.clear();
                    for it in &pending {
                        debug_assert_eq!(it.features.len(), d);
                        rows.extend_from_slice(&it.features);
                    }
                    preds.clear();
                    preds.resize(pending.len(), 0.0);
                    process(&rows, &mut preds);
                    for (it, p) in pending.drain(..).zip(&preds) {
                        let _ = it.reply.send(*p); // receiver may have gone away
                    }
                }
            })
            .expect("spawn batcher");
        DynamicBatcher { tx }
    }

    /// Enqueue one request; blocks until the batch containing it is served.
    pub fn predict(&self, features: Vec<f32>) -> Option<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(BatchItem { features, reply }).ok()?;
        rx.recv().ok()
    }

    /// Clone a submitter handle (for per-connection threads).
    pub fn handle(&self) -> Sender<BatchItem> {
        self.tx.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn answers_are_matched_to_requests() {
        // identity-ish processor: prediction = first feature * 2
        let b = DynamicBatcher::spawn(2, 8, Duration::from_millis(2), |rows, out| {
            for (r, o) in rows.chunks(2).zip(out) {
                *o = r[0] as f64 * 2.0;
            }
        });
        let y = b.predict(vec![3.0, 0.0]).unwrap();
        assert_eq!(y, 6.0);
        let y2 = b.predict(vec![-1.5, 9.0]).unwrap();
        assert_eq!(y2, -3.0);
    }

    #[test]
    fn batches_multiple_concurrent_requests() {
        let batches = Arc::new(AtomicUsize::new(0));
        let bclone = batches.clone();
        let b = Arc::new(DynamicBatcher::spawn(
            1,
            64,
            Duration::from_millis(30),
            move |rows, out| {
                bclone.fetch_add(1, Ordering::SeqCst);
                for (r, o) in rows.iter().zip(out) {
                    *o = *r as f64;
                }
            },
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let bb = b.clone();
            handles.push(std::thread::spawn(move || {
                bb.predict(vec![i as f32]).unwrap()
            }));
        }
        let mut results: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        results.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(results, (0..16).map(|i| i as f64).collect::<Vec<_>>());
        // all 16 should have been served in far fewer than 16 batches
        assert!(batches.load(Ordering::SeqCst) <= 8, "batches {}", batches.load(Ordering::SeqCst));
    }

    #[test]
    fn linger_bound_releases_partial_batches() {
        let b = DynamicBatcher::spawn(1, 1_000_000, Duration::from_millis(5), |rows, out| {
            for (r, o) in rows.iter().zip(out) {
                *o = *r as f64;
            }
        });
        let t = Instant::now();
        let y = b.predict(vec![7.0]).unwrap();
        assert_eq!(y, 7.0);
        assert!(t.elapsed() < Duration::from_secs(2));
    }
}
