//! Worker-pool micro-batcher: the serving engine's compute tier. A bounded
//! shared queue feeds `workers` batcher threads; each worker collects
//! requests until the batch-size or linger-time bound is hit, then runs
//! the whole batch through the model's allocation-free `predict_into`
//! contract (paper §4.2: a query costs O(m·d) after batch-hashing, and
//! binning features parallelize across cores — Wu et al., *Revisiting
//! Random Binning Features*).
//!
//! Admission control: the queue depth is a hard bound. A full queue
//! rejects the submit ([`SubmitError::Overloaded`]) instead of letting
//! latency grow without limit; the server tier turns that into an
//! `{"error":"overloaded"}` reply.
//!
//! Determinism: every prediction depends only on its own feature rows
//! (each row is hashed and looked up independently inside
//! `predict_into`), so results are bit-identical for every worker count,
//! queue depth, batch boundary, and arrival order —
//! `tests/serve_pool.rs` asserts this end-to-end through the TCP server.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::TrainedModel;
use crate::data::SparseChunk;

/// Batch-prediction surface the pool drives: one prediction per feature
/// row, written into `out` (the
/// [`Predictor::predict_into`](crate::sketch::Predictor::predict_into)
/// contract). Implemented by [`TrainedModel`]; tests substitute slow or
/// identity models to exercise overload and drain behavior.
pub trait BatchPredict: Send + Sync {
    fn predict_rows(&self, rows: &[f32], out: &mut [f64]);

    /// One prediction per CSR query row (`d` features per row). The
    /// default densifies the block and defers to
    /// [`predict_rows`](Self::predict_rows); [`TrainedModel`] routes to
    /// the operator's native sparse kernel.
    fn predict_sparse_rows(&self, d: usize, queries: SparseChunk<'_>, out: &mut [f64]) {
        let mut rows = vec![0.0f32; queries.nrows() * d];
        for i in 0..queries.nrows() {
            let (idx, vals) = queries.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                rows[i * d + j as usize] = v;
            }
        }
        self.predict_rows(&rows, out);
    }

    /// One (prediction, posterior variance) pair per feature row. The
    /// default declines (`None`): only models carrying a variance
    /// estimator — [`TrainedModel`] via
    /// [`predict_with_var`](TrainedModel::predict_with_var) — answer
    /// `"var":true` requests.
    fn predict_rows_with_var(&self, rows: &[f32], out: &mut [f64], var: &mut [f64]) -> Option<()> {
        let _ = (rows, out, var);
        None
    }
}

impl BatchPredict for TrainedModel {
    fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
        self.predict_into(rows, out)
    }

    fn predict_sparse_rows(&self, d: usize, queries: SparseChunk<'_>, out: &mut [f64]) {
        assert_eq!(d, self.dim(), "sparse query dimensionality mismatch");
        self.predict_sparse_into(&queries, out)
    }

    fn predict_rows_with_var(&self, rows: &[f32], out: &mut [f64], var: &mut [f64]) -> Option<()> {
        self.predict_with_var(rows, out, var)
    }
}

/// A queued request's feature rows, in whichever representation the
/// client sent them.
pub enum RowBlock {
    /// Row-major concatenated dense rows.
    Dense(Vec<f32>),
    /// An owned CSR block (`d` features per row; `indptr.len() == nrows+1`).
    Sparse { d: usize, indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f32> },
}

/// A served item's answer: one prediction per row, plus one posterior
/// variance per row when the item asked for them (`vars` stays `None`
/// for plain items, and for `"var":true` items whose model declines).
pub struct PoolReply {
    pub preds: Vec<f64>,
    pub vars: Option<Vec<f64>>,
}

/// One queued request: `nrows` feature rows bound for `model`, and the
/// channel to answer on (one prediction per row).
pub struct BatchItem {
    pub rows: RowBlock,
    pub nrows: usize,
    pub model: Arc<dyn BatchPredict>,
    /// Answer with posterior variances too (served unfused, like sparse).
    pub want_var: bool,
    pub reply: Sender<PoolReply>,
}

/// Why a submit did not enter the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue is at its configured depth — shed load instead of queueing.
    Overloaded,
    /// The pool has been shut down; no new work is accepted.
    ShuttingDown,
    /// The worker dropped the reply channel (worker thread panicked).
    WorkerGone,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "overloaded"),
            SubmitError::ShuttingDown => write!(f, "shutting down"),
            SubmitError::WorkerGone => write!(f, "batcher unavailable"),
        }
    }
}

struct Queue {
    items: VecDeque<BatchItem>,
    closed: bool,
}

/// Queue + knobs shared between the pool handle and its worker threads.
/// Workers hold only this (not the [`WorkerPool`] itself), so dropping the
/// last pool handle closes and joins them instead of leaking a reference
/// cycle.
struct Shared {
    q: Mutex<Queue>,
    available: Condvar,
    depth: usize,
    max_batch: usize,
    linger: Duration,
    workers: usize,
}

/// Bounded multi-producer queue + `workers` batcher threads with
/// per-worker reusable row/prediction buffers. Dropping the last handle
/// (or calling [`shutdown`](Self::shutdown)) closes the queue, drains it,
/// and joins the workers.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn `workers` batcher threads over a queue bounded at `depth`
    /// items. Each worker gathers up to `max_batch` items per cycle,
    /// waiting at most `linger` for stragglers after the first.
    pub fn spawn(
        workers: usize,
        depth: usize,
        max_batch: usize,
        linger: Duration,
    ) -> Arc<WorkerPool> {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            q: Mutex::new(Queue { items: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            depth: depth.max(1),
            max_batch: max_batch.max(1),
            linger,
            workers,
        });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let s = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("wlsh-worker-{w}"))
                    .spawn(move || s.run())
                    .expect("spawn pool worker"),
            );
        }
        Arc::new(WorkerPool { shared, handles: Mutex::new(handles) })
    }

    /// Number of batcher threads.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Most rows/items a worker fuses into one cycle (also the server's
    /// per-request batch cap).
    pub fn max_batch(&self) -> usize {
        self.shared.max_batch
    }

    /// Requests currently queued (not yet picked up by a worker).
    pub fn queue_len(&self) -> usize {
        self.shared.q.lock().unwrap().items.len()
    }

    /// Enqueue one request without blocking. A full queue or a closed pool
    /// rejects immediately — admission control happens here, not by
    /// letting the queue grow.
    pub fn submit(&self, item: BatchItem) -> Result<(), SubmitError> {
        {
            let mut q = self.shared.q.lock().unwrap();
            if q.closed {
                return Err(SubmitError::ShuttingDown);
            }
            if q.items.len() >= self.shared.depth {
                return Err(SubmitError::Overloaded);
            }
            q.items.push_back(item);
        }
        self.shared.available.notify_one();
        Ok(())
    }

    /// Submit `nrows` concatenated feature rows and block until the batch
    /// containing them is served. One prediction per row, in row order.
    pub fn predict(
        &self,
        model: Arc<dyn BatchPredict>,
        rows: Vec<f32>,
        nrows: usize,
    ) -> Result<Vec<f64>, SubmitError> {
        let (reply, rx) = mpsc::channel();
        self.submit(BatchItem {
            rows: RowBlock::Dense(rows),
            nrows,
            model,
            want_var: false,
            reply,
        })?;
        rx.recv().map(|r| r.preds).map_err(|_| SubmitError::WorkerGone)
    }

    /// Like [`predict`](Self::predict), but also asks for one posterior
    /// variance per row. The variance half is `None` when the model
    /// declines (no estimator attached — e.g. a raw [`BatchPredict`]
    /// stub, or an operator without a cross-kernel); the caller decides
    /// whether that is an error.
    pub fn predict_with_var(
        &self,
        model: Arc<dyn BatchPredict>,
        rows: Vec<f32>,
        nrows: usize,
    ) -> Result<(Vec<f64>, Option<Vec<f64>>), SubmitError> {
        let (reply, rx) = mpsc::channel();
        self.submit(BatchItem {
            rows: RowBlock::Dense(rows),
            nrows,
            model,
            want_var: true,
            reply,
        })?;
        rx.recv().map(|r| (r.preds, r.vars)).map_err(|_| SubmitError::WorkerGone)
    }

    /// Submit an owned CSR block of query rows and block until it is
    /// served. One prediction per row, in row order — bit-identical to
    /// [`predict`](Self::predict) on the densified rows.
    pub fn predict_sparse(
        &self,
        model: Arc<dyn BatchPredict>,
        d: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Vec<f64>, SubmitError> {
        let nrows = indptr.len().saturating_sub(1);
        let (reply, rx) = mpsc::channel();
        self.submit(BatchItem {
            rows: RowBlock::Sparse { d, indptr, indices, values },
            nrows,
            model,
            want_var: false,
            reply,
        })?;
        rx.recv().map(|r| r.preds).map_err(|_| SubmitError::WorkerGone)
    }

    /// Deterministic shutdown: stop admitting, wake every worker, and join
    /// them. Workers drain whatever is already queued before exiting, so
    /// every accepted request still gets its reply. Idempotent (and run by
    /// `Drop`, so an abandoned pool cannot leak its threads).
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.q.lock().unwrap();
            q.closed = true;
        }
        self.shared.available.notify_all();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Shared {
    fn run(&self) {
        let mut pending: Vec<BatchItem> = Vec::with_capacity(self.max_batch);
        // per-worker reusable buffers: steady-state serving allocates only
        // the per-request reply vectors
        let mut rows: Vec<f32> = Vec::new();
        let mut preds: Vec<f64> = Vec::new();
        while self.next_batch(&mut pending) {
            // a panicking model (bad BatchPredict impl, inconsistent
            // nrows) must not kill the worker: callers blocked on queued
            // items would hang forever with no one left to pop them.
            // Catch, drop the batch's reply senders (callers see
            // WorkerGone), and keep serving.
            let batch = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.process(&mut pending, &mut rows, &mut preds)
            }));
            if batch.is_err() {
                pending.clear();
            }
        }
    }

    /// Fill `pending` with the next batch. Returns `false` only when the
    /// pool is closed AND the queue is fully drained.
    fn next_batch(&self, pending: &mut Vec<BatchItem>) -> bool {
        let mut q = self.q.lock().unwrap();
        // block for the first item (drain-then-exit once closed)
        loop {
            if let Some(it) = q.items.pop_front() {
                pending.push(it);
                break;
            }
            if q.closed {
                return false;
            }
            q = self.available.wait(q).unwrap();
        }
        while pending.len() < self.max_batch {
            match q.items.pop_front() {
                Some(it) => pending.push(it),
                None => break,
            }
        }
        if pending.len() >= self.max_batch || self.linger.is_zero() {
            return true;
        }
        // linger for stragglers up to the deadline (or until closed)
        let deadline = Instant::now() + self.linger;
        loop {
            if q.closed {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            let (guard, _timeout) = self.available.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            while pending.len() < self.max_batch {
                match q.items.pop_front() {
                    Some(it) => pending.push(it),
                    None => break,
                }
            }
            if pending.len() >= self.max_batch {
                return true;
            }
        }
    }

    /// Run one gathered batch: consecutive items bound for the same model
    /// share a single `predict_rows` call over the concatenated rows
    /// (per-row results are independent, so fusing request boundaries is
    /// bit-transparent), then each item gets its slice of predictions.
    /// Fused calls are bounded by `max_batch` *rows* (not just items), so
    /// a run of batch requests can't push one `predict_rows` call past
    /// the predict kernel's serial threshold and nest its threading
    /// inside the worker's.
    fn process(&self, pending: &mut Vec<BatchItem>, rows: &mut Vec<f32>, preds: &mut Vec<f64>) {
        // Arc identity via the data pointer (distinct Arc allocations have
        // distinct addresses) — avoids comparing trait-object vtables,
        // which are not guaranteed unique.
        let model_id = |it: &BatchItem| Arc::as_ptr(&it.model) as *const ();
        let is_dense = |it: &BatchItem| matches!(it.rows, RowBlock::Dense(_));
        let mut i = 0;
        while i < pending.len() {
            // Variance items are served one per call: the per-row Lanczos
            // solve dominates, so fusing request boundaries buys nothing,
            // and the reply shape differs from the fused path's.
            if pending[i].want_var {
                let it = &pending[i];
                preds.clear();
                preds.resize(it.nrows, 0.0);
                let mut vars = vec![0.0f64; it.nrows];
                let supported = match &it.rows {
                    RowBlock::Dense(r) => {
                        it.model.predict_rows_with_var(r, preds, &mut vars).is_some()
                    }
                    // the wire has no sparse+var form; decline cleanly
                    RowBlock::Sparse { .. } => false,
                };
                let _ = it.reply.send(PoolReply {
                    preds: preds.clone(),
                    vars: if supported { Some(vars) } else { None },
                });
                i += 1;
                continue;
            }
            // Sparse items are served one per call — CSR blocks would need
            // an offset-shifting concatenation to fuse, and each row's
            // prediction is independent anyway, so fusing buys nothing
            // numerically. Dense fusion below is unchanged.
            if let RowBlock::Sparse { d, indptr, indices, values } = &pending[i].rows {
                preds.clear();
                preds.resize(pending[i].nrows, 0.0);
                let sp = SparseChunk { indptr, indices, values };
                pending[i].model.predict_sparse_rows(*d, sp, preds);
                let _ = pending[i].reply.send(PoolReply { preds: preds.clone(), vars: None });
                i += 1;
                continue;
            }
            let mut total = pending[i].nrows;
            let mut j = i + 1;
            while j < pending.len()
                && std::ptr::eq(model_id(&pending[j]), model_id(&pending[i]))
                && is_dense(&pending[j])
                && !pending[j].want_var
                && total + pending[j].nrows <= self.max_batch
            {
                total += pending[j].nrows;
                j += 1;
            }
            rows.clear();
            for it in &pending[i..j] {
                if let RowBlock::Dense(r) = &it.rows {
                    rows.extend_from_slice(r);
                }
            }
            preds.clear();
            preds.resize(total, 0.0);
            pending[i].model.predict_rows(rows, preds);
            let mut off = 0;
            for it in &pending[i..j] {
                // receiver may have gone away; losing that send is fine
                let _ = it.reply.send(PoolReply {
                    preds: preds[off..off + it.nrows].to_vec(),
                    vars: None,
                });
                off += it.nrows;
            }
            i = j;
        }
        pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// prediction = first feature of the row × 2 (arity `d`).
    struct Doubler {
        d: usize,
        batches: AtomicUsize,
    }

    impl BatchPredict for Doubler {
        fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
            self.batches.fetch_add(1, Ordering::SeqCst);
            for (r, o) in rows.chunks(self.d).zip(out) {
                *o = r[0] as f64 * 2.0;
            }
        }
    }

    /// sleeps per batch, then echoes the row's first feature.
    struct Sleeper {
        ms: u64,
    }

    impl BatchPredict for Sleeper {
        fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
            std::thread::sleep(Duration::from_millis(self.ms));
            for (r, o) in rows.iter().zip(out) {
                *o = *r as f64;
            }
        }
    }

    #[test]
    fn answers_are_matched_to_requests() {
        let model: Arc<dyn BatchPredict> =
            Arc::new(Doubler { d: 2, batches: AtomicUsize::new(0) });
        let pool = WorkerPool::spawn(2, 64, 8, Duration::from_millis(2));
        let y = pool.predict(model.clone(), vec![3.0, 0.0], 1).unwrap();
        assert_eq!(y, vec![6.0]);
        let y2 = pool.predict(model.clone(), vec![-1.5, 9.0, 4.0, 1.0], 2).unwrap();
        assert_eq!(y2, vec![-3.0, 8.0]);
        pool.shutdown();
        // post-shutdown submits are refused, not queued
        assert_eq!(
            pool.predict(model, vec![1.0, 0.0], 1),
            Err(SubmitError::ShuttingDown)
        );
    }

    #[test]
    fn batches_multiple_concurrent_requests() {
        let doubler = Arc::new(Doubler { d: 1, batches: AtomicUsize::new(0) });
        let model: Arc<dyn BatchPredict> = doubler.clone();
        let pool = WorkerPool::spawn(1, 1024, 64, Duration::from_millis(30));
        let mut handles = Vec::new();
        for i in 0..16 {
            let p = pool.clone();
            let m = model.clone();
            handles.push(std::thread::spawn(move || {
                p.predict(m, vec![i as f32], 1).unwrap()[0]
            }));
        }
        let mut results: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        results.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(results, (0..16).map(|i| i as f64 * 2.0).collect::<Vec<_>>());
        // far fewer batches than requests: the linger window coalesced them
        let batches = doubler.batches.load(Ordering::SeqCst);
        assert!(batches <= 8, "batches {batches}");
        pool.shutdown();
    }

    #[test]
    fn full_queue_rejects_with_overloaded() {
        let model: Arc<dyn BatchPredict> = Arc::new(Sleeper { ms: 300 });
        let pool = WorkerPool::spawn(1, 1, 1, Duration::ZERO);
        // occupy the single worker
        let p = pool.clone();
        let m = model.clone();
        let busy = std::thread::spawn(move || p.predict(m, vec![1.0], 1).unwrap());
        // give the worker time to pick the first item up
        std::thread::sleep(Duration::from_millis(100));
        // fill the queue (depth 1) ...
        let (reply, rx_queued) = mpsc::channel();
        pool.submit(BatchItem {
            rows: RowBlock::Dense(vec![2.0]),
            nrows: 1,
            model: model.clone(),
            want_var: false,
            reply,
        })
        .expect("first queued item fits");
        // ... and the next submit is shed, not queued
        let (reply2, _rx) = mpsc::channel();
        let err = pool
            .submit(BatchItem {
                rows: RowBlock::Dense(vec![3.0]),
                nrows: 1,
                model: model.clone(),
                want_var: false,
                reply: reply2,
            })
            .unwrap_err();
        assert_eq!(err, SubmitError::Overloaded);
        assert_eq!(busy.join().unwrap(), vec![1.0]);
        assert_eq!(rx_queued.recv().unwrap().preds, vec![2.0]);
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_items_before_exiting() {
        let model: Arc<dyn BatchPredict> = Arc::new(Sleeper { ms: 50 });
        let pool = WorkerPool::spawn(1, 64, 1, Duration::ZERO);
        let mut rxs = Vec::new();
        // first item occupies the worker; the rest sit in the queue
        for i in 0..5 {
            let (reply, rx) = mpsc::channel();
            pool.submit(BatchItem {
                rows: RowBlock::Dense(vec![i as f32]),
                nrows: 1,
                model: model.clone(),
                want_var: false,
                reply,
            })
            .unwrap();
            rxs.push(rx);
        }
        pool.shutdown(); // must drain all 5, then join
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().preds, vec![i as f64], "item {i} lost in shutdown");
        }
        // double shutdown is a no-op
        pool.shutdown();
    }

    #[test]
    fn mixed_model_batches_group_by_model() {
        let a: Arc<dyn BatchPredict> = Arc::new(Doubler { d: 1, batches: AtomicUsize::new(0) });
        let b: Arc<dyn BatchPredict> = Arc::new(Sleeper { ms: 0 });
        let pool = WorkerPool::spawn(2, 64, 16, Duration::from_millis(5));
        let mut handles = Vec::new();
        for i in 0..12 {
            let p = pool.clone();
            let m = if i % 2 == 0 { a.clone() } else { b.clone() };
            handles.push(std::thread::spawn(move || {
                (i, p.predict(m, vec![i as f32], 1).unwrap()[0])
            }));
        }
        for h in handles {
            let (i, y) = h.join().unwrap();
            let want = if i % 2 == 0 { i as f64 * 2.0 } else { i as f64 };
            assert_eq!(y, want, "request {i}");
        }
        pool.shutdown();
    }

    /// echoes rows, panicking when it sees the trigger value.
    struct PanicOn {
        trigger: f32,
    }

    impl BatchPredict for PanicOn {
        fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
            for (r, o) in rows.iter().zip(out) {
                assert!(*r != self.trigger, "boom");
                *o = *r as f64;
            }
        }
    }

    #[test]
    fn worker_survives_a_panicking_model() {
        let model: Arc<dyn BatchPredict> = Arc::new(PanicOn { trigger: 13.0 });
        // max_batch 1 isolates the poisoned request in its own batch
        let pool = WorkerPool::spawn(1, 64, 1, Duration::ZERO);
        assert_eq!(pool.predict(model.clone(), vec![1.0], 1), Ok(vec![1.0]));
        assert_eq!(pool.predict(model.clone(), vec![13.0], 1), Err(SubmitError::WorkerGone));
        // the worker caught the panic and keeps serving
        assert_eq!(pool.predict(model.clone(), vec![2.0], 1), Ok(vec![2.0]));
        pool.shutdown();
    }

    /// echoes rows, recording the largest fused call it ever saw.
    struct MaxRows {
        max: AtomicUsize,
    }

    impl BatchPredict for MaxRows {
        fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
            self.max.fetch_max(out.len(), Ordering::SeqCst);
            for (r, o) in rows.iter().zip(out) {
                *o = *r as f64;
            }
        }
    }

    #[test]
    fn fused_calls_respect_the_row_budget() {
        let mr = Arc::new(MaxRows { max: AtomicUsize::new(0) });
        let model: Arc<dyn BatchPredict> = mr.clone();
        // 3-row items against a 4-row budget: no two items may fuse
        let pool = WorkerPool::spawn(1, 1024, 4, Duration::from_millis(20));
        let mut handles = Vec::new();
        for i in 0..10 {
            let p = pool.clone();
            let m = model.clone();
            handles.push(std::thread::spawn(move || {
                p.predict(m, vec![i as f32, 0.0, 0.0], 3).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 3);
        }
        let seen = mr.max.load(Ordering::SeqCst);
        assert!(seen <= 4, "fused call of {seen} rows exceeded the 4-row budget");
        pool.shutdown();
    }

    #[test]
    fn sparse_items_flow_through_the_default_densify_path() {
        let model: Arc<dyn BatchPredict> =
            Arc::new(Doubler { d: 3, batches: AtomicUsize::new(0) });
        let pool = WorkerPool::spawn(1, 16, 8, Duration::ZERO);
        // two CSR rows over d=3: [4,0,1] and [0,2,0]
        let y = pool
            .predict_sparse(model, 3, vec![0, 2, 3], vec![0, 2, 1], vec![4.0, 1.0, 2.0])
            .unwrap();
        assert_eq!(y, vec![8.0, 0.0]);
        pool.shutdown();
    }

    /// echoes rows; variance = row value + 0.5.
    struct VarEcho;

    impl BatchPredict for VarEcho {
        fn predict_rows(&self, rows: &[f32], out: &mut [f64]) {
            for (r, o) in rows.iter().zip(out) {
                *o = *r as f64;
            }
        }

        fn predict_rows_with_var(
            &self,
            rows: &[f32],
            out: &mut [f64],
            var: &mut [f64],
        ) -> Option<()> {
            self.predict_rows(rows, out);
            for (r, v) in rows.iter().zip(var) {
                *v = *r as f64 + 0.5;
            }
            Some(())
        }
    }

    #[test]
    fn var_items_flow_through_unfused_and_plain_models_decline() {
        let with_var: Arc<dyn BatchPredict> = Arc::new(VarEcho);
        let plain: Arc<dyn BatchPredict> = Arc::new(Sleeper { ms: 0 });
        let pool = WorkerPool::spawn(2, 64, 8, Duration::from_millis(2));
        let (preds, vars) = pool.predict_with_var(with_var.clone(), vec![3.0, -1.0], 2).unwrap();
        assert_eq!(preds, vec![3.0, -1.0]);
        assert_eq!(vars, Some(vec![3.5, -0.5]));
        // a model without an estimator declines but still predicts
        let (preds, vars) = pool.predict_with_var(plain, vec![7.0], 1).unwrap();
        assert_eq!(vars, None);
        assert_eq!(preds.len(), 1);
        // the plain path through the same model stays untouched
        assert_eq!(pool.predict(with_var, vec![4.0], 1).unwrap(), vec![4.0]);
        pool.shutdown();
    }

    #[test]
    fn linger_bound_releases_partial_batches() {
        let model: Arc<dyn BatchPredict> = Arc::new(Sleeper { ms: 0 });
        let pool = WorkerPool::spawn(1, 64, 1_000_000, Duration::from_millis(5));
        let t = Instant::now();
        let y = pool.predict(model, vec![7.0], 1).unwrap();
        assert_eq!(y, vec![7.0]);
        assert!(t.elapsed() < Duration::from_secs(2));
        pool.shutdown();
    }
}
