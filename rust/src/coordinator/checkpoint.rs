//! Model checkpointing: persist a trained WLSH model (config + solved β +
//! the seeds that regenerate the sketch) and reload it into a servable
//! model without re-solving. The sketch itself is *not* serialized — it is
//! deterministic in (data, config, seed), which keeps checkpoints tiny
//! (O(n) for β) at the cost of an O(dn·m) rebuild on load, mirroring the
//! paper's O(dn) preprocessing claim.

use std::io::{Read, Write};
use std::path::Path;

use crate::config::KrrConfig;
use crate::coordinator::{TrainReport, TrainedModel, Trainer};
use crate::data::Dataset;
use crate::util::json::{Json, JsonWriter};

const MAGIC: &[u8; 8] = b"WLSHKRR1";

/// Write `model` to `path` (JSON header + little-endian f64 β block).
pub fn save(model: &TrainedModel, path: &Path) -> std::io::Result<()> {
    let c = &model.config;
    let header = JsonWriter::object()
        .field_str("method", &c.method)
        .field_usize("budget", c.budget)
        .field_str("bucket", &c.bucket)
        .field_f64("gamma_shape", c.gamma_shape)
        .field_f64("scale", c.scale)
        .field_f64("lambda", c.lambda)
        .field_usize("cg_max_iters", c.cg_max_iters)
        .field_f64("cg_tol", c.cg_tol)
        .field_str("precond", &c.precond)
        .field_usize("precond_rank", c.precond_rank)
        .field_usize("seed", c.seed as usize)
        .field_usize("n", model.beta.len())
        .finish();
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for b in &model.beta {
        f.write_all(&b.to_le_bytes())?;
    }
    Ok(())
}

/// Reload a checkpoint: rebuilds the operator from `train` (must be the
/// same dataset/standardization the model was trained on) and reattaches
/// the solved β.
pub fn load(path: &Path, train: &Dataset) -> Result<TrainedModel, String> {
    let mut f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).map_err(|e| e.to_string())?;
    if &magic != MAGIC {
        return Err("not a wlsh-krr checkpoint".into());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8).map_err(|e| e.to_string())?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf).map_err(|e| e.to_string())?;
    let header = Json::parse(std::str::from_utf8(&hbuf).map_err(|e| e.to_string())?)?;
    let g = |k: &str| header.get(k).and_then(Json::as_f64).ok_or(format!("missing {k}"));
    let config = KrrConfig {
        method: header.get("method").and_then(Json::as_str).ok_or("missing method")?.into(),
        budget: g("budget")? as usize,
        bucket: header.get("bucket").and_then(Json::as_str).ok_or("missing bucket")?.into(),
        gamma_shape: g("gamma_shape")?,
        scale: g("scale")?,
        lambda: g("lambda")?,
        cg_max_iters: g("cg_max_iters")? as usize,
        cg_tol: g("cg_tol")?,
        // absent in pre-PCG checkpoints — default off
        precond: header
            .get("precond")
            .and_then(Json::as_str)
            .unwrap_or("none")
            .into(),
        precond_rank: header
            .get("precond_rank")
            .and_then(Json::as_usize)
            .unwrap_or_else(|| KrrConfig::default().precond_rank),
        cg_verbose: false,
        workers: 1,
        seed: g("seed")? as u64,
    };
    let n = g("n")? as usize;
    if n != train.n {
        return Err(format!("checkpoint n={n} but dataset has n={}", train.n));
    }
    let mut beta = vec![0.0f64; n];
    let mut b8 = [0u8; 8];
    for bv in beta.iter_mut() {
        f.read_exact(&mut b8).map_err(|e| e.to_string())?;
        *bv = f64::from_le_bytes(b8);
    }
    let op = Trainer::new(config.clone()).build_operator(train);
    Ok(TrainedModel::assemble(
        op,
        beta,
        config,
        TrainReport {
            build_secs: 0.0,
            solve_secs: 0.0,
            cg_iters: 0,
            cg_rel_residual: 0.0,
            converged: true,
            operator: "restored".into(),
            precond: "restored".into(),
            memory_bytes: 0,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_by_name;

    #[test]
    fn save_load_roundtrip_predicts_identically() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(200, 2);
        let cfg = KrrConfig {
            method: "wlsh".into(),
            budget: 32,
            scale: 3.0,
            lambda: 0.5,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr);
        let want = model.predict(&te.x);
        let path = std::env::temp_dir().join("wlsh_ckpt_test.bin");
        save(&model, &path).unwrap();
        let restored = load(&path, &tr).unwrap();
        let got = restored.predict(&te.x);
        assert_eq!(want, got);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_wrong_dataset_size() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, _) = ds.split(200, 2);
        let cfg = KrrConfig { method: "wlsh".into(), budget: 8, ..Default::default() };
        let model = Trainer::new(cfg).train(&tr);
        let path = std::env::temp_dir().join("wlsh_ckpt_test2.bin");
        save(&model, &path).unwrap();
        let (smaller, _) = tr.split(100, 3);
        assert!(load(&path, &smaller).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("wlsh_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let mut ds = synthetic_by_name("wine", Some(50), 1).unwrap();
        ds.standardize();
        assert!(load(&path, &ds).is_err());
        std::fs::remove_file(&path).ok();
    }
}
