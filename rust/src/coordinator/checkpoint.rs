//! Model checkpointing: persist a trained WLSH model (config + solved β +
//! the seeds that regenerate the sketch) and reload it into a servable
//! model without re-solving. The sketch itself is *not* serialized — it is
//! deterministic in (data, config, seed), which keeps checkpoints tiny
//! (O(n) for β) at the cost of an O(dn·m) rebuild on load, mirroring the
//! paper's O(dn) preprocessing claim.
//!
//! The header's method/bucket/precond fields are the spec enums' `Display`
//! strings, parsed back through their `FromStr` impls — the same grammar
//! the CLI and TOML use. Headers written before the typed API (bare
//! `precond` + separate `precond_rank` key) still load.
//!
//! The serving tier is built on these files: `serve --model name=path`
//! loads named checkpoints into the
//! [`ModelRegistry`](crate::coordinator::ModelRegistry), and the
//! protocol's `reload` command hot-swaps one atomically — both through a
//! loader closure over the same training split the checkpoint was saved
//! against (`load` rejects a mismatched `n`).

use std::io::{Read, Write};
use std::path::Path;

use crate::api::{KrrError, MethodSpec, PrecondSpec, SamplingSpec, TopologySpec};
use crate::config::KrrConfig;
use crate::coordinator::{TrainReport, TrainedModel, Trainer};
use crate::data::{Dataset, MatrixSource};
use crate::sketch::{KrrOperator, WlshBuildParams, WlshSketch};
use crate::util::json::{Json, JsonWriter};
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"WLSHKRR1";

/// Write `model` to `path` (JSON header + little-endian f64 β block).
///
/// Importance-sampled models additionally persist their provenance —
/// `sampling` (the spec string) plus the exact kept `(pool index,
/// weight)` lists from [`KrrOperator::sampling_header`] — so a reload
/// reconstructs the *identical* weighted operator without re-scoring the
/// pool. Uniform models write `sampling` only, keeping their headers
/// otherwise byte-compatible with pre-sampling readers.
pub fn save(model: &TrainedModel, path: &Path) -> std::io::Result<()> {
    let c = &model.config;
    let mut w = JsonWriter::object()
        .field_str("method", &c.method.to_string())
        .field_usize("budget", c.budget)
        .field_str("bucket", &c.bucket.to_string())
        .field_f64("gamma_shape", c.gamma_shape)
        .field_f64("scale", c.scale)
        .field_f64("lambda", c.lambda)
        .field_usize("cg_max_iters", c.cg_max_iters)
        .field_f64("cg_tol", c.cg_tol)
        .field_str("precond", &c.precond.to_string())
        .field_str("topology", &c.topology.to_string())
        .field_usize("chunk_rows", c.chunk_rows)
        .field_usize("seed", c.seed as usize)
        .field_str("sampling", &c.sampling.to_string());
    if let Some(info) = model.op.sampling_header() {
        let idx: Vec<f64> = info.kept.iter().map(|&(i, _)| i as f64).collect();
        let wts: Vec<f64> = info.kept.iter().map(|&(_, iw)| iw).collect();
        w = w
            .field_usize("pool_m", info.pool_m)
            .field_arr_f64("keep_idx", &idx)
            .field_arr_f64("keep_w", &wts);
    }
    let header = w.field_usize("n", model.beta.len()).finish();
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for b in &model.beta {
        f.write_all(&b.to_le_bytes())?;
    }
    Ok(())
}

/// Reload a checkpoint: rebuilds the operator from `train` (must be the
/// same dataset/standardization the model was trained on) and reattaches
/// the solved β.
pub fn load(path: &Path, train: &Dataset) -> Result<TrainedModel, KrrError> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| KrrError::Io(format!("{}: {e}", path.display())))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(KrrError::Io("not a wlsh-krr checkpoint".into()));
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(
        std::str::from_utf8(&hbuf).map_err(|e| KrrError::Io(e.to_string()))?,
    )
    .map_err(KrrError::Io)?;
    let g = |k: &str| {
        header
            .get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| KrrError::Io(format!("checkpoint header missing {k}")))
    };
    let s = |k: &str| {
        header
            .get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| KrrError::Io(format!("checkpoint header missing {k}")))
    };
    // the string fields parse through the same spec grammar the CLI and
    // TOML use; legacy headers carry exactly these strings
    let raw_precond = header.get("precond").and_then(Json::as_str);
    let mut precond: PrecondSpec = match raw_precond {
        Some(p) => p.parse()?,
        None => PrecondSpec::None, // absent in pre-PCG checkpoints
    };
    // legacy headers stored the rank in a separate field next to a bare
    // "nystrom"; an explicit nystrom(rank=R) wins over the legacy key
    if raw_precond == Some("nystrom") {
        if let (PrecondSpec::Nystrom { rank }, Some(legacy)) =
            (&mut precond, header.get("precond_rank").and_then(Json::as_usize))
        {
            *rank = legacy;
        }
    }
    // absent in pre-distributed checkpoints — those are local by definition
    let topology: TopologySpec = match header.get("topology").and_then(Json::as_str) {
        Some(t) => t.parse()?,
        None => TopologySpec::Local,
    };
    // absent in pre-sampling checkpoints — those are uniform by
    // definition; a present-but-unknown grammar is a clean BadParam (a
    // checkpoint from a newer build must never panic an older loader)
    let sampling: SamplingSpec = match header.get("sampling") {
        Some(v) => v
            .as_str()
            .ok_or_else(|| KrrError::Io("checkpoint \"sampling\" must be a string".into()))?
            .parse()?,
        None => SamplingSpec::Uniform,
    };
    let config = KrrConfig {
        method: s("method")?.parse()?,
        budget: g("budget")? as usize,
        bucket: s("bucket")?.parse()?,
        gamma_shape: g("gamma_shape")?,
        scale: g("scale")?,
        lambda: g("lambda")?,
        cg_max_iters: g("cg_max_iters")? as usize,
        cg_tol: g("cg_tol")?,
        precond,
        cg_verbose: false,
        workers: 1,
        // absent in pre-streaming checkpoints; irrelevant to the rebuilt
        // operator's values (chunking is bit-transparent) either way
        chunk_rows: header
            .get("chunk_rows")
            .and_then(Json::as_usize)
            .unwrap_or(KrrConfig::default().chunk_rows),
        seed: g("seed")? as u64,
        topology,
        sampling,
    };
    // same range-check path as the builder/CLI/TOML — a corrupt header
    // (scale ≤ 0, negative λ) must not silently produce a NaN model
    config.validate()?;
    let n = g("n")? as usize;
    if n != train.n {
        return Err(KrrError::Io(format!(
            "checkpoint n={n} but dataset has n={}",
            train.n
        )));
    }
    let mut beta = vec![0.0f64; n];
    let mut b8 = [0u8; 8];
    for bv in beta.iter_mut() {
        f.read_exact(&mut b8)?;
        *bv = f64::from_le_bytes(b8);
    }
    let stored_keep = parse_keep_list(&header, &config)?;
    let op: Arc<dyn KrrOperator> = match &stored_keep {
        // Rebuild exactly the saved selection: the fork-replay
        // discipline makes each kept instance bit-identical to its pool
        // sibling, and the stored weights are applied verbatim — the
        // pool is *never* re-scored on load.
        Some((pool_m, keep)) if config.topology == TopologySpec::Local => {
            let params = WlshBuildParams::from_config(&config, train.n, train.d)
                .sampling(SamplingSpec::Uniform);
            let src = MatrixSource::new("checkpoint", &train.x, train.d.max(1));
            Arc::new(WlshSketch::build_selected(&params, &src, *pool_m, keep)?)
        }
        // Sharded topologies re-derive the selection coordinator-side;
        // leverage scoring is deterministic in (data, config, seed), so
        // the recomputed keep list equals the stored one bit-for-bit.
        _ => Trainer::new(config.clone()).build_operator(train)?,
    };
    Ok(TrainedModel::assemble(
        op,
        beta,
        config,
        TrainReport {
            build_secs: 0.0,
            solve_secs: 0.0,
            cg_iters: 0,
            cg_rel_residual: 0.0,
            converged: true,
            operator: "restored".into(),
            precond: "restored".into(),
            memory_bytes: 0,
            rows_per_sec: 0.0,
            peak_rss_bytes: 0,
        },
    ))
}

/// Extract the stored `(pool_m, kept pairs)` provenance from a header,
/// validating its internal consistency. Absent keys mean a uniform (or
/// pre-sampling) checkpoint; partially present or malformed keys are
/// corrupt headers and fail with a clean [`KrrError::Io`], never a
/// panic.
fn parse_keep_list(
    header: &Json,
    config: &KrrConfig,
) -> Result<Option<(usize, Vec<(usize, f64)>)>, KrrError> {
    let (idx_v, w_v) = match (header.get("keep_idx"), header.get("keep_w")) {
        (None, None) => return Ok(None),
        (Some(i), Some(w)) => (i, w),
        _ => {
            return Err(KrrError::Io(
                "checkpoint has one of \"keep_idx\"/\"keep_w\" without the other".into(),
            ))
        }
    };
    let bad = |k: &str| KrrError::Io(format!("checkpoint {k:?} must be an array of numbers"));
    let keep_idx: Vec<usize> = idx_v
        .as_arr()
        .ok_or_else(|| bad("keep_idx"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| bad("keep_idx")))
        .collect::<Result<_, _>>()?;
    let keep_w = w_v.as_f64_vec().ok_or_else(|| bad("keep_w"))?;
    if keep_idx.len() != keep_w.len() || keep_idx.is_empty() {
        return Err(KrrError::Io(format!(
            "checkpoint keep lists disagree: {} indices, {} weights",
            keep_idx.len(),
            keep_w.len()
        )));
    }
    let pool_m = header
        .get("pool_m")
        .and_then(Json::as_usize)
        .ok_or_else(|| KrrError::Io("checkpoint keep list without \"pool_m\"".into()))?;
    if config.sampling.is_uniform() {
        return Err(KrrError::Io(
            "checkpoint stores a keep list but declares uniform sampling".into(),
        ));
    }
    if config.method != MethodSpec::Wlsh {
        return Err(KrrError::Io(format!(
            "checkpoint stores a keep list but method is {}",
            config.method
        )));
    }
    Ok(Some((pool_m, keep_idx.into_iter().zip(keep_w).collect())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::MethodSpec;
    use crate::data::synthetic_by_name;

    #[test]
    fn save_load_roundtrip_predicts_identically() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(200, 2);
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            lambda: 0.5,
            precond: PrecondSpec::Nystrom { rank: 24 },
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let want = model.predict(&te.x);
        let path = std::env::temp_dir().join("wlsh_ckpt_test.bin");
        save(&model, &path).unwrap();
        let restored = load(&path, &tr).unwrap();
        assert_eq!(restored.config, model.config);
        let got = restored.predict(&te.x);
        assert_eq!(want, got);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_header_with_separate_precond_rank_still_loads() {
        // Reconstruct the pre-typed-API header format: bare "nystrom" with
        // the rank in its own field, and the old key order.
        let mut ds = synthetic_by_name("wine", Some(120), 3).unwrap();
        ds.standardize();
        let header = JsonWriter::object()
            .field_str("method", "wlsh")
            .field_usize("budget", 8)
            .field_str("bucket", "smooth2")
            .field_f64("gamma_shape", 7.0)
            .field_f64("scale", 3.0)
            .field_f64("lambda", 0.5)
            .field_usize("cg_max_iters", 50)
            .field_f64("cg_tol", 1e-4)
            .field_str("precond", "nystrom")
            .field_usize("precond_rank", 19)
            .field_usize("seed", 11)
            .field_usize("n", ds.n)
            .finish();
        let path = std::env::temp_dir().join("wlsh_ckpt_legacy.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for i in 0..ds.n {
            bytes.extend_from_slice(&(i as f64 * 0.01).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let model = load(&path, &ds).unwrap();
        assert_eq!(model.config.method, MethodSpec::Wlsh);
        assert_eq!(model.config.bucket, crate::api::BucketSpec::Smooth(2));
        assert_eq!(model.config.precond, PrecondSpec::Nystrom { rank: 19 });
        // no topology key either — legacy checkpoints are local
        assert_eq!(model.config.topology, TopologySpec::Local);
        assert_eq!(model.beta[100], 1.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn leverage_checkpoint_restores_the_exact_keep_list_and_predictions() {
        let mut ds = synthetic_by_name("wine", Some(220), 5).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(180, 2);
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 24,
            scale: 3.0,
            lambda: 0.5,
            sampling: SamplingSpec::Leverage { pilot: 8, keep: 12 },
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let want_info = model.op.sampling_header().expect("leverage model has a header").clone();
        assert_eq!(want_info.kept.len(), 12);
        let want = model.predict(&te.x);
        let path = std::env::temp_dir().join("wlsh_ckpt_leverage.bin");
        save(&model, &path).unwrap();
        let restored = load(&path, &tr).unwrap();
        // the stored (index, weight) pairs round-trip exactly — the pool
        // is rebuilt from the keep list, never re-scored
        assert_eq!(restored.op.sampling_header(), Some(&want_info));
        assert_eq!(restored.config, model.config);
        assert_eq!(restored.beta, model.beta);
        assert_eq!(restored.predict(&te.x), want);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_or_corrupt_sampling_headers_fail_cleanly() {
        let mut ds = synthetic_by_name("wine", Some(80), 9).unwrap();
        ds.standardize();
        // build a structurally valid checkpoint, then vary the sampling keys
        let write = |extra: &dyn Fn(JsonWriter) -> JsonWriter| {
            let w = JsonWriter::object()
                .field_str("method", "wlsh")
                .field_usize("budget", 8)
                .field_str("bucket", "smooth2")
                .field_f64("gamma_shape", 7.0)
                .field_f64("scale", 3.0)
                .field_f64("lambda", 0.5)
                .field_usize("cg_max_iters", 50)
                .field_f64("cg_tol", 1e-4)
                .field_str("precond", "none")
                .field_usize("seed", 11);
            let header = extra(w).field_usize("n", ds.n).finish();
            let path = std::env::temp_dir().join("wlsh_ckpt_badsampling.bin");
            let mut bytes = Vec::new();
            bytes.extend_from_slice(MAGIC);
            bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
            bytes.extend_from_slice(header.as_bytes());
            for i in 0..ds.n {
                bytes.extend_from_slice(&(i as f64 * 0.01).to_le_bytes());
            }
            std::fs::write(&path, &bytes).unwrap();
            path
        };
        // a sampling grammar this build does not know: Err, not panic
        let path = write(&|w| w.field_str("sampling", "magic(beans=3)"));
        assert!(load(&path, &ds).is_err());
        // keep_idx without keep_w: corrupt header
        let path = write(&|w| {
            w.field_str("sampling", "leverage(pilot=4,keep=2)")
                .field_usize("pool_m", 8)
                .field_arr_f64("keep_idx", &[1.0, 3.0])
        });
        assert!(load(&path, &ds).is_err());
        // a keep list under a uniform declaration: inconsistent header
        let path = write(&|w| {
            w.field_str("sampling", "uniform")
                .field_usize("pool_m", 8)
                .field_arr_f64("keep_idx", &[1.0, 3.0])
                .field_arr_f64("keep_w", &[1.0, 1.0])
        });
        assert!(load(&path, &ds).is_err());
        // out-of-pool keep index: rejected by build_selected, cleanly
        let path = write(&|w| {
            w.field_str("sampling", "leverage(pilot=4,keep=2)")
                .field_usize("pool_m", 8)
                .field_arr_f64("keep_idx", &[1.0, 9.0])
                .field_arr_f64("keep_w", &[1.0, 1.0])
        });
        assert!(load(&path, &ds).is_err());
        // absent sampling key still loads as uniform (legacy)
        let path = write(&|w| w);
        let model = load(&path, &ds).unwrap();
        assert!(model.config.sampling.is_uniform());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_wrong_dataset_size() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, _) = ds.split(200, 2);
        let cfg = KrrConfig { method: MethodSpec::Wlsh, budget: 8, ..Default::default() };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let path = std::env::temp_dir().join("wlsh_ckpt_test2.bin");
        save(&model, &path).unwrap();
        let (smaller, _) = tr.split(100, 3);
        assert!(load(&path, &smaller).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("wlsh_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let mut ds = synthetic_by_name("wine", Some(50), 1).unwrap();
        ds.standardize();
        assert!(load(&path, &ds).is_err());
        std::fs::remove_file(&path).ok();
    }
}
