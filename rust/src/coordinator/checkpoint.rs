//! Model checkpointing: persist a trained WLSH model (config + solved β +
//! the seeds that regenerate the sketch) and reload it into a servable
//! model without re-solving. The sketch itself is *not* serialized — it is
//! deterministic in (data, config, seed), which keeps checkpoints tiny
//! (O(n) for β) at the cost of an O(dn·m) rebuild on load, mirroring the
//! paper's O(dn) preprocessing claim.
//!
//! The header's method/bucket/precond fields are the spec enums' `Display`
//! strings, parsed back through their `FromStr` impls — the same grammar
//! the CLI and TOML use. Headers written before the typed API (bare
//! `precond` + separate `precond_rank` key) still load.
//!
//! The serving tier is built on these files: `serve --model name=path`
//! loads named checkpoints into the
//! [`ModelRegistry`](crate::coordinator::ModelRegistry), and the
//! protocol's `reload` command hot-swaps one atomically — both through a
//! loader closure over the same training split the checkpoint was saved
//! against (`load` rejects a mismatched `n`).

use std::io::{Read, Write};
use std::path::Path;

use crate::api::{KrrError, PrecondSpec, TopologySpec};
use crate::config::KrrConfig;
use crate::coordinator::{TrainReport, TrainedModel, Trainer};
use crate::data::Dataset;
use crate::util::json::{Json, JsonWriter};

const MAGIC: &[u8; 8] = b"WLSHKRR1";

/// Write `model` to `path` (JSON header + little-endian f64 β block).
pub fn save(model: &TrainedModel, path: &Path) -> std::io::Result<()> {
    let c = &model.config;
    let header = JsonWriter::object()
        .field_str("method", &c.method.to_string())
        .field_usize("budget", c.budget)
        .field_str("bucket", &c.bucket.to_string())
        .field_f64("gamma_shape", c.gamma_shape)
        .field_f64("scale", c.scale)
        .field_f64("lambda", c.lambda)
        .field_usize("cg_max_iters", c.cg_max_iters)
        .field_f64("cg_tol", c.cg_tol)
        .field_str("precond", &c.precond.to_string())
        .field_str("topology", &c.topology.to_string())
        .field_usize("chunk_rows", c.chunk_rows)
        .field_usize("seed", c.seed as usize)
        .field_usize("n", model.beta.len())
        .finish();
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for b in &model.beta {
        f.write_all(&b.to_le_bytes())?;
    }
    Ok(())
}

/// Reload a checkpoint: rebuilds the operator from `train` (must be the
/// same dataset/standardization the model was trained on) and reattaches
/// the solved β.
pub fn load(path: &Path, train: &Dataset) -> Result<TrainedModel, KrrError> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| KrrError::Io(format!("{}: {e}", path.display())))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(KrrError::Io("not a wlsh-krr checkpoint".into()));
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(
        std::str::from_utf8(&hbuf).map_err(|e| KrrError::Io(e.to_string()))?,
    )
    .map_err(KrrError::Io)?;
    let g = |k: &str| {
        header
            .get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| KrrError::Io(format!("checkpoint header missing {k}")))
    };
    let s = |k: &str| {
        header
            .get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| KrrError::Io(format!("checkpoint header missing {k}")))
    };
    // the string fields parse through the same spec grammar the CLI and
    // TOML use; legacy headers carry exactly these strings
    let raw_precond = header.get("precond").and_then(Json::as_str);
    let mut precond: PrecondSpec = match raw_precond {
        Some(p) => p.parse()?,
        None => PrecondSpec::None, // absent in pre-PCG checkpoints
    };
    // legacy headers stored the rank in a separate field next to a bare
    // "nystrom"; an explicit nystrom(rank=R) wins over the legacy key
    if raw_precond == Some("nystrom") {
        if let (PrecondSpec::Nystrom { rank }, Some(legacy)) =
            (&mut precond, header.get("precond_rank").and_then(Json::as_usize))
        {
            *rank = legacy;
        }
    }
    // absent in pre-distributed checkpoints — those are local by definition
    let topology: TopologySpec = match header.get("topology").and_then(Json::as_str) {
        Some(t) => t.parse()?,
        None => TopologySpec::Local,
    };
    let config = KrrConfig {
        method: s("method")?.parse()?,
        budget: g("budget")? as usize,
        bucket: s("bucket")?.parse()?,
        gamma_shape: g("gamma_shape")?,
        scale: g("scale")?,
        lambda: g("lambda")?,
        cg_max_iters: g("cg_max_iters")? as usize,
        cg_tol: g("cg_tol")?,
        precond,
        cg_verbose: false,
        workers: 1,
        // absent in pre-streaming checkpoints; irrelevant to the rebuilt
        // operator's values (chunking is bit-transparent) either way
        chunk_rows: header
            .get("chunk_rows")
            .and_then(Json::as_usize)
            .unwrap_or(KrrConfig::default().chunk_rows),
        seed: g("seed")? as u64,
        topology,
    };
    // same range-check path as the builder/CLI/TOML — a corrupt header
    // (scale ≤ 0, negative λ) must not silently produce a NaN model
    config.validate()?;
    let n = g("n")? as usize;
    if n != train.n {
        return Err(KrrError::Io(format!(
            "checkpoint n={n} but dataset has n={}",
            train.n
        )));
    }
    let mut beta = vec![0.0f64; n];
    let mut b8 = [0u8; 8];
    for bv in beta.iter_mut() {
        f.read_exact(&mut b8)?;
        *bv = f64::from_le_bytes(b8);
    }
    let op = Trainer::new(config.clone()).build_operator(train)?;
    Ok(TrainedModel::assemble(
        op,
        beta,
        config,
        TrainReport {
            build_secs: 0.0,
            solve_secs: 0.0,
            cg_iters: 0,
            cg_rel_residual: 0.0,
            converged: true,
            operator: "restored".into(),
            precond: "restored".into(),
            memory_bytes: 0,
            rows_per_sec: 0.0,
            peak_rss_bytes: 0,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::MethodSpec;
    use crate::data::synthetic_by_name;

    #[test]
    fn save_load_roundtrip_predicts_identically() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(200, 2);
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            lambda: 0.5,
            precond: PrecondSpec::Nystrom { rank: 24 },
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let want = model.predict(&te.x);
        let path = std::env::temp_dir().join("wlsh_ckpt_test.bin");
        save(&model, &path).unwrap();
        let restored = load(&path, &tr).unwrap();
        assert_eq!(restored.config, model.config);
        let got = restored.predict(&te.x);
        assert_eq!(want, got);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_header_with_separate_precond_rank_still_loads() {
        // Reconstruct the pre-typed-API header format: bare "nystrom" with
        // the rank in its own field, and the old key order.
        let mut ds = synthetic_by_name("wine", Some(120), 3).unwrap();
        ds.standardize();
        let header = JsonWriter::object()
            .field_str("method", "wlsh")
            .field_usize("budget", 8)
            .field_str("bucket", "smooth2")
            .field_f64("gamma_shape", 7.0)
            .field_f64("scale", 3.0)
            .field_f64("lambda", 0.5)
            .field_usize("cg_max_iters", 50)
            .field_f64("cg_tol", 1e-4)
            .field_str("precond", "nystrom")
            .field_usize("precond_rank", 19)
            .field_usize("seed", 11)
            .field_usize("n", ds.n)
            .finish();
        let path = std::env::temp_dir().join("wlsh_ckpt_legacy.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for i in 0..ds.n {
            bytes.extend_from_slice(&(i as f64 * 0.01).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let model = load(&path, &ds).unwrap();
        assert_eq!(model.config.method, MethodSpec::Wlsh);
        assert_eq!(model.config.bucket, crate::api::BucketSpec::Smooth(2));
        assert_eq!(model.config.precond, PrecondSpec::Nystrom { rank: 19 });
        // no topology key either — legacy checkpoints are local
        assert_eq!(model.config.topology, TopologySpec::Local);
        assert_eq!(model.beta[100], 1.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_wrong_dataset_size() {
        let mut ds = synthetic_by_name("wine", Some(250), 1).unwrap();
        ds.standardize();
        let (tr, _) = ds.split(200, 2);
        let cfg = KrrConfig { method: MethodSpec::Wlsh, budget: 8, ..Default::default() };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let path = std::env::temp_dir().join("wlsh_ckpt_test2.bin");
        save(&model, &path).unwrap();
        let (smaller, _) = tr.split(100, 3);
        assert!(load(&path, &smaller).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("wlsh_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let mut ds = synthetic_by_name("wine", Some(50), 1).unwrap();
        ds.standardize();
        assert!(load(&path, &ds).is_err());
        std::fs::remove_file(&path).ok();
    }
}
