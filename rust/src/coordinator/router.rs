//! Prediction router: fans one large *offline* batch of queries out over
//! worker threads, each holding a shared reference to the trained model,
//! and collects the results in order. This is the bulk-scoring
//! counterpart to the online [`WorkerPool`](super::WorkerPool) engine
//! (which batches many small concurrent requests); both bound their own
//! threading so parallelism never nests.

use std::sync::Arc;

use super::TrainedModel;
use crate::sketch::SERIAL_QUERY_CHUNK;
use crate::util::par;

/// Shards batch predictions across `workers` threads.
pub struct PredictRouter {
    model: Arc<TrainedModel>,
    workers: usize,
    d: usize,
}

impl PredictRouter {
    /// The feature arity comes from the model's predictor handle.
    pub fn new(model: Arc<TrainedModel>, workers: usize) -> PredictRouter {
        let d = model.dim();
        PredictRouter { model, workers: workers.max(1), d }
    }

    /// Predict for row-major queries, preserving order.
    pub fn predict(&self, queries: &[f32]) -> Vec<f64> {
        let nq = queries.len() / self.d;
        // Small batches stay below the predict kernel's serial threshold,
        // so handing them over whole cannot spawn inner threads.
        if nq < 2 * self.workers && nq <= SERIAL_QUERY_CHUNK {
            return self.model.predict(queries);
        }
        // Shard at (or below) the predict kernel's serial chunk size: each
        // inner `model.predict` then stays single-threaded, so the router's
        // `workers` is a hard bound on prediction threading (workers = 1 ⇒
        // fully serial) and parallelism never nests.
        let chunk_rows = nq.div_ceil(self.workers).min(SERIAL_QUERY_CHUNK);
        let chunks: Vec<&[f32]> = queries.chunks(chunk_rows * self.d).collect();
        let model = &self.model;
        let pieces = par::fan_out(chunks.len(), self.workers, |c| model.predict(chunks[c]));
        let mut out = Vec::with_capacity(nq);
        for p in pieces {
            out.extend(p);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KrrConfig;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;

    #[test]
    fn router_matches_direct_prediction() {
        let mut ds = synthetic_by_name("wine", Some(200), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(160, 2);
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            ..Default::default()
        };
        let model = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
        let direct = model.predict(&te.x);
        for workers in [1, 2, 4] {
            let router = PredictRouter::new(model.clone(), workers);
            let routed = router.predict(&te.x);
            assert_eq!(routed.len(), direct.len());
            for i in 0..direct.len() {
                assert!((routed[i] - direct[i]).abs() < 1e-12, "w={workers} i={i}");
            }
        }
    }

    #[test]
    fn handles_tiny_batches() {
        let mut ds = synthetic_by_name("wine", Some(100), 3).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(90, 4);
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 8,
            scale: 3.0,
            ..Default::default()
        };
        let model = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
        let router = PredictRouter::new(model, 8);
        let one = router.predict(&te.x[..te.d]);
        assert_eq!(one.len(), 1);
    }
}
