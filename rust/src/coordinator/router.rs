//! Prediction router: fans a batch of queries out over worker threads,
//! each holding a shared reference to the trained model, and collects the
//! results in order. Structural on a 1-core box, but the sharding keeps
//! the serving path scalable and is exercised by the tests/benches.

use std::sync::Arc;

use super::TrainedModel;

/// Shards batch predictions across `workers` threads.
pub struct PredictRouter {
    model: Arc<TrainedModel>,
    workers: usize,
    d: usize,
}

impl PredictRouter {
    pub fn new(model: Arc<TrainedModel>, workers: usize, d: usize) -> PredictRouter {
        PredictRouter { model, workers: workers.max(1), d }
    }

    /// Predict for row-major queries, preserving order.
    pub fn predict(&self, queries: &[f32]) -> Vec<f64> {
        let nq = queries.len() / self.d;
        if self.workers == 1 || nq < 2 * self.workers {
            return self.model.predict(queries);
        }
        let chunk_rows = nq.div_ceil(self.workers);
        let mut out = vec![0.0f64; nq];
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (w, rows) in queries.chunks(chunk_rows * self.d).enumerate() {
                let model = &self.model;
                handles.push((w, scope.spawn(move || model.predict(rows))));
            }
            for (w, h) in handles {
                let preds = h.join().expect("router worker panicked");
                let start = w * chunk_rows;
                out[start..start + preds.len()].copy_from_slice(&preds);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KrrConfig;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;

    #[test]
    fn router_matches_direct_prediction() {
        let mut ds = synthetic_by_name("wine", Some(200), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(160, 2);
        let cfg = KrrConfig { method: "wlsh".into(), budget: 32, scale: 3.0, ..Default::default() };
        let model = Arc::new(Trainer::new(cfg).train(&tr));
        let direct = model.predict(&te.x);
        for workers in [1, 2, 4] {
            let router = PredictRouter::new(model.clone(), workers, te.d);
            let routed = router.predict(&te.x);
            assert_eq!(routed.len(), direct.len());
            for i in 0..direct.len() {
                assert!((routed[i] - direct[i]).abs() < 1e-12, "w={workers} i={i}");
            }
        }
    }

    #[test]
    fn handles_tiny_batches() {
        let mut ds = synthetic_by_name("wine", Some(100), 3).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(90, 4);
        let cfg = KrrConfig { method: "wlsh".into(), budget: 8, scale: 3.0, ..Default::default() };
        let model = Arc::new(Trainer::new(cfg).train(&tr));
        let router = PredictRouter::new(model, 8, te.d);
        let one = router.predict(&te.x[..te.d]);
        assert_eq!(one.len(), 1);
    }
}
