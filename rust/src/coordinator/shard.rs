//! Sharded distributed CG solve and serving: partition the m WLSH
//! instances across N worker processes, keep the CG loop (and all vector
//! arithmetic) on the coordinator, and fan the fused mat-vec / predict
//! kernels out over the shards through the typed wire protocol
//! ([`proto`](crate::coordinator::proto)).
//!
//! Bit-identity discipline (the same contract `util/par.rs` enforces for
//! threads, extended across processes): instance ranges cut on
//! `FUSE_BLOCK` boundaries, every shard returns *raw* per-block partial
//! vectors, and the coordinator accumulates them in global block order
//! before applying `1/m_total` once — exactly the reduction
//! `WlshSketch::matvec_threads` performs in one process. Prediction ships
//! raw per-instance terms with explicit bucket-miss markers, accumulated
//! left-to-right in global instance order. Numbers cross the wire as
//! shortest-round-trip decimals, which are bit-exact for finite f64/f32.
//! Consequence: the N-shard solve's β and predictions equal the
//! single-process results *exactly*, for every shard count
//! (`tests/shard_equivalence.rs`).
//!
//! Failure semantics: shard connections retry with backoff while a worker
//! is coming up; once the solve is running, any I/O error, protocol
//! error, or worker death surfaces as [`KrrError::Shard`] naming the
//! shard address. `KrrOperator::matvec` is infallible by design, so
//! [`ShardedOperator`] latches the first failure, short-circuits every
//! subsequent mat-vec (CG then terminates within its iteration cap in
//! microseconds), and the trainer converts the latch into a hard error —
//! no partial result is ever returned.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::api::{BucketSpec, KrrError, TopologySpec};
use crate::config::KrrConfig;
use crate::coordinator::proto::{Request, Response, ShardBuild, ShardReady};
use crate::data::MatrixSource;
use crate::sketch::{KrrOperator, Predictor, SamplingInfo, WlshBuildParams, WlshSketch};
use std::sync::Arc;

/// How long a shard connection keeps retrying before giving up (workers
/// announce their address only after binding, so refusals here mean a
/// worker is mid-spawn, not absent). Override in milliseconds with
/// `WLSH_SHARD_CONNECT_MS` (tests shrink it to fail fast).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);
/// First retry delay; doubles per attempt up to [`CONNECT_BACKOFF_MAX`].
const CONNECT_BACKOFF_START: Duration = Duration::from_millis(25);
const CONNECT_BACKOFF_MAX: Duration = Duration::from_millis(400);
/// Per-reply read budget. A dead worker fails in microseconds (reset /
/// EOF); this bound only catches a live-but-wedged worker, so it is
/// sized for the slowest legitimate reply (a full sketch build).
const READ_TIMEOUT: Duration = Duration::from_secs(120);
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

fn connect_timeout() -> Duration {
    match std::env::var("WLSH_SHARD_CONNECT_MS").ok().and_then(|v| v.parse::<u64>().ok()) {
        Some(ms) => Duration::from_millis(ms),
        None => CONNECT_TIMEOUT,
    }
}

/// Partition of `m_total` WLSH instances over `n_shards` workers, cut on
/// `FUSE_BLOCK` boundaries so the distributed mat-vec reduction replays
/// the single-process block order.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardPlan {
    pub m_total: usize,
    /// Per-shard instance ranges `[lo, hi)`, contiguous and in order.
    pub ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Split `m_total` instances over `n_shards` at block granularity
    /// (shard s gets blocks `[⌊s·nb/N⌋, ⌊(s+1)·nb/N⌋)`; trailing shards
    /// may own zero instances when there are fewer blocks than shards).
    pub fn new(m_total: usize, n_shards: usize) -> ShardPlan {
        assert!(n_shards >= 1, "need at least one shard");
        let fb = WlshSketch::FUSE_BLOCK;
        let nblocks = m_total.div_ceil(fb);
        let ranges = (0..n_shards)
            .map(|s| {
                let blo = s * nblocks / n_shards;
                let bhi = (s + 1) * nblocks / n_shards;
                ((blo * fb).min(m_total), (bhi * fb).min(m_total))
            })
            .collect();
        ShardPlan { m_total, ranges }
    }
}

/// One shard connection: lazy, auto-reconnecting while the worker comes
/// up, line-oriented request/reply. All replies funnel through
/// [`call`](Self::call), which converts every transport or protocol
/// failure into [`KrrError::Shard`] naming the address.
pub struct ShardClient {
    addr: String,
    conn: Mutex<Option<(TcpStream, BufReader<TcpStream>)>>,
}

impl ShardClient {
    pub fn new(addr: &str) -> ShardClient {
        ShardClient { addr: addr.to_string(), conn: Mutex::new(None) }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn shard_err(&self, what: impl std::fmt::Display) -> KrrError {
        KrrError::Shard(format!("{}: {what}", self.addr))
    }

    /// Connect with retry/backoff (covers the worker's bind-to-announce
    /// window and slow process spawns).
    fn connect(&self) -> Result<(TcpStream, BufReader<TcpStream>), KrrError> {
        let deadline = Instant::now() + connect_timeout();
        let mut backoff = CONNECT_BACKOFF_START;
        loop {
            match TcpStream::connect(&self.addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
                    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
                    let reader = BufReader::new(
                        stream.try_clone().map_err(|e| self.shard_err(e))?,
                    );
                    return Ok((stream, reader));
                }
                Err(e) => {
                    if Instant::now() + backoff > deadline {
                        return Err(self.shard_err(format!(
                            "connect failed after retrying for {:?}: {e}",
                            connect_timeout()
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(CONNECT_BACKOFF_MAX);
                }
            }
        }
    }

    /// One request → one reply. Transport failures drop the cached
    /// connection (the next call re-dials, with the same retry budget);
    /// a worker-side [`Response::Error`] also surfaces as
    /// [`KrrError::Shard`] — shard workers are internal, so their errors
    /// are failures, not user input problems.
    pub fn call(&self, req: &Request) -> Result<Response, KrrError> {
        let mut guard = self.conn.lock().expect("shard client lock poisoned");
        if guard.is_none() {
            *guard = Some(self.connect()?);
        }
        let (stream, reader) = guard.as_mut().expect("just connected");
        let line = req.to_line();
        let io = (|| -> std::io::Result<String> {
            stream.write_all(line.as_bytes())?;
            stream.write_all(b"\n")?;
            let mut reply = String::new();
            let nread = reader.read_line(&mut reply)?;
            if nread == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "worker closed the connection",
                ));
            }
            Ok(reply)
        })();
        let reply = match io {
            Ok(r) => r,
            Err(e) => {
                *guard = None; // poisoned stream; re-dial on next call
                return Err(self.shard_err(e));
            }
        };
        match Response::parse(reply.trim_end()) {
            Ok(Response::Error(msg)) => Err(self.shard_err(msg)),
            Ok(resp) => Ok(resp),
            Err(e) => Err(self.shard_err(format!("bad reply: {e}"))),
        }
    }

    /// Best-effort shutdown request (used when tearing down local
    /// workers; errors are ignored — the process is about to be reaped).
    fn send_shutdown(&self) {
        let _ = self.call(&Request::Shutdown);
    }
}

/// A set of shard workers executing one [`ShardPlan`]: the clients, and —
/// for locally spawned topologies — the child processes themselves.
/// Dropping the group shuts local workers down (remote workers are not
/// ours to stop). The solved model's operator holds the group in an
/// `Arc`, so shards live exactly as long as something can still route
/// queries to them.
pub struct ShardGroup {
    pub plan: ShardPlan,
    clients: Vec<ShardClient>,
    children: Mutex<Vec<Child>>,
}

impl ShardGroup {
    /// Spawn `n_shards` local `shard-worker` processes (ephemeral ports,
    /// addresses scraped from their stdout announcements) and connect.
    pub fn spawn_local(n_shards: usize, m_total: usize) -> Result<ShardGroup, KrrError> {
        let bin = worker_binary()?;
        let mut children = Vec::with_capacity(n_shards);
        let mut clients = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let mut child = Command::new(&bin)
                .args(["shard-worker", "--addr", "127.0.0.1:0"])
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| {
                    KrrError::Shard(format!("spawn {} (shard {s}): {e}", bin.display()))
                })?;
            let stdout = child.stdout.take().expect("stdout was piped");
            let mut reader = BufReader::new(stdout);
            let mut line = String::new();
            let addr = loop {
                line.clear();
                let nread = reader.read_line(&mut line).map_err(|e| {
                    KrrError::Shard(format!("shard {s} stdout: {e}"))
                })?;
                if nread == 0 {
                    // reap the corpse for a useful exit status
                    let status = child.wait().map(|s| s.to_string()).unwrap_or_default();
                    return Err(KrrError::Shard(format!(
                        "shard {s} exited before announcing its address ({status})"
                    )));
                }
                if let Some(rest) = line.trim_end().strip_prefix("shard listening on ") {
                    break rest.to_string();
                }
            };
            children.push(child);
            clients.push(ShardClient::new(&addr));
        }
        Ok(ShardGroup {
            plan: ShardPlan::new(m_total, n_shards),
            clients,
            children: Mutex::new(children),
        })
    }

    /// Connect to already-running workers at `addrs` (the
    /// `remote(addr=...)` topology; one shard per address, in spec
    /// order — the order is part of the reduction contract).
    pub fn connect_remote(addrs: &[String], m_total: usize) -> Result<ShardGroup, KrrError> {
        assert!(!addrs.is_empty(), "need at least one shard address");
        Ok(ShardGroup {
            plan: ShardPlan::new(m_total, addrs.len()),
            clients: addrs.iter().map(|a| ShardClient::new(a)).collect(),
            children: Mutex::new(Vec::new()),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.clients.len()
    }

    /// Run `f(shard_index, client)` for every shard concurrently and
    /// return the results in shard order (the caller performs all
    /// order-sensitive reductions; this only parallelizes the waiting).
    /// The first failure (lowest shard index) wins.
    fn for_each_shard<T: Send>(
        &self,
        f: impl Fn(usize, &ShardClient) -> Result<T, KrrError> + Sync,
    ) -> Result<Vec<T>, KrrError> {
        let f = &f;
        let results: Vec<Result<T, KrrError>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .clients
                .iter()
                .enumerate()
                .map(|(s, client)| scope.spawn(move || f(s, client)))
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(KrrError::Shard("shard call panicked".to_string())),
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Distribute the training matrix: every shard builds its instance
    /// range of the sketch (in parallel — builds are the expensive part).
    /// With a non-uniform `selection` (computed coordinator-side, since
    /// leverage scoring needs the whole pool), shard `s` receives its
    /// `[lo, hi)` slice of the *kept* sequence — the plan cuts that
    /// sequence on `FUSE_BLOCK` boundaries, so global block order (and
    /// hence bit-identity with the single-process weighted sketch) is
    /// preserved.
    fn build(
        &self,
        cfg: &KrrConfig,
        x: &[f32],
        n: usize,
        d: usize,
        selection: Option<&SamplingInfo>,
    ) -> Result<(), KrrError> {
        self.for_each_shard(|s, client| {
            let (lo, hi) = self.plan.ranges[s];
            let (pool_m, keep_idx, keep_w) = match selection {
                // an empty slice (shard owns zero instances) degrades to
                // the uniform encoding — the wire invariant is
                // `keep_idx empty ⇔ pool_m == 0`
                Some(info) if lo < hi => {
                    let slice = &info.kept[lo..hi];
                    (
                        info.pool_m,
                        slice.iter().map(|&(i, _)| i).collect(),
                        slice.iter().map(|&(_, w)| w).collect(),
                    )
                }
                _ => (0, Vec::new(), Vec::new()),
            };
            let req = Request::ShardBuild(ShardBuild {
                n,
                d,
                x: x.to_vec(),
                m_total: self.plan.m_total,
                lo,
                hi,
                bucket: cfg.bucket.to_string(),
                gamma_shape: cfg.gamma_shape,
                scale: cfg.scale,
                seed: cfg.seed,
                chunk_rows: cfg.chunk_rows,
                workers: cfg.workers,
                pool_m,
                keep_idx,
                keep_w,
            });
            match client.call(&req)? {
                Response::ShardReady(ShardReady { m_local, .. }) if m_local == hi - lo => Ok(()),
                Response::ShardReady(sh) => Err(KrrError::Shard(format!(
                    "{}: built {} instances, expected {}",
                    client.addr(),
                    sh.m_local,
                    hi - lo
                ))),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected build reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        Ok(())
    }

    /// Distributed fused mat-vec: gather every shard's raw block
    /// partials, reduce in global block order (shard order × in-shard
    /// block order), normalize once. Bit-identical to
    /// `WlshSketch::matvec_threads` on the full sketch.
    fn matvec(&self, beta: &[f64], n: usize) -> Result<Vec<f64>, KrrError> {
        let per_shard = self.for_each_shard(|_, client| {
            match client.call(&Request::ShardMatvec { beta: beta.to_vec() })? {
                Response::MatvecPartials(partials) => Ok(partials),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected matvec reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        let mut out = vec![0.0f64; n];
        for (s, partials) in per_shard.iter().enumerate() {
            for p in partials {
                if p.len() != n {
                    return Err(KrrError::Shard(format!(
                        "{}: partial has {} rows, expected {n}",
                        self.clients[s].addr(),
                        p.len()
                    )));
                }
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
        }
        let inv_m = 1.0 / self.plan.m_total as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        Ok(out)
    }

    /// Hash `x_new` (row-major) into every shard's instance range,
    /// resuming the incremental build. Every shard sees the same rows (a
    /// shard owns a slice of the m *instances*, each hashed over all n
    /// rows), and each must agree on the resulting row count.
    fn append(&self, x_new: &[f32], expect_n: usize) -> Result<(), KrrError> {
        self.for_each_shard(|_, client| {
            match client.call(&Request::ShardAppend { x: x_new.to_vec() })? {
                Response::ShardReady(ShardReady { n, .. }) if n == expect_n => Ok(()),
                Response::ShardReady(sh) => Err(KrrError::Shard(format!(
                    "{}: appended to {} rows, expected {expect_n}",
                    client.addr(),
                    sh.n
                ))),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected append reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        Ok(())
    }

    /// Distributed cross-kernel vector for one query row: gather every
    /// shard's raw per-block `(kxx, vector)` partials, reduce in global
    /// block order (shard order × in-shard block order), normalize once.
    /// Bit-identical to `WlshSketch::cross_vector` on the full sketch.
    fn cross_vector(&self, row: &[f32], n: usize) -> Result<(f64, Vec<f64>), KrrError> {
        let per_shard = self.for_each_shard(|_, client| {
            match client.call(&Request::ShardCross { row: row.to_vec() })? {
                Response::CrossPartials(partials) => Ok(partials),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected cross reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        let mut kxx = 0.0f64;
        let mut out = vec![0.0f64; n];
        for (s, partials) in per_shard.iter().enumerate() {
            for (kp, p) in partials {
                if p.len() != n {
                    return Err(KrrError::Shard(format!(
                        "{}: cross partial has {} rows, expected {n}",
                        self.clients[s].addr(),
                        p.len()
                    )));
                }
                kxx += kp;
                for (o, v) in out.iter_mut().zip(p) {
                    *o += *v;
                }
            }
        }
        let inv_m = 1.0 / self.plan.m_total as f64;
        kxx *= inv_m;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        Ok((kxx, out))
    }

    /// Freeze every shard's serving loads from the solved β.
    fn load_beta(&self, beta: &[f64]) -> Result<(), KrrError> {
        self.for_each_shard(|_, client| {
            match client.call(&Request::ShardLoadBeta { beta: beta.to_vec() })? {
                Response::ShardReady(ShardReady { loaded: true, .. }) => Ok(()),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected load-beta reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        Ok(())
    }

    /// Distributed prediction: gather raw per-instance terms from every
    /// shard, accumulate left-to-right in global instance order
    /// (skipping bucket misses), normalize once. Bit-identical to the
    /// single-process predictor.
    fn predict(&self, rows: &[Vec<f32>], out: &mut [f64]) -> Result<(), KrrError> {
        assert_eq!(rows.len(), out.len(), "one output slot per query row");
        let per_shard = self.for_each_shard(|_, client| {
            match client.call(&Request::ShardPredict { rows: rows.to_vec() })? {
                Response::PredictPartials(terms) => Ok(terms),
                other => Err(KrrError::Shard(format!(
                    "{}: unexpected predict reply {other:?}",
                    client.addr()
                ))),
            }
        })?;
        for (s, terms) in per_shard.iter().enumerate() {
            if terms.len() != rows.len() {
                return Err(KrrError::Shard(format!(
                    "{}: {} query rows replied, expected {}",
                    self.clients[s].addr(),
                    terms.len(),
                    rows.len()
                )));
            }
        }
        let inv_m = 1.0 / self.plan.m_total as f64;
        for (qi, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for terms in &per_shard {
                for t in terms[qi].iter().flatten() {
                    acc += *t;
                }
            }
            *o = acc * inv_m;
        }
        Ok(())
    }
}

impl Drop for ShardGroup {
    fn drop(&mut self) {
        let mut children = self.children.lock().expect("children lock poisoned");
        if children.is_empty() {
            return;
        }
        // polite shutdown first (lets workers exit 0), then the axe
        for client in &self.clients {
            client.send_shutdown();
        }
        for child in children.iter_mut() {
            let deadline = Instant::now() + Duration::from_millis(500);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// Resolve the `shard-worker` binary for locally spawned shards:
/// `WLSH_SHARD_BIN` wins; otherwise the current executable (when it *is*
/// `wlsh-krr`), else `wlsh-krr` next to it or one directory up (test
/// binaries live in `target/<profile>/deps/`).
fn worker_binary() -> Result<std::path::PathBuf, KrrError> {
    if let Ok(bin) = std::env::var("WLSH_SHARD_BIN") {
        return Ok(bin.into());
    }
    let exe = std::env::current_exe()
        .map_err(|e| KrrError::Shard(format!("cannot locate own binary: {e}")))?;
    let name = format!("wlsh-krr{}", std::env::consts::EXE_SUFFIX);
    if exe.file_name().map(|f| f == name.as_str()).unwrap_or(false) {
        return Ok(exe);
    }
    let dir = exe.parent().unwrap_or(std::path::Path::new("."));
    for candidate in [dir.join(&name), dir.join("..").join(&name)] {
        if candidate.is_file() {
            return Ok(candidate);
        }
    }
    Err(KrrError::Shard(format!(
        "cannot find the wlsh-krr binary near {} (set WLSH_SHARD_BIN)",
        exe.display()
    )))
}

/// The m-instance WLSH operator, physically partitioned across a
/// [`ShardGroup`]. The CG loop calls [`KrrOperator::matvec`]
/// coordinator-side exactly as for a local sketch; only the fused-block
/// kernel runs remotely.
///
/// `matvec` is infallible by trait contract, so shard failures latch
/// into an internal slot: the first error is recorded, every subsequent
/// mat-vec/predict short-circuits to zeros, and the trainer turns the
/// latch into `Err(KrrError::Shard)` after the solve — a dead worker
/// costs one read-timeout at most, never a hang, never a silently wrong
/// model.
pub struct ShardedOperator {
    group: Arc<ShardGroup>,
    /// Training rows currently hashed (atomic: online appends grow it
    /// while CG/serving readers hold the same `Arc`).
    n: AtomicUsize,
    d: usize,
    /// Importance-sampling provenance when the build was non-uniform
    /// (surfaced through [`KrrOperator::sampling_header`] so sharded
    /// models checkpoint their keep list exactly like local ones).
    sampling: Option<SamplingInfo>,
    failure: Mutex<Option<KrrError>>,
}

impl ShardedOperator {
    /// Stand up the topology (spawn or connect per `config.topology`)
    /// and distribute the sketch build.
    ///
    /// Non-uniform sampling is resolved *before* the fan-out: the
    /// coordinator (which holds the full training matrix anyway) builds
    /// the pool locally, scores it, and ships each shard its slice of
    /// the kept `(index, weight)` sequence. The shard plan then covers
    /// the kept count m′, so the distributed operator normalizes by
    /// `1/m′` exactly like the single-process weighted sketch.
    pub fn build(
        config: &KrrConfig,
        x: &[f32],
        n: usize,
        d: usize,
    ) -> Result<Arc<ShardedOperator>, KrrError> {
        let selection = if config.sampling.is_uniform() {
            None
        } else {
            let src = MatrixSource::new("coordinator", x, d.max(1));
            let params = WlshBuildParams::from_config(config, n, d);
            let full = WlshSketch::build(&params, &src)?;
            Some(full.sampling_info.clone().ok_or_else(|| {
                KrrError::BadParam(format!(
                    "sampling {} recorded no selection to shard",
                    config.sampling
                ))
            })?)
        };
        let m_total = selection.as_ref().map_or(config.budget, |i| i.kept.len());
        let group = match &config.topology {
            TopologySpec::Local => {
                return Err(KrrError::BadParam(
                    "ShardedOperator::build called with a local topology".into(),
                ))
            }
            TopologySpec::Shards { n: shards } => ShardGroup::spawn_local(*shards, m_total)?,
            TopologySpec::Remote { addrs } => ShardGroup::connect_remote(addrs, m_total)?,
        };
        group.build(config, x, n, d, selection.as_ref())?;
        Ok(Arc::new(ShardedOperator {
            group: Arc::new(group),
            n: AtomicUsize::new(n),
            d,
            sampling: selection,
            failure: Mutex::new(None),
        }))
    }

    /// Append `x_new` (row-major, `d` features per row) to every shard's
    /// sketch, resuming the incremental build. Unlike the in-process
    /// sketches there is no copy-on-write here — the sketch state lives
    /// in the worker processes, so the append mutates it in place for
    /// every handle sharing this operator.
    pub fn append(&self, x_new: &[f32]) -> Result<usize, KrrError> {
        if let Some(e) = self.failure() {
            return Err(e);
        }
        if x_new.len() % self.d != 0 {
            return Err(KrrError::BadParam(format!(
                "append expects {} features per row, got {} values",
                self.d,
                x_new.len()
            )));
        }
        let k = x_new.len() / self.d;
        let expect_n = self.n.load(Ordering::SeqCst) + k;
        self.group.append(x_new, expect_n)?;
        self.n.store(expect_n, Ordering::SeqCst);
        Ok(k)
    }

    /// The first shard failure, if any (checked by the trainer after the
    /// solve; the slot stays latched so later checks see it too).
    pub fn failure(&self) -> Option<KrrError> {
        self.failure.lock().expect("failure lock poisoned").clone()
    }

    fn latch(&self, e: KrrError) {
        self.failure.lock().expect("failure lock poisoned").get_or_insert(e);
    }

    fn failed(&self) -> bool {
        self.failure.lock().expect("failure lock poisoned").is_some()
    }

    pub fn group(&self) -> &Arc<ShardGroup> {
        &self.group
    }
}

impl KrrOperator for ShardedOperator {
    fn n(&self) -> usize {
        self.n.load(Ordering::SeqCst)
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        let n = self.n();
        if self.failed() {
            return vec![0.0; n];
        }
        match self.group.matvec(beta, n) {
            Ok(y) => y,
            Err(e) => {
                self.latch(e);
                vec![0.0; n]
            }
        }
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let rows: Vec<Vec<f32>> = queries.chunks(self.d).map(<[f32]>::to_vec).collect();
        let mut out = vec![0.0f64; rows.len()];
        let run = || -> Result<(), KrrError> {
            self.group.load_beta(beta)?;
            self.group.predict(&rows, &mut out)
        };
        if let Err(e) = run() {
            self.latch(e);
            out.fill(0.0);
        }
        out
    }

    fn predictor(self: Arc<Self>, beta: &[f64]) -> Box<dyn Predictor> {
        if let Err(e) = self.group.load_beta(beta) {
            self.latch(e);
        }
        let d = self.d;
        Box::new(ShardedPredictor { op: self, d })
    }

    // `diag()` stays the default `None`: the diagonal lives with the
    // shard weights, and the Jacobi path already falls back (with a
    // warning) when an operator exposes no cheap diagonal.

    fn cross_vector(&self, query: &[f32]) -> Option<(f64, Vec<f64>)> {
        let n = self.n();
        if self.failed() {
            return None;
        }
        match self.group.cross_vector(query, n) {
            Ok(kv) => Some(kv),
            Err(e) => {
                self.latch(e);
                None
            }
        }
    }

    fn name(&self) -> String {
        format!(
            "sharded-wlsh(m={},shards={})",
            self.group.plan.m_total,
            self.group.n_shards()
        )
    }

    fn sampling_header(&self) -> Option<&SamplingInfo> {
        self.sampling.as_ref()
    }

    fn memory_bytes(&self) -> usize {
        // coordinator-side footprint only — the sketch lives in the
        // worker processes
        0
    }
}

/// Serving handle over a [`ShardedOperator`]: fans each query batch to
/// every shard and reduces the raw terms in instance order. Implements
/// the same [`Predictor`] contract local sketches do, so a sharded model
/// flows through the registry / worker pool / TCP server (backpressure,
/// stats, hot-reload) unchanged.
pub struct ShardedPredictor {
    op: Arc<ShardedOperator>,
    d: usize,
}

impl Predictor for ShardedPredictor {
    fn dim(&self) -> usize {
        self.d
    }

    fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        if self.op.failed() {
            out.fill(0.0);
            return;
        }
        let rows: Vec<Vec<f32>> = queries.chunks(self.d).map(<[f32]>::to_vec).collect();
        if let Err(e) = self.op.group.predict(&rows, out) {
            self.op.latch(e);
            out.fill(0.0);
        }
    }
}

// ------------------------------------------------------------- the worker

/// Shard-worker state: the owned instance range of the sketch, plus
/// serving loads once a β has been frozen.
struct WorkerState {
    sketch: Option<Arc<WlshSketch>>,
    loads: Option<Vec<Vec<f64>>>,
    d: usize,
    n: usize,
    workers: usize,
    chunk_rows: usize,
}

impl WorkerState {
    fn ready(&self) -> ShardReady {
        ShardReady {
            n: self.n,
            d: self.d,
            m_local: self.sketch.as_ref().map(|s| s.m()).unwrap_or(0),
            blocks: self
                .sketch
                .as_ref()
                .map(|s| s.m().div_ceil(WlshSketch::FUSE_BLOCK))
                .unwrap_or(0),
            loaded: self.loads.is_some(),
        }
    }

    fn handle(&mut self, req: Request) -> Result<Response, String> {
        match req {
            Request::ShardBuild(b) => {
                if b.x.len() != b.n * b.d {
                    return Err(format!(
                        "shard-build: x has {} values, expected n·d = {}",
                        b.x.len(),
                        b.n * b.d
                    ));
                }
                let bucket: BucketSpec = b.bucket.parse().map_err(|e| format!("{e}"))?;
                let src = MatrixSource::new("shard", &b.x, b.d.max(1));
                let params = WlshBuildParams::new(b.n, b.d, b.m_total)
                    .bucket(bucket)
                    .gamma_shape(b.gamma_shape)
                    .scale(b.scale)
                    .seed(b.seed)
                    .chunk_rows(b.chunk_rows.max(1))
                    .workers(b.workers.max(1));
                let sketch = if b.keep_idx.is_empty() {
                    WlshSketch::build_range(&params, &src, b.lo, b.hi)
                } else {
                    // the coordinator already scored the pool; build
                    // exactly the shipped (pool index, weight) slice —
                    // never re-score locally
                    let keep: Vec<(usize, f64)> = b
                        .keep_idx
                        .iter()
                        .copied()
                        .zip(b.keep_w.iter().copied())
                        .collect();
                    WlshSketch::build_selected(&params, &src, b.pool_m, &keep)
                }
                .map_err(|e| format!("{e}"))?;
                self.n = b.n;
                self.d = b.d;
                self.workers = b.workers.max(1);
                self.chunk_rows = b.chunk_rows.max(1);
                self.sketch = Some(Arc::new(sketch));
                self.loads = None;
                Ok(Response::ShardReady(self.ready()))
            }
            Request::ShardAppend { x } => {
                let sketch = self.sketch.as_mut().ok_or("no sketch built yet")?;
                if self.d == 0 || x.len() % self.d != 0 {
                    return Err(format!(
                        "shard-append: x has {} values, not a multiple of d = {}",
                        x.len(),
                        self.d
                    ));
                }
                let src = MatrixSource::new("shard-append", &x, self.d);
                let appended = Arc::make_mut(sketch)
                    .append_source(&src, self.chunk_rows, self.workers)
                    .map_err(|e| format!("{e}"))?;
                self.n += appended;
                // any frozen β predates the new rows; force a reload
                self.loads = None;
                Ok(Response::ShardReady(self.ready()))
            }
            Request::ShardCross { row } => {
                let sketch = self.sketch.as_ref().ok_or("no sketch built yet")?;
                if row.len() != self.d {
                    return Err(format!(
                        "shard-cross: expected {} features, got {}",
                        self.d,
                        row.len()
                    ));
                }
                Ok(Response::CrossPartials(sketch.cross_partials(&row, self.workers)))
            }
            Request::ShardMatvec { beta } => {
                let sketch = self.sketch.as_ref().ok_or("no sketch built yet")?;
                if beta.len() != self.n {
                    return Err(format!(
                        "shard-matvec: beta has {} rows, sketch has {}",
                        beta.len(),
                        self.n
                    ));
                }
                Ok(Response::MatvecPartials(sketch.block_partials(&beta, self.workers)))
            }
            Request::ShardLoadBeta { beta } => {
                let sketch = self.sketch.as_ref().ok_or("no sketch built yet")?;
                if beta.len() != self.n {
                    return Err(format!(
                        "shard-load-beta: beta has {} rows, sketch has {}",
                        beta.len(),
                        self.n
                    ));
                }
                self.loads = Some(sketch.loads_all(&beta, self.workers));
                Ok(Response::ShardReady(self.ready()))
            }
            Request::ShardPredict { rows } => {
                let sketch = self.sketch.as_ref().ok_or("no sketch built yet")?;
                let loads = self.loads.as_ref().ok_or("no beta loaded yet")?;
                let mut flat = Vec::with_capacity(rows.len() * self.d);
                for (i, row) in rows.iter().enumerate() {
                    if row.len() != self.d {
                        return Err(format!(
                            "shard-predict row {i}: expected {} features, got {}",
                            self.d,
                            row.len()
                        ));
                    }
                    flat.extend_from_slice(row);
                }
                Ok(Response::PredictPartials(sketch.predict_terms(loads, &flat)))
            }
            Request::ShardInfo => Ok(Response::ShardReady(self.ready())),
            Request::Shutdown => unreachable!("handled by the connection loop"),
            _ => Err("shard worker speaks shard-* ops only".to_string()),
        }
    }
}

/// Run a shard worker: bind `addr`, announce `shard listening on
/// <addr>` on stdout (machine-readable — the spawner scrapes it), then
/// serve coordinator connections sequentially until a `shutdown`
/// request. Exposed as a library function so tests can run in-thread
/// workers; the `wlsh-krr shard-worker` subcommand is a thin wrapper.
pub fn run_worker(addr: &str, ready: Option<mpsc::Sender<String>>) -> Result<(), KrrError> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| KrrError::Io(format!("shard bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| KrrError::Io(e.to_string()))?
        .to_string();
    println!("shard listening on {local}");
    // stdout is scraped by the spawner; make sure the line is visible
    // even through a pipe
    std::io::stdout().flush().ok();
    if let Some(tx) = ready {
        tx.send(local).ok();
    }
    let mut state =
        WorkerState { sketch: None, loads: None, d: 0, n: 0, workers: 1, chunk_rows: 1 };
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        stream.set_nodelay(true).ok();
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
        let mut writer = stream.try_clone().map_err(|e| KrrError::Io(e.to_string()))?;
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break, // connection died; await the next one
            };
            if line.trim().is_empty() {
                continue;
            }
            let reply = match Request::parse(&line) {
                Ok(Request::Shutdown) => {
                    let bye = Response::Ok { model: None }.to_line();
                    let _ = writeln!(writer, "{bye}");
                    return Ok(());
                }
                Ok(req) => match state.handle(req) {
                    Ok(resp) => resp,
                    Err(msg) => Response::Error(msg),
                },
                Err(msg) => Response::Error(msg),
            };
            if writeln!(writer, "{}", reply.to_line()).is_err() {
                break;
            }
        }
        // EOF: the coordinator disconnected; keep state and wait for a
        // reconnect (sketches are expensive to rebuild)
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_cuts_on_block_boundaries_and_covers_everything() {
        for (m, shards) in [(64usize, 4usize), (37, 2), (8, 3), (100, 7), (16, 1), (4, 3)] {
            let plan = ShardPlan::new(m, shards);
            assert_eq!(plan.ranges.len(), shards);
            assert_eq!(plan.ranges[0].0, 0);
            assert_eq!(plan.ranges[shards - 1].1, m, "m={m} shards={shards}");
            for w in plan.ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous: m={m} shards={shards}");
            }
            for &(lo, hi) in &plan.ranges {
                assert!(lo <= hi);
                assert_eq!(lo % WlshSketch::FUSE_BLOCK, 0, "lo={lo} not block-aligned");
                assert!(
                    hi % WlshSketch::FUSE_BLOCK == 0 || hi == m,
                    "hi={hi} not block-aligned (m={m})"
                );
            }
        }
    }

    #[test]
    fn worker_rejects_serving_requests_and_premature_ops() {
        let mut state =
            WorkerState { sketch: None, loads: None, d: 0, n: 0, workers: 1, chunk_rows: 1 };
        let err = state
            .handle(Request::Predict { features: vec![1.0], model: None, var: false })
            .unwrap_err();
        assert!(err.contains("shard-* ops only"), "{err}");
        let err = state.handle(Request::ShardMatvec { beta: vec![] }).unwrap_err();
        assert!(err.contains("no sketch"), "{err}");
        let err = state.handle(Request::ShardPredict { rows: vec![] }).unwrap_err();
        assert!(err.contains("no sketch"), "{err}");
    }
}
