//! Typed wire protocol for the JSON-lines serving/shard fabric.
//!
//! One request/response grammar, shared by every endpoint that speaks the
//! TCP protocol: the serving tier ([`serve`](crate::coordinator::serve)),
//! the shard-worker loop (`krr shard-worker`), the example clients, and
//! the load tests. A [`Request`] parses from one line and serializes back
//! to one line ([`Request::to_line`] / [`Request::parse`] round-trip
//! bit-exactly, property-tested below); same for [`Response`].
//!
//! Serving requests (wire-compatible with the pre-typed protocol):
//!
//! ```text
//! → {"features": [f32...], "model"?: "name"}      ← {"pred": η̃(q)}
//! → {"batch": [[f32...],...], "model"?: "name"}   ← one {"pred": ...} line per row
//! → {"sparse": [[idx, val],...], "model"?: "..."} ← {"pred": ...}
//! → {"cmd": "stats"}                              ← {"served": ..., "p50_us": ..., ...}
//! → {"cmd": "reload", "model"?: "m", "path": "ckpt"} ← {"ok": "true", "model": "m"}
//! → {"cmd": "shutdown"}                           ← {"ok": "true"}
//! ```
//!
//! Online-learning and uncertainty extensions: predict/batch accept an
//! optional `"var": true` flag (answered with `{"pred":…,"var":…}` lines
//! carrying the sketched posterior variance), and `append` streams new
//! training rows into a model's online trainer:
//!
//! ```text
//! → {"features": [...], "var": true}              ← {"pred": ..., "var": ...}
//! → {"batch": [[...],...], "var": true}           ← one {"pred":…,"var":…} line per row
//! → {"cmd": "append", "rows": [[f32...],...], "targets": [f64...], "model"?: "m"}
//!           ← {"appended": k, "n": n, "generation": g, "last_update": ts,
//!              "warm_iters": w, "cold_iters": c|null}
//! ```
//!
//! Shard operations (new verbs under the same `"cmd"` key; the
//! coordinator is the only client):
//!
//! ```text
//! → {"cmd": "shard-build", n, d, x, m_total, lo, hi, bucket, ...}
//!                                   ← {"shard": {n, d, m_local, blocks}}
//! → {"cmd": "shard-matvec", "beta": [f64...]}
//!                                   ← {"block_partials": [[f64...],...]}
//! → {"cmd": "shard-load-beta", "beta": [f64...]}
//!                                   ← {"shard": {...}}
//! → {"cmd": "shard-predict", "rows": [[f32...],...]}
//!                                   ← {"query_partials": [[f64|null,...],...]}
//! → {"cmd": "shard-append", "x": [f32...]}
//!                                   ← {"shard": {...}}
//! → {"cmd": "shard-cross", "row": [f32...]}
//!                                   ← {"cross_kxx": [f64...], "cross_blocks": [[f64...],...]}
//! → {"cmd": "shard-info"}           ← {"shard": {...}}
//! ```
//!
//! Number transport is bit-exact for finite values: Rust's `{}` Display
//! for f64/f32 emits the shortest decimal that round-trips, and the JSON
//! parser reads it back through `str::parse::<f64>` — so β, partial sums,
//! and f32 feature rows cross the wire without losing a bit (this is what
//! lets the distributed solve reproduce the single-process solution
//! exactly). Non-finite values serialize as `null`: a semantic bucket-miss
//! marker inside `query_partials`, a loud parse error everywhere else.
//!
//! Parsing here is *structural* (shapes and types, with the exact error
//! strings the server has always replied with); *semantic* checks that
//! need server state (feature-count mismatches, `max_batch`, sparse index
//! range vs the model dimension) stay in the endpoint that owns the
//! state.

use crate::util::json::{escape, Json, JsonWriter};
use std::fmt::Write as _;

/// One parsed protocol request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Predict one dense feature row. `var` asks for the sketched
    /// posterior variance alongside the point prediction.
    Predict { features: Vec<f32>, model: Option<String>, var: bool },
    /// Predict a batch of dense rows (one reply line per row). `var` asks
    /// for per-row variance.
    Batch { rows: Vec<Vec<f32>>, model: Option<String>, var: bool },
    /// Predict one sparse row given as `[index, value]` pairs.
    Sparse { pairs: Vec<(usize, f64)>, model: Option<String> },
    /// Server-wide serving statistics.
    Stats,
    /// Atomically hot-swap `model` (default: the registry's default slot)
    /// from the checkpoint at `path`.
    Reload { model: Option<String>, path: String },
    /// Stop accepting connections and drain.
    Shutdown,
    /// Append training rows to `model`'s online trainer and re-solve
    /// (requires an attached [`crate::online::OnlineTrainer`]).
    Append { model: Option<String>, rows: Vec<Vec<f32>>, targets: Vec<f64> },
    /// Build this worker's instance range of the WLSH sketch.
    ShardBuild(ShardBuild),
    /// Raw per-block mat-vec partials for the coordinator's CG step.
    ShardMatvec { beta: Vec<f64> },
    /// Freeze serving loads from the solved β.
    ShardLoadBeta { beta: Vec<f64> },
    /// Raw per-instance prediction terms for a query batch.
    ShardPredict { rows: Vec<Vec<f32>> },
    /// Hash additional training rows (row-major, the worker's `d`) into
    /// this worker's instance range, resuming the incremental build.
    ShardAppend { x: Vec<f32> },
    /// Raw per-block cross-kernel partials `(Σ w_s(q)², unnormalized
    /// k̃_q-contribution)` for one query row — the distributed half of
    /// `WlshSketch::cross_vector`.
    ShardCross { row: Vec<f32> },
    /// Describe the worker's current shard state.
    ShardInfo,
}

/// Everything a shard worker needs to build instances `[lo, hi)` of an
/// m_total-instance WLSH sketch bit-identically to a single-process
/// build: the raw (already standardized) training rows plus the exact
/// sketch parameters. `chunk_rows`/`workers` shape memory and threading
/// only — the result is bit-transparent to both.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardBuild {
    pub n: usize,
    pub d: usize,
    /// Row-major n×d training matrix.
    pub x: Vec<f32>,
    pub m_total: usize,
    pub lo: usize,
    pub hi: usize,
    /// Bucket spec string (`BucketSpec` grammar).
    pub bucket: String,
    pub gamma_shape: f64,
    pub scale: f64,
    pub seed: u64,
    pub chunk_rows: usize,
    pub workers: usize,
    /// Importance-sampled builds only: the instance-pool size the kept
    /// indices refer to. 0 (with empty `keep_idx`) means a uniform range
    /// build — the wire omits all three keys, so legacy lines parse
    /// unchanged.
    pub pool_m: usize,
    /// Kept pool indices owned by this shard (ascending); paired
    /// one-to-one with `keep_w`.
    pub keep_idx: Vec<usize>,
    /// Importance weights for `keep_idx`, applied verbatim by the worker.
    pub keep_w: Vec<f64>,
}

/// One parsed protocol response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// One prediction.
    Pred(f64),
    /// One prediction plus its sketched posterior variance (reply to a
    /// `"var": true` predict/batch).
    PredVar { pred: f64, var: f64 },
    /// Command acknowledged (`reload` echoes the swapped model name).
    Ok { model: Option<String> },
    /// Request-level failure (the connection stays open).
    Error(String),
    /// Server-wide serving statistics.
    Stats(StatsReply),
    /// Online append acknowledged: rows accepted, new training-set size,
    /// the slot's post-swap generation / last-update stamp, and the CG
    /// iteration counts of the warm (and, in `ColdExact` mode, cold)
    /// re-solves.
    Appended {
        appended: usize,
        n: usize,
        generation: usize,
        last_update: usize,
        warm_iters: usize,
        cold_iters: Option<usize>,
    },
    /// Shard worker state (reply to build / load-beta / info).
    ShardReady(ShardReady),
    /// Raw per-FUSE_BLOCK mat-vec partial vectors, in local block order,
    /// without the 1/m normalization (the coordinator owns the global
    /// reduction order and applies 1/m_total once).
    MatvecPartials(Vec<Vec<f64>>),
    /// Per query row, the raw per-instance terms `w · B_{h(q)}` for this
    /// worker's instances, in local instance order; `None` marks a bucket
    /// miss (skipped, not added as 0.0, so coordinator-side accumulation
    /// replays the single-process chain exactly).
    PredictPartials(Vec<Vec<Option<f64>>>),
    /// Per-FUSE_BLOCK cross-kernel partials `(kxx_partial, unnormalized
    /// vector)`, in local block order, without the 1/m normalization —
    /// the coordinator concatenates shard replies in shard order (= the
    /// global block order) and normalizes once.
    CrossPartials(Vec<(f64, Vec<f64>)>),
}

/// Shard worker state echoed after `shard-build`/`shard-load-beta`, and
/// on demand via `shard-info`.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardReady {
    /// Training rows hashed (0 before a build).
    pub n: usize,
    /// Feature dimension (0 before a build).
    pub d: usize,
    /// Instances this worker owns.
    pub m_local: usize,
    /// FUSE_BLOCK-blocks this worker owns.
    pub blocks: usize,
    /// Whether serving loads are frozen (a β has been loaded).
    pub loaded: bool,
}

/// Typed form of the server's `stats` reply.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsReply {
    pub served: usize,
    pub rejected: usize,
    pub queue_depth: usize,
    pub workers: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Per-model counters, name-sorted.
    pub models: Vec<(String, ModelStatsReply)>,
}

/// One model's slice of the `stats` reply.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelStatsReply {
    pub served: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Monotonic model version (1 = first registration; +1 per swap).
    pub generation: usize,
    /// Unix seconds of the most recent swap into the slot (0 = never).
    pub last_update: usize,
}

// ---------------------------------------------------------------- helpers

fn push_f64(buf: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(buf, "{v}");
    } else {
        buf.push_str("null");
    }
}

fn push_f64s(buf: &mut String, vs: &[f64]) {
    buf.push('[');
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        push_f64(buf, *v);
    }
    buf.push(']');
}

fn push_f32s(buf: &mut String, vs: &[f32]) {
    buf.push('[');
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        if v.is_finite() {
            let _ = write!(buf, "{v}");
        } else {
            buf.push_str("null");
        }
    }
    buf.push(']');
}

fn push_f32_rows(buf: &mut String, rows: &[Vec<f32>]) {
    buf.push('[');
    for (i, row) in rows.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        push_f32s(buf, row);
    }
    buf.push(']');
}

fn push_model(buf: &mut String, model: &Option<String>) {
    if let Some(m) = model {
        buf.push_str(",\"model\":");
        buf.push_str(&escape(m));
    }
}

/// f64 vec → f32 vec (the wire carries f32 features as their exact f64
/// embedding, so this cast is lossless for values that started as f32).
fn to_f32s(vs: Vec<f64>) -> Vec<f32> {
    vs.into_iter().map(|v| v as f32).collect()
}

fn f32_rows_field(req: &Json, key: &str) -> Result<Vec<Vec<f32>>, String> {
    let rows = req
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{key:?} must be an array of feature rows"))?;
    rows.iter()
        .enumerate()
        .map(|(i, row)| {
            row.as_f64_vec()
                .map(to_f32s)
                .ok_or_else(|| format!("{key} row {i} must be an array of numbers"))
        })
        .collect()
}

fn f64_vec_field(req: &Json, key: &str) -> Result<Vec<f64>, String> {
    req.get(key)
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| format!("{key:?} must be an array of numbers"))
}

fn usize_field(req: &Json, key: &str) -> Result<usize, String> {
    req.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("{key:?} must be a non-negative integer"))
}

fn usize_vec_field(req: &Json, key: &str) -> Result<Vec<usize>, String> {
    let arr = req
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{key:?} must be an array of non-negative integers"))?;
    arr.iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| format!("{key:?} must be an array of non-negative integers"))
        })
        .collect()
}

fn f64_field(req: &Json, key: &str) -> Result<f64, String> {
    req.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{key:?} must be a number"))
}

fn str_field(req: &Json, key: &str) -> Result<String, String> {
    req.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("{key:?} must be a string"))
}

fn sparse_pairs(j: &Json) -> Result<Vec<(usize, f64)>, String> {
    let arr = j
        .as_arr()
        .ok_or_else(|| "\"sparse\" must be an array of [index, value] pairs".to_string())?;
    let mut pairs = Vec::with_capacity(arr.len());
    for (i, pair) in arr.iter().enumerate() {
        let pv = pair
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| format!("sparse entry {i} must be an [index, value] pair"))?;
        let idx = pv[0]
            .as_usize()
            .ok_or_else(|| format!("sparse entry {i}: index must be a non-negative integer"))?;
        let val = pv[1]
            .as_f64()
            .ok_or_else(|| format!("sparse entry {i}: value must be a number"))?;
        pairs.push((idx, val));
    }
    Ok(pairs)
}

// ---------------------------------------------------------------- Request

impl Request {
    /// Parse one request line. The error string is ready to send back as
    /// a [`Response::Error`] (these are the exact messages the server has
    /// always used).
    pub fn parse(line: &str) -> Result<Request, String> {
        let req = Json::parse(line)?;
        let model = req.get("model").and_then(Json::as_str).map(str::to_string);
        // `"var": true` opts in; absent / false / anything else means no
        // variance (legacy lines carry no "var" key at all)
        let var = matches!(req.get("var"), Some(Json::Bool(true)));
        if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
            return match cmd {
                "stats" => Ok(Request::Stats),
                "shutdown" => Ok(Request::Shutdown),
                "reload" => {
                    let path = req
                        .get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| "reload needs \"path\"".to_string())?;
                    Ok(Request::Reload { model, path: path.to_string() })
                }
                "append" => {
                    let rows = f32_rows_field(&req, "rows")?;
                    let targets = f64_vec_field(&req, "targets")?;
                    if rows.is_empty() {
                        return Err("append needs at least one row".to_string());
                    }
                    if rows.len() != targets.len() {
                        return Err(format!(
                            "append has {} rows but {} targets",
                            rows.len(),
                            targets.len()
                        ));
                    }
                    Ok(Request::Append { model, rows, targets })
                }
                "shard-build" => {
                    // sampling keys are optional (legacy lines omit them);
                    // present-but-malformed is still an error
                    let pool_m = match req.get("pool_m") {
                        Some(_) => usize_field(&req, "pool_m")?,
                        None => 0,
                    };
                    let keep_idx = match req.get("keep_idx") {
                        Some(_) => usize_vec_field(&req, "keep_idx")?,
                        None => Vec::new(),
                    };
                    let keep_w = match req.get("keep_w") {
                        Some(_) => f64_vec_field(&req, "keep_w")?,
                        None => Vec::new(),
                    };
                    if keep_idx.len() != keep_w.len() {
                        return Err(format!(
                            "shard-build has {} keep_idx but {} keep_w",
                            keep_idx.len(),
                            keep_w.len()
                        ));
                    }
                    Ok(Request::ShardBuild(ShardBuild {
                        n: usize_field(&req, "n")?,
                        d: usize_field(&req, "d")?,
                        x: to_f32s(f64_vec_field(&req, "x")?),
                        m_total: usize_field(&req, "m_total")?,
                        lo: usize_field(&req, "lo")?,
                        hi: usize_field(&req, "hi")?,
                        bucket: str_field(&req, "bucket")?,
                        gamma_shape: f64_field(&req, "gamma_shape")?,
                        scale: f64_field(&req, "scale")?,
                        seed: usize_field(&req, "seed")? as u64,
                        chunk_rows: usize_field(&req, "chunk_rows")?,
                        workers: usize_field(&req, "workers")?,
                        pool_m,
                        keep_idx,
                        keep_w,
                    }))
                }
                "shard-matvec" => {
                    Ok(Request::ShardMatvec { beta: f64_vec_field(&req, "beta")? })
                }
                "shard-load-beta" => {
                    Ok(Request::ShardLoadBeta { beta: f64_vec_field(&req, "beta")? })
                }
                "shard-predict" => {
                    Ok(Request::ShardPredict { rows: f32_rows_field(&req, "rows")? })
                }
                "shard-append" => {
                    Ok(Request::ShardAppend { x: to_f32s(f64_vec_field(&req, "x")?) })
                }
                "shard-cross" => {
                    Ok(Request::ShardCross { row: to_f32s(f64_vec_field(&req, "row")?) })
                }
                "shard-info" => Ok(Request::ShardInfo),
                other => Err(format!("unknown cmd {other:?}")),
            };
        }
        if let Some(sp) = req.get("sparse") {
            return Ok(Request::Sparse { pairs: sparse_pairs(sp)?, model });
        }
        if let Some(f) = req.get("features") {
            let features = f
                .as_f64_vec()
                .map(to_f32s)
                .ok_or_else(|| "\"features\" must be an array of numbers".to_string())?;
            return Ok(Request::Predict { features, model, var });
        }
        if req.get("batch").is_some() {
            let rows = f32_rows_field(&req, "batch")?;
            if rows.is_empty() {
                return Err("\"batch\" must contain at least one row".to_string());
            }
            return Ok(Request::Batch { rows, model, var });
        }
        Err("need \"features\", \"batch\", or \"cmd\"".to_string())
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Predict { features, model, var } => {
                let mut s = String::from("{\"features\":");
                push_f32s(&mut s, features);
                push_model(&mut s, model);
                if *var {
                    s.push_str(",\"var\":true");
                }
                s.push('}');
                s
            }
            Request::Batch { rows, model, var } => {
                let mut s = String::from("{\"batch\":");
                push_f32_rows(&mut s, rows);
                push_model(&mut s, model);
                if *var {
                    s.push_str(",\"var\":true");
                }
                s.push('}');
                s
            }
            Request::Sparse { pairs, model } => {
                let mut s = String::from("{\"sparse\":[");
                for (i, (idx, val)) in pairs.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    let _ = write!(s, "[{idx},");
                    push_f64(&mut s, *val);
                    s.push(']');
                }
                s.push(']');
                push_model(&mut s, model);
                s.push('}');
                s
            }
            Request::Stats => "{\"cmd\":\"stats\"}".to_string(),
            Request::Shutdown => "{\"cmd\":\"shutdown\"}".to_string(),
            Request::Append { model, rows, targets } => {
                let mut s = String::from("{\"cmd\":\"append\",\"rows\":");
                push_f32_rows(&mut s, rows);
                s.push_str(",\"targets\":");
                push_f64s(&mut s, targets);
                push_model(&mut s, model);
                s.push('}');
                s
            }
            Request::Reload { model, path } => {
                let mut s = String::from("{\"cmd\":\"reload\"");
                push_model(&mut s, model);
                s.push_str(",\"path\":");
                s.push_str(&escape(path));
                s.push('}');
                s
            }
            Request::ShardBuild(b) => {
                let mut s = String::with_capacity(b.x.len() * 8 + 256);
                let _ = write!(
                    s,
                    "{{\"cmd\":\"shard-build\",\"n\":{},\"d\":{},\"m_total\":{},\"lo\":{},\
                     \"hi\":{},\"bucket\":{},\"gamma_shape\":",
                    b.n,
                    b.d,
                    b.m_total,
                    b.lo,
                    b.hi,
                    escape(&b.bucket)
                );
                push_f64(&mut s, b.gamma_shape);
                s.push_str(",\"scale\":");
                push_f64(&mut s, b.scale);
                let _ = write!(
                    s,
                    ",\"seed\":{},\"chunk_rows\":{},\"workers\":{}",
                    b.seed, b.chunk_rows, b.workers
                );
                // sampling keys ride along only for importance-sampled
                // builds, so uniform lines stay byte-identical to the
                // legacy wire format
                if !b.keep_idx.is_empty() {
                    let _ = write!(s, ",\"pool_m\":{},\"keep_idx\":[", b.pool_m);
                    for (i, idx) in b.keep_idx.iter().enumerate() {
                        if i > 0 {
                            s.push(',');
                        }
                        let _ = write!(s, "{idx}");
                    }
                    s.push_str("],\"keep_w\":");
                    push_f64s(&mut s, &b.keep_w);
                }
                s.push_str(",\"x\":");
                push_f32s(&mut s, &b.x);
                s.push('}');
                s
            }
            Request::ShardMatvec { beta } => {
                let mut s = String::with_capacity(beta.len() * 10 + 32);
                s.push_str("{\"cmd\":\"shard-matvec\",\"beta\":");
                push_f64s(&mut s, beta);
                s.push('}');
                s
            }
            Request::ShardLoadBeta { beta } => {
                let mut s = String::with_capacity(beta.len() * 10 + 32);
                s.push_str("{\"cmd\":\"shard-load-beta\",\"beta\":");
                push_f64s(&mut s, beta);
                s.push('}');
                s
            }
            Request::ShardPredict { rows } => {
                let mut s = String::from("{\"cmd\":\"shard-predict\",\"rows\":");
                push_f32_rows(&mut s, rows);
                s.push('}');
                s
            }
            Request::ShardAppend { x } => {
                let mut s = String::with_capacity(x.len() * 8 + 32);
                s.push_str("{\"cmd\":\"shard-append\",\"x\":");
                push_f32s(&mut s, x);
                s.push('}');
                s
            }
            Request::ShardCross { row } => {
                let mut s = String::from("{\"cmd\":\"shard-cross\",\"row\":");
                push_f32s(&mut s, row);
                s.push('}');
                s
            }
            Request::ShardInfo => "{\"cmd\":\"shard-info\"}".to_string(),
        }
    }
}

// --------------------------------------------------------------- Response

impl Response {
    /// Parse one reply line. `Err` means the line was not even a
    /// recognizable reply (a protocol-level failure, distinct from a
    /// well-formed [`Response::Error`]).
    pub fn parse(line: &str) -> Result<Response, String> {
        let j = Json::parse(line)?;
        if let Some(msg) = j.get("error").and_then(Json::as_str) {
            return Ok(Response::Error(msg.to_string()));
        }
        if let Some(p) = j.get("pred") {
            let pred = p
                .as_f64()
                .ok_or_else(|| "\"pred\" must be a number".to_string())?;
            if let Some(v) = j.get("var") {
                let var = v
                    .as_f64()
                    .ok_or_else(|| "\"var\" must be a number".to_string())?;
                return Ok(Response::PredVar { pred, var });
            }
            return Ok(Response::Pred(pred));
        }
        if j.get("appended").is_some() {
            let cold_iters = match j.get("cold_iters") {
                None | Some(Json::Null) => None,
                Some(c) => Some(c.as_usize().ok_or_else(|| {
                    "\"cold_iters\" must be a non-negative integer or null".to_string()
                })?),
            };
            return Ok(Response::Appended {
                appended: usize_field(&j, "appended")?,
                n: usize_field(&j, "n")?,
                generation: usize_field(&j, "generation")?,
                last_update: usize_field(&j, "last_update")?,
                warm_iters: usize_field(&j, "warm_iters")?,
                cold_iters,
            });
        }
        if let Some(sh) = j.get("shard") {
            return Ok(Response::ShardReady(ShardReady {
                n: usize_field(sh, "n")?,
                d: usize_field(sh, "d")?,
                m_local: usize_field(sh, "m_local")?,
                blocks: usize_field(sh, "blocks")?,
                loaded: matches!(sh.get("loaded"), Some(Json::Bool(true))),
            }));
        }
        if let Some(bp) = j.get("block_partials").and_then(Json::as_arr) {
            let partials = bp
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    p.as_f64_vec()
                        .ok_or_else(|| format!("block partial {i} must be an array of numbers"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            return Ok(Response::MatvecPartials(partials));
        }
        if let Some(qp) = j.get("query_partials").and_then(Json::as_arr) {
            let partials = qp
                .iter()
                .enumerate()
                .map(|(i, row)| {
                    let terms = row.as_arr().ok_or_else(|| {
                        format!("query partial {i} must be an array of numbers/nulls")
                    })?;
                    terms
                        .iter()
                        .map(|t| match t {
                            Json::Null => Ok(None),
                            Json::Num(v) => Ok(Some(*v)),
                            _ => Err(format!(
                                "query partial {i} must be an array of numbers/nulls"
                            )),
                        })
                        .collect::<Result<Vec<_>, _>>()
                })
                .collect::<Result<Vec<_>, _>>()?;
            return Ok(Response::PredictPartials(partials));
        }
        if let Some(kb) = j.get("cross_blocks").and_then(Json::as_arr) {
            let kxx = j
                .get("cross_kxx")
                .and_then(Json::as_f64_vec)
                .ok_or_else(|| "\"cross_kxx\" must be an array of numbers".to_string())?;
            if kxx.len() != kb.len() {
                return Err(format!(
                    "cross reply has {} kxx entries but {} blocks",
                    kxx.len(),
                    kb.len()
                ));
            }
            let blocks = kb
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    p.as_f64_vec()
                        .ok_or_else(|| format!("cross block {i} must be an array of numbers"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            return Ok(Response::CrossPartials(kxx.into_iter().zip(blocks).collect()));
        }
        if j.get("served").is_some() && j.get("workers").is_some() {
            return Ok(Response::Stats(stats_reply(&j)?));
        }
        if let Some(ok) = j.get("ok") {
            // historic wire form is the *string* "true"; accept a real
            // bool too
            if ok.as_str() == Some("true") || *ok == Json::Bool(true) {
                let model = j.get("model").and_then(Json::as_str).map(str::to_string);
                return Ok(Response::Ok { model });
            }
            return Err(format!("unrecognized \"ok\" value in reply: {line}"));
        }
        Err(format!("unrecognized reply: {line}"))
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Pred(p) => JsonWriter::object().field_f64("pred", *p).finish(),
            Response::PredVar { pred, var } => JsonWriter::object()
                .field_f64("pred", *pred)
                .field_f64("var", *var)
                .finish(),
            Response::Appended { appended, n, generation, last_update, warm_iters, cold_iters } => {
                let w = JsonWriter::object()
                    .field_usize("appended", *appended)
                    .field_usize("n", *n)
                    .field_usize("generation", *generation)
                    .field_usize("last_update", *last_update)
                    .field_usize("warm_iters", *warm_iters);
                match cold_iters {
                    Some(c) => w.field_usize("cold_iters", *c).finish(),
                    None => w.field_raw("cold_iters", "null").finish(),
                }
            }
            Response::Ok { model } => {
                let w = JsonWriter::object().field_str("ok", "true");
                match model {
                    Some(m) => w.field_str("model", m).finish(),
                    None => w.finish(),
                }
            }
            Response::Error(msg) => JsonWriter::object().field_str("error", msg).finish(),
            Response::Stats(s) => {
                let mut models = String::from("{");
                for (i, (name, m)) in s.models.iter().enumerate() {
                    if i > 0 {
                        models.push(',');
                    }
                    models.push_str(&escape(name));
                    models.push(':');
                    models.push_str(
                        &JsonWriter::object()
                            .field_usize("served", m.served)
                            .field_f64("p50_us", m.p50_us)
                            .field_f64("p95_us", m.p95_us)
                            .field_f64("p99_us", m.p99_us)
                            .field_usize("generation", m.generation)
                            .field_usize("last_update", m.last_update)
                            .finish(),
                    );
                }
                models.push('}');
                JsonWriter::object()
                    .field_usize("served", s.served)
                    .field_usize("rejected", s.rejected)
                    .field_usize("queue_depth", s.queue_depth)
                    .field_usize("workers", s.workers)
                    .field_f64("mean_us", s.mean_us)
                    .field_f64("p50_us", s.p50_us)
                    .field_f64("p90_us", s.p90_us)
                    .field_f64("p95_us", s.p95_us)
                    .field_f64("p99_us", s.p99_us)
                    .field_raw("models", &models)
                    .finish()
            }
            Response::ShardReady(sh) => {
                let body = JsonWriter::object()
                    .field_usize("n", sh.n)
                    .field_usize("d", sh.d)
                    .field_usize("m_local", sh.m_local)
                    .field_usize("blocks", sh.blocks)
                    .field_raw("loaded", if sh.loaded { "true" } else { "false" })
                    .finish();
                JsonWriter::object().field_raw("shard", &body).finish()
            }
            Response::MatvecPartials(partials) => {
                let mut s =
                    String::with_capacity(partials.iter().map(|p| p.len() * 10).sum::<usize>() + 32);
                s.push_str("{\"block_partials\":[");
                for (i, p) in partials.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    push_f64s(&mut s, p);
                }
                s.push_str("]}");
                s
            }
            Response::PredictPartials(partials) => {
                let mut s = String::from("{\"query_partials\":[");
                for (i, row) in partials.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push('[');
                    for (k, t) in row.iter().enumerate() {
                        if k > 0 {
                            s.push(',');
                        }
                        match t {
                            Some(v) => push_f64(&mut s, *v),
                            None => s.push_str("null"),
                        }
                    }
                    s.push(']');
                }
                s.push_str("]}");
                s
            }
            Response::CrossPartials(partials) => {
                let mut s =
                    String::with_capacity(partials.iter().map(|(_, p)| p.len() * 10).sum::<usize>() + 48);
                s.push_str("{\"cross_kxx\":[");
                for (i, (kxx, _)) in partials.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    push_f64(&mut s, *kxx);
                }
                s.push_str("],\"cross_blocks\":[");
                for (i, (_, p)) in partials.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    push_f64s(&mut s, p);
                }
                s.push_str("]}");
                s
            }
        }
    }
}

fn stats_reply(j: &Json) -> Result<StatsReply, String> {
    let f = |k: &str| {
        j.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("stats reply missing {k:?}"))
    };
    let u = |k: &str| {
        j.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("stats reply missing {k:?}"))
    };
    let mut models = Vec::new();
    if let Some(Json::Obj(map)) = j.get("models") {
        for (name, m) in map {
            let mf = |k: &str| {
                m.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("stats model {name:?} missing {k:?}"))
            };
            let mu = |k: &str| {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("stats model {name:?} missing {k:?}"))
            };
            models.push((
                name.clone(),
                ModelStatsReply {
                    served: mu("served")?,
                    p50_us: mf("p50_us")?,
                    p95_us: mf("p95_us")?,
                    p99_us: mf("p99_us")?,
                    generation: mu("generation")?,
                    last_update: mu("last_update")?,
                },
            ));
        }
    }
    Ok(StatsReply {
        served: u("served")?,
        rejected: u("rejected")?,
        queue_depth: u("queue_depth")?,
        workers: u("workers")?,
        mean_us: f("mean_us")?,
        p50_us: f("p50_us")?,
        p90_us: f("p90_us")?,
        p95_us: f("p95_us")?,
        p99_us: f("p99_us")?,
        models,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg64;

    fn roundtrip_req(req: &Request) -> Result<(), String> {
        let line = req.to_line();
        let back = Request::parse(&line).map_err(|e| format!("{line}: {e}"))?;
        if back != *req {
            return Err(format!("{req:?} → {line} → {back:?}"));
        }
        Ok(())
    }

    fn roundtrip_resp(resp: &Response) -> Result<(), String> {
        let line = resp.to_line();
        let back = Response::parse(&line).map_err(|e| format!("{line}: {e}"))?;
        if back != *resp {
            return Err(format!("{resp:?} → {line} → {back:?}"));
        }
        Ok(())
    }

    fn wild_f64(r: &mut Pcg64) -> f64 {
        // spread across magnitudes, including subnormal-ish extremes
        let mag = r.uniform_in(-300.0, 300.0);
        (r.normal()) * 10f64.powf(mag)
    }

    fn wild_f32(r: &mut Pcg64) -> f32 {
        let mag = r.uniform_in(-37.0, 37.0);
        ((r.normal()) * 10f64.powf(mag)) as f32
    }

    fn name(r: &mut Pcg64) -> String {
        // exercise escaping: quotes, backslashes, controls, unicode
        let alphabet = ['a', 'Z', '9', '"', '\\', '\n', '\t', 'é', '-', '_'];
        (0..r.below(8) + 1)
            .map(|_| alphabet[r.below(alphabet.len() as u64) as usize])
            .collect()
    }

    #[test]
    fn prop_requests_roundtrip_bit_exactly() {
        prop_check(
            101,
            60,
            |r| {
                let variant = r.below(12);
                let model = if r.below(2) == 0 { None } else { Some(name(r)) };
                match variant {
                    0 => Request::Predict {
                        features: (0..r.below(6) + 1).map(|_| wild_f32(r)).collect(),
                        model,
                        var: r.below(2) == 1,
                    },
                    1 => Request::Batch {
                        rows: (0..r.below(4) + 1)
                            .map(|_| (0..3).map(|_| wild_f32(r)).collect())
                            .collect(),
                        model,
                        var: r.below(2) == 1,
                    },
                    2 => Request::Sparse {
                        pairs: (0..r.below(5))
                            .map(|_| (r.below(1000) as usize, wild_f64(r)))
                            .collect(),
                        model,
                    },
                    3 => Request::Stats,
                    4 => Request::Reload { model, path: name(r) },
                    5 => Request::Shutdown,
                    6 => {
                        // half the builds carry an importance-sampling
                        // selection (the invariant the wire format keeps:
                        // keep_idx empty ⇔ pool_m == 0)
                        let k = if r.below(2) == 0 { 0 } else { r.below(6) as usize + 1 };
                        let keep_idx: Vec<usize> =
                            (0..k).map(|i| i * 3 + r.below(3) as usize).collect();
                        let keep_w: Vec<f64> = (0..k).map(|_| wild_f64(r).abs()).collect();
                        let pool_m = if k == 0 { 0 } else { r.below(64) as usize + 32 };
                        Request::ShardBuild(ShardBuild {
                            n: r.below(50) as usize,
                            d: r.below(8) as usize + 1,
                            x: (0..r.below(20)).map(|_| wild_f32(r)).collect(),
                            m_total: r.below(64) as usize + 1,
                            lo: r.below(8) as usize,
                            hi: r.below(64) as usize,
                            bucket: "smooth2".to_string(),
                            gamma_shape: wild_f64(r).abs(),
                            scale: wild_f64(r).abs(),
                            seed: r.below(1 << 40),
                            chunk_rows: r.below(100) as usize + 1,
                            workers: r.below(8) as usize + 1,
                            pool_m,
                            keep_idx,
                            keep_w,
                        })
                    }
                    7 => Request::ShardMatvec {
                        beta: (0..r.below(10) + 1).map(|_| wild_f64(r)).collect(),
                    },
                    8 => Request::ShardPredict {
                        rows: (0..r.below(4) + 1)
                            .map(|_| (0..2).map(|_| wild_f32(r)).collect())
                            .collect(),
                    },
                    9 => {
                        let k = r.below(4) as usize + 1;
                        Request::Append {
                            model,
                            rows: (0..k).map(|_| (0..2).map(|_| wild_f32(r)).collect()).collect(),
                            targets: (0..k).map(|_| wild_f64(r)).collect(),
                        }
                    }
                    10 => Request::ShardAppend {
                        x: (0..r.below(12)).map(|_| wild_f32(r)).collect(),
                    },
                    _ => Request::ShardCross {
                        row: (0..r.below(6) + 1).map(|_| wild_f32(r)).collect(),
                    },
                }
            },
            roundtrip_req,
        );
    }

    #[test]
    fn prop_responses_roundtrip_bit_exactly() {
        prop_check(
            202,
            60,
            |r| match r.below(9) {
                0 => Response::Pred(wild_f64(r)),
                1 => Response::Ok {
                    model: if r.below(2) == 0 { None } else { Some(name(r)) },
                },
                2 => Response::Error(name(r)),
                3 => Response::ShardReady(ShardReady {
                    n: r.below(1000) as usize,
                    d: r.below(50) as usize,
                    m_local: r.below(64) as usize,
                    blocks: r.below(8) as usize,
                    loaded: r.below(2) == 1,
                }),
                4 => Response::MatvecPartials(
                    (0..r.below(4) + 1)
                        .map(|_| (0..r.below(6) + 1).map(|_| wild_f64(r)).collect())
                        .collect(),
                ),
                5 => Response::PredictPartials(
                    (0..r.below(4) + 1)
                        .map(|_| {
                            (0..r.below(6) + 1)
                                .map(|_| {
                                    if r.below(3) == 0 { None } else { Some(wild_f64(r)) }
                                })
                                .collect()
                        })
                        .collect(),
                ),
                6 => Response::PredVar { pred: wild_f64(r), var: wild_f64(r).abs() },
                7 => Response::Appended {
                    appended: r.below(100) as usize,
                    n: r.below(100_000) as usize,
                    generation: r.below(1000) as usize + 1,
                    last_update: r.below(1 << 31) as usize,
                    warm_iters: r.below(500) as usize,
                    cold_iters: if r.below(2) == 0 {
                        None
                    } else {
                        Some(r.below(500) as usize)
                    },
                },
                _ => Response::CrossPartials(
                    (0..r.below(4) + 1)
                        .map(|_| {
                            (
                                wild_f64(r),
                                (0..r.below(6) + 1).map(|_| wild_f64(r)).collect(),
                            )
                        })
                        .collect(),
                ),
            },
            roundtrip_resp,
        );
    }

    #[test]
    fn stats_roundtrips_and_matches_legacy_shape() {
        let s = StatsReply {
            served: 12,
            rejected: 1,
            queue_depth: 1024,
            workers: 2,
            mean_us: 12.5,
            p50_us: 10.0,
            p90_us: 20.0,
            p95_us: 30.5,
            p99_us: 99.25,
            models: vec![
                (
                    "default".to_string(),
                    ModelStatsReply {
                        served: 12,
                        p50_us: 10.0,
                        p95_us: 30.5,
                        p99_us: 99.25,
                        generation: 3,
                        last_update: 1_700_000_000,
                    },
                ),
                (
                    "other".to_string(),
                    ModelStatsReply {
                        served: 0,
                        p50_us: 0.0,
                        p95_us: 0.0,
                        p99_us: 0.0,
                        generation: 1,
                        last_update: 0,
                    },
                ),
            ],
        };
        let resp = Response::Stats(s);
        roundtrip_resp(&resp).unwrap();
        // legacy clients pluck these fields from the flat object
        let line = resp.to_line();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("served").and_then(Json::as_usize), Some(12));
        assert_eq!(j.get("p95_us").and_then(Json::as_f64), Some(30.5));
        let per_model = j
            .get("models")
            .and_then(|m| m.get("default"))
            .and_then(|m| m.get("served"))
            .and_then(Json::as_usize);
        assert_eq!(per_model, Some(12));
        // the online-update freshness fields ride in the same per-model map
        let generation = j
            .get("models")
            .and_then(|m| m.get("default"))
            .and_then(|m| m.get("generation"))
            .and_then(Json::as_usize);
        assert_eq!(generation, Some(3));
    }

    #[test]
    fn legacy_request_lines_still_parse() {
        // hand-written pre-proto client lines (whitespace, string "ok"
        // replies, optional model routing) must keep working verbatim
        let r = Request::parse("{\"features\": [1.0, -2.5, 3e-2]}").unwrap();
        assert_eq!(
            r,
            Request::Predict { features: vec![1.0, -2.5, 3e-2], model: None, var: false }
        );
        let r = Request::parse("{\"batch\": [[1, 2], [3, 4]], \"model\": \"m\"}").unwrap();
        assert!(matches!(r, Request::Batch { ref rows, ref model, var: false }
            if rows.len() == 2 && model.as_deref() == Some("m")));
        let r = Request::parse("{\"sparse\": [[0, 1.5], [7, -2.0]]}").unwrap();
        assert_eq!(
            r,
            Request::Sparse { pairs: vec![(0, 1.5), (7, -2.0)], model: None }
        );
        assert_eq!(Request::parse("{\"cmd\": \"stats\"}").unwrap(), Request::Stats);
        assert_eq!(Request::parse("{\"cmd\": \"shutdown\"}").unwrap(), Request::Shutdown);
        let r = Request::parse("{\"cmd\": \"reload\", \"model\": \"m\", \"path\": \"c\"}")
            .unwrap();
        assert_eq!(
            r,
            Request::Reload { model: Some("m".to_string()), path: "c".to_string() }
        );
        let ok = Response::parse("{\"ok\":\"true\",\"model\":\"m\"}").unwrap();
        assert_eq!(ok, Response::Ok { model: Some("m".to_string()) });
    }

    #[test]
    fn malformed_requests_keep_the_historic_error_strings() {
        let err = |line: &str| Request::parse(line).unwrap_err();
        assert_eq!(err("{}"), "need \"features\", \"batch\", or \"cmd\"");
        assert_eq!(
            err("{\"features\": \"x\"}"),
            "\"features\" must be an array of numbers"
        );
        assert_eq!(
            err("{\"batch\": []}"),
            "\"batch\" must contain at least one row"
        );
        assert_eq!(
            err("{\"batch\": [17]}"),
            "batch row 0 must be an array of numbers"
        );
        assert_eq!(err("{\"cmd\": \"nope\"}"), "unknown cmd \"nope\"");
        assert_eq!(err("{\"cmd\": \"reload\"}"), "reload needs \"path\"");
        assert_eq!(
            err("{\"sparse\": [[-1, 2.0]]}"),
            "sparse entry 0: index must be a non-negative integer"
        );
        assert_eq!(
            err("{\"sparse\": [[0.5, 2.0]]}"),
            "sparse entry 0: index must be a non-negative integer"
        );
        assert_eq!(
            err("{\"sparse\": [\"x\"]}"),
            "sparse entry 0 must be an [index, value] pair"
        );
        assert_eq!(
            err("{\"sparse\": [[0, \"x\"]]}"),
            "sparse entry 0: value must be a number"
        );
        assert!(Request::parse("not json").is_err());
    }

    #[test]
    fn var_and_append_forms_parse_and_roundtrip() {
        // "var": true opts in; absent or false stays a plain predict, so
        // legacy clients never see a "var" field in serialized lines
        let r = Request::parse("{\"features\": [1.5], \"var\": true}").unwrap();
        assert_eq!(
            r,
            Request::Predict { features: vec![1.5], model: None, var: true }
        );
        assert!(r.to_line().contains("\"var\":true"));
        let r = Request::parse("{\"features\": [1.5], \"var\": false}").unwrap();
        assert!(matches!(r, Request::Predict { var: false, .. }));
        assert!(!r.to_line().contains("var"));
        let r = Request::parse(
            "{\"cmd\": \"append\", \"rows\": [[1, 2], [3, 4]], \"targets\": [0.5, -1.5]}",
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Append {
                model: None,
                rows: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
                targets: vec![0.5, -1.5],
            }
        );
        // reply forms: pred+var on one line, appended ack with nullable
        // cold_iters — all bit-exact through the wire
        roundtrip_resp(&Response::PredVar { pred: 1.0 + f64::EPSILON, var: 5e-324 }).unwrap();
        roundtrip_resp(&Response::Appended {
            appended: 7,
            n: 107,
            generation: 2,
            last_update: 1_723_000_000,
            warm_iters: 9,
            cold_iters: None,
        })
        .unwrap();
        let parsed = Response::parse(
            "{\"appended\":7,\"n\":107,\"generation\":2,\"last_update\":0,\"warm_iters\":9,\"cold_iters\":31}",
        )
        .unwrap();
        assert!(matches!(parsed, Response::Appended { cold_iters: Some(31), .. }));
    }

    #[test]
    fn malformed_append_and_var_fields_error_cleanly() {
        let err = |line: &str| Request::parse(line).unwrap_err();
        assert_eq!(
            err("{\"cmd\": \"append\"}"),
            "\"rows\" must be an array of feature rows"
        );
        assert_eq!(
            err("{\"cmd\": \"append\", \"rows\": [[1]], \"targets\": \"x\"}"),
            "\"targets\" must be an array of numbers"
        );
        assert_eq!(
            err("{\"cmd\": \"append\", \"rows\": [], \"targets\": []}"),
            "append needs at least one row"
        );
        assert_eq!(
            err("{\"cmd\": \"append\", \"rows\": [[1], [2]], \"targets\": [0.5]}"),
            "append has 2 rows but 1 targets"
        );
        assert_eq!(
            err("{\"cmd\": \"shard-append\"}"),
            "\"x\" must be an array of numbers"
        );
        assert_eq!(
            err("{\"cmd\": \"shard-cross\", \"row\": \"x\"}"),
            "\"row\" must be an array of numbers"
        );
        assert_eq!(
            Response::parse("{\"pred\": 1.0, \"var\": \"big\"}").unwrap_err(),
            "\"var\" must be a number"
        );
        assert_eq!(
            Response::parse("{\"appended\": 1, \"n\": 2, \"warm_iters\": 3}").unwrap_err(),
            "\"generation\" must be a non-negative integer"
        );
        assert_eq!(
            Response::parse("{\"cross_kxx\": [1.0], \"cross_blocks\": [[1.0], [2.0]]}")
                .unwrap_err(),
            "cross reply has 1 kxx entries but 2 blocks"
        );
    }

    #[test]
    fn extreme_f64_values_cross_the_wire_bit_exactly() {
        for v in [
            f64::MIN_POSITIVE,
            f64::MAX,
            -f64::MAX,
            5e-324, // smallest subnormal
            1.0 + f64::EPSILON,
            -0.0,
            std::f64::consts::PI,
        ] {
            let line = Request::ShardMatvec { beta: vec![v] }.to_line();
            match Request::parse(&line).unwrap() {
                Request::ShardMatvec { beta } => {
                    assert_eq!(beta[0].to_bits(), v.to_bits(), "{v:e} via {line}")
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
