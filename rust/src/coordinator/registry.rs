//! Named model registry for the serving tier: multiple checkpoints served
//! side by side, routed by the request's optional `"model"` field, with
//! atomic hot-reload.
//!
//! Swapping a model is one `Arc` store under a write lock: in-flight
//! requests keep the `Arc` they already resolved (they finish on the old
//! model), new requests see the new one, and no connection is dropped.
//! Per-model serving stats live beside the models and survive swaps, so a
//! hot-reload does not reset a model's served count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{SystemTime, UNIX_EPOCH};

use super::TrainedModel;
use crate::api::KrrError;
use crate::metrics::{Counter, LatencyHistogram};
use crate::online::OnlineTrainer;

/// Name a request routes to when it carries no `"model"` field and more
/// than one model is registered.
pub const DEFAULT_MODEL: &str = "default";

/// Per-model serving counters (persist across hot-reloads of the model).
pub struct ModelStats {
    /// Predictions served (rows, not requests — a batch of 8 counts 8).
    pub served: Counter,
    pub latency: LatencyHistogram,
    /// Monotonic model version: 1 when the slot is first registered,
    /// +1 on every swap into the slot (hot-reload or online update) — an
    /// operator-visible freshness signal surfaced in the `stats` reply.
    pub generation: Counter,
    /// Unix seconds of the most recent swap into this slot (0 = never).
    pub last_update: AtomicU64,
}

impl ModelStats {
    fn new() -> ModelStats {
        ModelStats {
            served: Counter::default(),
            latency: LatencyHistogram::new(4096),
            generation: Counter::default(),
            last_update: AtomicU64::new(0),
        }
    }

    /// Record a model swap into the slot (registration, hot-reload, or
    /// online update): bump the generation and stamp the wall clock.
    fn bump(&self) {
        self.generation.add(1);
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        self.last_update.store(now, Ordering::Relaxed);
    }
}

/// Checkpoint loader the `reload` protocol command calls: path → servable
/// model. Supplied by the host (it knows the training dataset a
/// checkpoint rebuilds against); without one, `reload` is refused.
pub type ModelLoader = dyn Fn(&str) -> Result<Arc<TrainedModel>, KrrError> + Send + Sync;

/// One registry slot: the servable model plus its persistent stats (the
/// stats `Arc` survives model swaps, so hot-reloads don't reset counts).
struct Entry {
    model: Arc<TrainedModel>,
    stats: Arc<ModelStats>,
    /// Online-update handle for the slot, when the host attached one.
    /// Appends serialize under the trainer's mutex; the re-solved model
    /// swaps in through the same [`ModelRegistry::insert`] path as a
    /// hot-reload, so the handle (like the stats) survives swaps.
    online: Option<Arc<Mutex<OnlineTrainer>>>,
}

/// Thread-safe name → model map with optional checkpoint loader.
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Entry>>,
    loader: Option<Box<ModelLoader>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        ModelRegistry::new()
    }
}

impl ModelRegistry {
    /// Empty registry without a checkpoint loader (`reload` is refused).
    pub fn new() -> ModelRegistry {
        ModelRegistry { models: RwLock::new(BTreeMap::new()), loader: None }
    }

    /// Empty registry whose `reload` command loads checkpoints through
    /// `loader`.
    pub fn with_loader(loader: Box<ModelLoader>) -> ModelRegistry {
        ModelRegistry { loader: Some(loader), ..ModelRegistry::new() }
    }

    /// One-model registry under [`DEFAULT_MODEL`] — the common case for
    /// benches/tests and the train-then-serve CLI path.
    pub fn single(model: Arc<TrainedModel>) -> Arc<ModelRegistry> {
        let r = ModelRegistry::new();
        r.insert(DEFAULT_MODEL, model);
        Arc::new(r)
    }

    /// Register (or atomically replace) `name`. Returns the previous
    /// model, if any. In-flight requests holding the old `Arc` finish on
    /// it; the swap drops no connection and keeps the slot's stats.
    pub fn insert(&self, name: &str, model: Arc<TrainedModel>) -> Option<Arc<TrainedModel>> {
        let mut models = self.models.write().unwrap();
        match models.get_mut(name) {
            Some(entry) => {
                entry.stats.bump();
                Some(std::mem::replace(&mut entry.model, model))
            }
            None => {
                let stats = Arc::new(ModelStats::new());
                stats.bump();
                models.insert(name.to_string(), Entry { model, stats, online: None });
                None
            }
        }
    }

    /// Attach an online-update handle to an already-registered slot, so
    /// `append` requests can route to it. The handle persists across model
    /// swaps (it is the thing *producing* the swaps).
    pub fn attach_online(
        &self,
        name: &str,
        trainer: Arc<Mutex<OnlineTrainer>>,
    ) -> Result<(), KrrError> {
        let mut models = self.models.write().unwrap();
        match models.get_mut(name) {
            Some(entry) => {
                entry.online = Some(trainer);
                Ok(())
            }
            None => Err(KrrError::BadParam(format!(
                "cannot attach online trainer to unregistered model {name:?}"
            ))),
        }
    }

    /// The online-update handle for a registered model, if one is attached.
    pub fn online_for(&self, name: &str) -> Option<Arc<Mutex<OnlineTrainer>>> {
        self.models.read().unwrap().get(name)?.online.as_ref().map(Arc::clone)
    }

    /// Resolve a request's optional model name to
    /// `(name, model, stats)`: an explicit name looks up exactly that
    /// entry; no name routes to the single registered model, or to
    /// [`DEFAULT_MODEL`] when several are registered. One read-lock
    /// acquisition, two `Arc` clones, and one small name allocation —
    /// this sits on the per-request hot path.
    #[allow(clippy::type_complexity)]
    pub fn resolve(
        &self,
        name: Option<&str>,
    ) -> Option<(String, Arc<TrainedModel>, Arc<ModelStats>)> {
        let models = self.models.read().unwrap();
        let (n, e) = match name {
            Some(n) => (n, models.get(n)?),
            None => {
                if models.len() == 1 {
                    let (n, e) = models.iter().next().unwrap();
                    (n.as_str(), e)
                } else {
                    (DEFAULT_MODEL, models.get(DEFAULT_MODEL)?)
                }
            }
        };
        Some((n.to_string(), Arc::clone(&e.model), Arc::clone(&e.stats)))
    }

    /// The persistent stats slot for a registered model.
    pub fn stats_for(&self, name: &str) -> Option<Arc<ModelStats>> {
        self.models.read().unwrap().get(name).map(|e| Arc::clone(&e.stats))
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }

    /// Hot-reload `name` from a checkpoint at `path` through the
    /// configured loader. Only names that are already registered can be
    /// reloaded — a typo'd name must fail loudly, not silently grow the
    /// registry while stale traffic keeps hitting the old model (and the
    /// check runs before the expensive O(dn·m) checkpoint rebuild). The
    /// load happens outside the registry lock; only the final pointer
    /// swap serializes with readers.
    pub fn reload(&self, name: &str, path: &str) -> Result<(), KrrError> {
        let loader = self.loader.as_ref().ok_or_else(|| {
            KrrError::BadParam("reload unavailable: server started without a model loader".into())
        })?;
        if !self.models.read().unwrap().contains_key(name) {
            return Err(KrrError::BadParam(format!(
                "reload of unregistered model {name:?} (serving: {})",
                self.names().join(", ")
            )));
        }
        let model = loader(path)?;
        self.insert(name, model);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::MethodSpec;
    use crate::config::KrrConfig;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;

    fn tiny_model(budget: usize) -> Arc<TrainedModel> {
        let mut ds = synthetic_by_name("wine", Some(120), 1).unwrap();
        ds.standardize();
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget,
            scale: 3.0,
            ..Default::default()
        };
        Arc::new(Trainer::new(cfg).train(&ds).unwrap())
    }

    #[test]
    fn resolve_routes_by_name_and_defaults() {
        let a = tiny_model(4);
        let b = tiny_model(8);
        let r = ModelRegistry::new();
        assert!(r.resolve(None).is_none());
        r.insert("a", a.clone());
        // single model: no name needed, whatever it is called
        let (name, m, _) = r.resolve(None).unwrap();
        assert_eq!(name, "a");
        assert!(Arc::ptr_eq(&m, &a));
        r.insert(DEFAULT_MODEL, b.clone());
        // several models: bare requests go to "default", names still work
        let (name, m, _) = r.resolve(None).unwrap();
        assert_eq!(name, DEFAULT_MODEL);
        assert!(Arc::ptr_eq(&m, &b));
        assert!(Arc::ptr_eq(&r.resolve(Some("a")).unwrap().1, &a));
        assert!(r.resolve(Some("missing")).is_none());
        assert!(r.stats_for("missing").is_none());
        assert_eq!(r.names(), vec!["a".to_string(), DEFAULT_MODEL.to_string()]);
    }

    #[test]
    fn insert_swaps_atomically_and_stats_persist() {
        let v1 = tiny_model(4);
        let v2 = tiny_model(8);
        let r = ModelRegistry::new();
        r.insert(DEFAULT_MODEL, v1.clone());
        r.stats_for(DEFAULT_MODEL).unwrap().served.add(5);
        let prev = r.insert(DEFAULT_MODEL, v2.clone()).unwrap();
        assert!(Arc::ptr_eq(&prev, &v1));
        assert!(Arc::ptr_eq(&r.resolve(None).unwrap().1, &v2));
        // the old handle still predicts — in-flight requests are safe
        let q = vec![0.0f32; prev.dim()];
        assert_eq!(prev.predict(&q).len(), 1);
        // served count survived the swap (the slot's stats Arc is kept)
        assert_eq!(r.stats_for(DEFAULT_MODEL).unwrap().served.get(), 5);
    }

    #[test]
    fn reload_without_loader_is_refused() {
        let r = ModelRegistry::new();
        let err = r.reload(DEFAULT_MODEL, "/nonexistent").unwrap_err();
        assert!(matches!(err, KrrError::BadParam(_)), "{err}");
    }

    #[test]
    fn reload_through_loader_swaps_the_model() {
        let v2 = tiny_model(8);
        let v2c = v2.clone();
        let r = ModelRegistry::with_loader(Box::new(move |path: &str| {
            assert_eq!(path, "ckpt-v2");
            Ok(v2c.clone())
        }));
        r.insert(DEFAULT_MODEL, tiny_model(4));
        r.reload(DEFAULT_MODEL, "ckpt-v2").unwrap();
        assert!(Arc::ptr_eq(&r.resolve(None).unwrap().1, &v2));
        // a typo'd name errors (before the loader runs) instead of
        // silently registering a new entry
        let err = r.reload("defaultt", "ckpt-v2").unwrap_err();
        assert!(matches!(err, KrrError::BadParam(_)), "{err}");
        assert_eq!(r.names(), vec![DEFAULT_MODEL.to_string()]);
    }
}
