//! TCP JSON-lines prediction server (the request path).
//!
//! Protocol (one JSON object per line):
//!   → {"features": [f1, ...], "model": "m"?}  ← {"pred": 1.234} | {"error": "..."}
//!   → {"batch": [[...], ...], "model": "m"?}  ← one {"pred": ...} line per row, in order
//!   → {"sparse": [[idx, val], ...], "model": "m"?}  ← {"pred": ...}  (one CSR row;
//!       omitted indices are 0, duplicate indices keep the last value)
//!   → {"features": [...], "var": true}        ← {"pred": ..., "var": ...}  (posterior
//!       variance per row; also on "batch" — errors if the model has no estimator)
//!   → {"cmd": "append", "rows": [[...], ...], "targets": [...], "model": "m"?}
//!                                             ← {"appended": ..., "n": ...,
//!                                                "generation": ..., "last_update": ...,
//!                                                "warm_iters": ..., "cold_iters": ...}
//!       (online update: rows join the model's sketch, a warm-started re-solve
//!       runs, and the result hot-swaps into the slot — needs an attached
//!       [`OnlineTrainer`](crate::online::OnlineTrainer))
//!   → {"cmd": "stats"}                        ← {"served": ..., "rejected": ...,
//!                                                "queue_depth": ..., "workers": ...,
//!                                                p50/p90/p95/p99, "models": {per-model
//!                                                incl. generation/last_update}}
//!   → {"cmd": "reload", "model": "m", "path": "ckpt"}  ← {"ok": true}  (atomic hot swap)
//!   → {"cmd": "shutdown"}                     ← {"ok": true}  (signal-driven, idempotent)
//!
//! Lines parse through the typed wire module
//! ([`proto`](crate::coordinator::proto)): structural validation and the
//! historic error strings live there, shared with the shard-worker loop
//! and the example/test clients; the semantic checks that need server
//! state (feature arity vs the model, `max_batch`, sparse index range)
//! stay here.
//!
//! Every connection gets a reader thread; requests from all connections
//! flow through one bounded queue into the [`WorkerPool`]'s batcher
//! threads, so the serving tier scales with cores the way the training
//! tier does. A full queue sheds load with `{"error":"overloaded"}`
//! instead of queueing unboundedly. Shutdown is signal-driven: the accept
//! loop polls a stop flag (no self-connect poke), connection threads
//! finish the requests they already read, and the pool drains its queue
//! before its workers exit — no accepted request loses its reply. Idle
//! waits (accept retries and quiet-connection reads) back off from
//! [`IDLE_MIN`] to [`IDLE_MAX`] and snap back on activity, so an idle
//! server wakes a few times a second instead of forty — while shutdown
//! latency stays bounded by `IDLE_MAX` + the drain.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::proto::{Request, Response};
use super::{BatchPredict, ModelRegistry, SubmitError, WorkerPool};
use crate::metrics::{Counter, LatencyHistogram};
use crate::util::json::JsonWriter;

/// Shortest idle wait (right after activity): blocked reads/accepts
/// re-check for work and the stop flag this often at first...
const IDLE_MIN: Duration = Duration::from_millis(1);
/// ...then double per empty wait up to this cap. Must stay comfortably
/// below [`SHUTDOWN_GRACE`] so every thread notices a stop signal well
/// within the drain budget.
const IDLE_MAX: Duration = Duration::from_millis(250);

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Most queued requests a worker fuses per cycle, and the cap on rows
    /// a single `{"batch": ...}` request may carry (bounds one request's
    /// share of a worker).
    pub max_batch: usize,
    /// How long a worker waits for stragglers after its first request.
    pub linger: Duration,
    /// Batcher threads sharing the request queue.
    pub workers: usize,
    /// Admission bound: requests queued beyond this are rejected with
    /// `{"error":"overloaded"}`.
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            max_batch: 64,
            linger: Duration::from_micros(500),
            workers: 1,
            queue_depth: 1024,
        }
    }
}

/// Shared serving metrics (global across models; per-model counters live
/// in the registry).
pub struct ServerStats {
    /// Request latency, enqueue → reply (single and batch requests alike).
    pub latency: LatencyHistogram,
    /// Predictions served (rows — a batch of 8 counts 8).
    pub served: Counter,
    /// Requests shed by admission control.
    pub rejected: Counter,
}

/// Run the server until a `shutdown` command arrives. Returns the stats.
///
/// Requests route through `registry` (single model: see
/// [`ModelRegistry::single`]); the feature arity comes from each model's
/// [`Predictor`](crate::sketch::Predictor) handle. `ready` (if given) is
/// signalled with the bound address once listening.
pub fn serve(
    registry: Arc<ModelRegistry>,
    cfg: ServerConfig,
    ready: Option<std::sync::mpsc::Sender<String>>,
) -> std::io::Result<Arc<ServerStats>> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local = listener.local_addr()?.to_string();
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }
    // the accept loop polls: a blocking accept could only be interrupted
    // by the old self-connect poke, which raced real connections
    listener.set_nonblocking(true)?;
    let stats = Arc::new(ServerStats {
        latency: LatencyHistogram::new(4096),
        served: Counter::default(),
        rejected: Counter::default(),
    });
    let stop = Arc::new(AtomicBool::new(false));
    let pool = WorkerPool::spawn(cfg.workers, cfg.queue_depth, cfg.max_batch, cfg.linger);
    let mut conn_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut idle = IDLE_MIN;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                idle = IDLE_MIN;
                // reap connections that already hung up, so a long-lived
                // server doesn't accumulate one JoinHandle per past client
                conn_threads.retain(|t| !t.is_finished());
                let pool = pool.clone();
                let registry = registry.clone();
                let stats = stats.clone();
                let stop2 = stop.clone();
                conn_threads.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, &registry, &pool, &stats, &stop2);
                }));
            }
            // empty accept queue (and persistent accept errors, e.g. fd
            // exhaustion — those must not busy-spin at 100% CPU either):
            // back off while idle, snap back on the next connection
            Err(_) => {
                std::thread::sleep(idle);
                idle = (idle * 2).min(IDLE_MAX);
            }
        }
    }
    // deterministic drain: connection threads finish the requests they
    // already read (their reads poll `stop`), then the pool drains its
    // queue and joins its workers — replies for accepted work all land
    for t in conn_threads {
        let _ = t.join();
    }
    pool.shutdown();
    Ok(stats)
}

/// How long a connection keeps serving after shutdown is signalled, so
/// requests the client already pipelined (buffered kernel-side or
/// user-side) still get replies while a client that streams forever
/// cannot hold the server open.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(1);

/// Cap on how long one reply write may block on a client that has
/// stopped draining its socket.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Read lines off one connection until EOF or server stop. Reads use a
/// timeout so a quiet connection notices shutdown; the timeout starts at
/// [`IDLE_MIN`] and doubles per empty read up to [`IDLE_MAX`], snapping
/// back whenever bytes arrive — a long-lived idle connection costs a few
/// wakeups a second, not forty, while shutdown is still noticed within
/// `IDLE_MAX`. Bytes already received keep being served through a bounded
/// grace window, so requests pipelined before a shutdown lose no replies
/// — but shutdown still completes within `SHUTDOWN_GRACE` even against a
/// client that never stops sending.
fn handle_conn(
    mut stream: TcpStream,
    registry: &ModelRegistry,
    pool: &WorkerPool,
    stats: &ServerStats,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    let mut idle = IDLE_MIN;
    stream.set_read_timeout(Some(idle))?;
    // a client that stops reading must not park this thread in write_all
    // forever (that would outlive the shutdown grace window and hang
    // serve()'s join) — time the write out and drop the connection
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut writer = stream.try_clone()?;
    let mut acc: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 8192];
    let mut stop_deadline: Option<Instant> = None;
    loop {
        // serve every complete line already buffered
        while let Some(nl) = acc.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = acc.drain(..=nl).collect();
            let text = String::from_utf8_lossy(&line);
            let text = text.trim();
            if !text.is_empty() {
                handle_line(text, registry, pool, stats, stop, &mut writer)?;
            }
        }
        if stop.load(Ordering::SeqCst) {
            let deadline = *stop_deadline.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
            if Instant::now() >= deadline {
                return Ok(()); // grace spent: stop even mid-stream
            }
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                // client closed its write side; a final request without a
                // trailing newline still deserves its reply
                let text = String::from_utf8_lossy(&acc);
                let text = text.trim();
                if !text.is_empty() {
                    handle_line(text, registry, pool, stats, stop, &mut writer)?;
                }
                return Ok(());
            }
            Ok(n) => {
                acc.extend_from_slice(&tmp[..n]);
                if idle > IDLE_MIN {
                    idle = IDLE_MIN;
                    stream.set_read_timeout(Some(idle))?;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // an idle gap after the stop signal means the pipeline is
                // drained — no need to sit out the rest of the grace window
                if stop.load(Ordering::SeqCst) {
                    return Ok(());
                }
                if idle < IDLE_MAX {
                    idle = (idle * 2).min(IDLE_MAX);
                    stream.set_read_timeout(Some(idle))?;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

fn err_json(msg: &str) -> String {
    JsonWriter::object().field_str("error", msg).finish()
}

/// Parse (via the typed wire module) and answer one request line (always
/// exactly ≥1 reply line).
fn handle_line(
    line: &str,
    registry: &ModelRegistry,
    pool: &WorkerPool,
    stats: &ServerStats,
    stop: &AtomicBool,
    writer: &mut TcpStream,
) -> std::io::Result<()> {
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err(e) => {
            writeln!(writer, "{}", err_json(&e))?;
            return Ok(());
        }
    };
    match &req {
        Request::Stats => {
            writeln!(writer, "{}", stats_json(registry, pool, stats))?;
            return Ok(());
        }
        Request::Shutdown => {
            // idempotent: flipping an already-set flag is harmless
            stop.store(true, Ordering::SeqCst);
            writeln!(writer, "{}", Response::Ok { model: None }.to_line())?;
            return Ok(());
        }
        Request::Reload { model, path } => {
            let name = model.as_deref().unwrap_or(super::DEFAULT_MODEL);
            let reply = match registry.reload(name, path) {
                Ok(()) => Response::Ok { model: Some(name.to_string()) }.to_line(),
                Err(e) => err_json(&e.to_string()),
            };
            writeln!(writer, "{reply}")?;
            return Ok(());
        }
        Request::Append { model, rows, targets } => {
            let name = model.as_deref().unwrap_or(super::DEFAULT_MODEL);
            let reply = match append_rows(registry, name, rows, targets) {
                Ok(resp) => resp.to_line(),
                Err(msg) => err_json(&msg),
            };
            writeln!(writer, "{reply}")?;
            return Ok(());
        }
        Request::ShardBuild(_)
        | Request::ShardMatvec { .. }
        | Request::ShardLoadBeta { .. }
        | Request::ShardPredict { .. }
        | Request::ShardAppend { .. }
        | Request::ShardCross { .. }
        | Request::ShardInfo => {
            writeln!(
                writer,
                "{}",
                err_json("shard-* ops go to `wlsh-krr shard-worker` processes, not the serving endpoint")
            )?;
            return Ok(());
        }
        Request::Predict { .. } | Request::Batch { .. } | Request::Sparse { .. } => {}
    }
    // prediction path: resolve the model first (its dim validates arity)
    let model_name = match &req {
        Request::Predict { model, .. }
        | Request::Batch { model, .. }
        | Request::Sparse { model, .. } => model.as_deref(),
        _ => unreachable!("non-prediction requests replied above"),
    };
    let (resolved_name, model, mstats) = match registry.resolve(model_name) {
        Some(v) => v,
        None => {
            let msg = match model_name {
                Some(m) => format!("unknown model {m:?}"),
                None if registry.is_empty() => "no models registered".to_string(),
                None => "no model named \"default\" among several registered".to_string(),
            };
            writeln!(writer, "{}", err_json(&msg))?;
            return Ok(());
        }
    };
    let d = model.dim();
    let handle: Arc<dyn BatchPredict> = model;
    let want_var = matches!(
        req,
        Request::Predict { var: true, .. } | Request::Batch { var: true, .. }
    );
    let t = Instant::now();
    let (outcome, nrows) = match req {
        Request::Sparse { pairs, .. } => match sparse_csr(&pairs, d) {
            Ok((indptr, indices, values)) => {
                (pool.predict_sparse(handle, d, indptr, indices, values).map(|p| (p, None)), 1)
            }
            Err(msg) => {
                writeln!(writer, "{}", err_json(&msg))?;
                return Ok(());
            }
        },
        Request::Predict { features, .. } => {
            if features.len() != d {
                writeln!(
                    writer,
                    "{}",
                    err_json(&format!("expected {d} features, got {}", features.len()))
                )?;
                return Ok(());
            }
            if want_var {
                (pool.predict_with_var(handle, features, 1), 1)
            } else {
                (pool.predict(handle, features, 1).map(|p| (p, None)), 1)
            }
        }
        Request::Batch { rows, .. } => match flatten_batch(rows, d, pool.max_batch()) {
            Ok((flat, nrows)) => {
                if want_var {
                    (pool.predict_with_var(handle, flat, nrows), nrows)
                } else {
                    (pool.predict(handle, flat, nrows).map(|p| (p, None)), nrows)
                }
            }
            Err(msg) => {
                writeln!(writer, "{}", err_json(&msg))?;
                return Ok(());
            }
        },
        _ => unreachable!("non-prediction requests replied above"),
    };
    match outcome {
        Ok((preds, vars)) => {
            if want_var && vars.is_none() {
                writeln!(
                    writer,
                    "{}",
                    err_json(&format!("model {resolved_name:?} exposes no variance estimate"))
                )?;
                return Ok(());
            }
            let secs = t.elapsed().as_secs_f64();
            stats.latency.record(secs);
            stats.served.add(nrows as u64);
            mstats.latency.record(secs);
            mstats.served.add(nrows as u64);
            // one buffered write per request, not one syscall per row
            let mut reply = String::with_capacity(preds.len() * 24);
            match &vars {
                Some(vs) => {
                    for (p, v) in preds.iter().zip(vs) {
                        reply.push_str(&Response::PredVar { pred: *p, var: *v }.to_line());
                        reply.push('\n');
                    }
                }
                None => {
                    for p in &preds {
                        reply.push_str(&JsonWriter::object().field_f64("pred", *p).finish());
                        reply.push('\n');
                    }
                }
            }
            writer.write_all(reply.as_bytes())?;
        }
        Err(e) => {
            if e == SubmitError::Overloaded {
                stats.rejected.add(1);
            }
            writeln!(writer, "{}", err_json(&e.to_string()))?;
        }
    }
    Ok(())
}

/// Serve one `append` request: route to the slot's
/// [`OnlineTrainer`](crate::online::OnlineTrainer), run the incremental
/// sketch update + warm-started re-solve, and hot-swap the re-solved
/// model into the registry — all under the trainer's mutex, so
/// concurrent appends publish in append order and the registry never
/// regresses to a model missing rows a later append saw. In-flight
/// predictions keep the `Arc` they already resolved; no connection
/// drops.
fn append_rows(
    registry: &ModelRegistry,
    name: &str,
    rows: &[Vec<f32>],
    targets: &[f64],
) -> Result<Response, String> {
    let trainer = registry
        .online_for(name)
        .ok_or_else(|| format!("model {name:?} has no online trainer attached"))?;
    // the wire parser guarantees rows and targets are non-empty and of
    // equal length; per-row arity is the trainer's check
    let mut flat = Vec::with_capacity(rows.len() * rows.first().map_or(0, Vec::len));
    for r in rows {
        flat.extend_from_slice(r);
    }
    let mut t = trainer.lock().unwrap();
    let (report, model) = t.append(&flat, targets).map_err(|e| e.to_string())?;
    registry.insert(name, model);
    drop(t);
    let stats = registry
        .stats_for(name)
        .ok_or_else(|| format!("model {name:?} vanished during append"))?;
    Ok(Response::Appended {
        appended: report.appended,
        n: report.n,
        generation: stats.generation.get() as usize,
        last_update: stats.last_update.load(Ordering::Relaxed) as usize,
        warm_iters: report.warm_iters,
        cold_iters: report.cold_iters,
    })
}

/// Flatten a typed batch (shape already validated by the wire parser)
/// into the pool's row-major buffer, applying the server-side semantic
/// checks: per-row arity against the model's `d`, and the `max_rows` cap
/// (the pool's batch bound caps one request's share of a worker). A
/// malformed request gets one error reply for the whole request.
fn flatten_batch(
    rows: Vec<Vec<f32>>,
    d: usize,
    max_rows: usize,
) -> Result<(Vec<f32>, usize), String> {
    if rows.len() > max_rows {
        return Err(format!(
            "batch of {} rows exceeds the server's max_batch of {max_rows}; split it",
            rows.len()
        ));
    }
    let nrows = rows.len();
    let mut flat = Vec::with_capacity(nrows * d);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != d {
            return Err(format!("batch row {i}: expected {d} features, got {}", row.len()));
        }
        flat.extend_from_slice(row);
    }
    Ok((flat, nrows))
}

/// Turn typed `[index, value]` pairs (shape and integer-ness already
/// validated by the wire parser) into one CSR query row: range-check
/// indices against the model's `d`, then sort and deduplicate (last value
/// wins) to the loader's CSR invariant. An empty pair list is a valid
/// all-zeros row.
fn sparse_csr(
    pairs: &[(usize, f64)],
    d: usize,
) -> Result<(Vec<usize>, Vec<u32>, Vec<f32>), String> {
    let mut entries: Vec<(u32, f32)> = Vec::with_capacity(pairs.len());
    for (i, &(idx, val)) in pairs.iter().enumerate() {
        if idx >= d {
            return Err(format!("sparse entry {i}: index {idx} out of range for {d} features"));
        }
        entries.push((idx as u32, val as f32));
    }
    // ascending unique indices; the stable sort keeps arrival order among
    // duplicates, so last-wins matches a dense scatter's overwrite
    entries.sort_by_key(|e| e.0);
    let mut indices: Vec<u32> = Vec::with_capacity(entries.len());
    let mut values: Vec<f32> = Vec::with_capacity(entries.len());
    for (j, v) in entries {
        if indices.last() == Some(&j) {
            *values.last_mut().expect("non-empty") = v;
        } else {
            indices.push(j);
            values.push(v);
        }
    }
    Ok((vec![0, indices.len()], indices, values))
}

/// The `stats` reply: global counters + latency quantiles, queue state,
/// and a nested per-model block.
fn stats_json(registry: &ModelRegistry, pool: &WorkerPool, stats: &ServerStats) -> String {
    let s = stats.latency.summary();
    let mut models = JsonWriter::object();
    for name in registry.names() {
        let ms = match registry.stats_for(&name) {
            Some(ms) => ms,
            None => continue, // removed between names() and here
        };
        let m = ms.latency.summary();
        models = models.field_raw(
            &name,
            &JsonWriter::object()
                .field_usize("served", ms.served.get() as usize)
                .field_usize("generation", ms.generation.get() as usize)
                .field_usize("last_update", ms.last_update.load(Ordering::Relaxed) as usize)
                .field_f64("p50_us", m.p50 * 1e6)
                .field_f64("p95_us", m.p95 * 1e6)
                .field_f64("p99_us", m.p99 * 1e6)
                .finish(),
        );
    }
    JsonWriter::object()
        .field_usize("served", stats.served.get() as usize)
        .field_usize("rejected", stats.rejected.get() as usize)
        .field_usize("queue_depth", pool.queue_len())
        .field_usize("workers", pool.workers())
        .field_f64("mean_us", stats.latency.mean() * 1e6)
        .field_f64("p50_us", s.p50 * 1e6)
        .field_f64("p90_us", s.p90 * 1e6)
        .field_f64("p95_us", s.p95 * 1e6)
        .field_f64("p99_us", s.p99 * 1e6)
        .field_raw("models", &models.finish())
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    use crate::config::KrrConfig;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;
    use crate::util::json::Json;

    fn small_model() -> (Arc<super::super::TrainedModel>, usize, Vec<f32>, Vec<f64>) {
        let mut ds = synthetic_by_name("wine", Some(150), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(120, 2);
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 16,
            scale: 3.0,
            ..Default::default()
        };
        let model = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
        let expected = model.predict(&te.x[..te.d * 3]);
        (model, tr.d, te.x[..te.d * 3].to_vec(), expected)
    }

    fn start(
        registry: Arc<ModelRegistry>,
        workers: usize,
    ) -> (String, std::thread::JoinHandle<Arc<ServerStats>>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let cfg =
            ServerConfig { addr: "127.0.0.1:0".into(), workers, ..Default::default() };
        let handle = std::thread::spawn(move || serve(registry, cfg, Some(tx)).unwrap());
        (rx.recv().unwrap(), handle)
    }

    #[test]
    fn end_to_end_roundtrip() {
        let (model, d, queries, expected) = small_model();
        assert_eq!(model.dim(), d);
        let (addr, handle) = start(ModelRegistry::single(model), 2);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for (qi, want) in expected.iter().enumerate() {
            let feats: Vec<String> = queries[qi * d..(qi + 1) * d]
                .iter()
                .map(|v| format!("{v}"))
                .collect();
            writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            let got = resp.get("pred").and_then(Json::as_f64).unwrap();
            assert!((got - want).abs() < 1e-6, "query {qi}: {got} vs {want}");
        }
        // stats then shutdown
        writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("served").and_then(Json::as_usize).unwrap(), expected.len());
        assert_eq!(resp.get("rejected").and_then(Json::as_usize).unwrap(), 0);
        assert_eq!(resp.get("workers").and_then(Json::as_usize).unwrap(), 2);
        let p95 = resp.get("p95_us").and_then(Json::as_f64).unwrap();
        assert!(p95 >= 0.0);
        let per_model = resp
            .get("models")
            .and_then(|m| m.get("default"))
            .and_then(|m| m.get("served"))
            .and_then(Json::as_usize)
            .unwrap();
        assert_eq!(per_model, expected.len());
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("ok"), "{line2}");
        handle.join().unwrap();
    }

    #[test]
    fn batch_requests_reply_one_line_per_row() {
        let (model, d, queries, expected) = small_model();
        let (addr, handle) = start(ModelRegistry::single(model), 1);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let rows: Vec<String> = (0..expected.len())
            .map(|qi| {
                let feats: Vec<String> =
                    queries[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
                format!("[{}]", feats.join(","))
            })
            .collect();
        writeln!(conn, "{{\"batch\": [{}]}}", rows.join(",")).unwrap();
        for (qi, want) in expected.iter().enumerate() {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let got = Json::parse(&line).unwrap().get("pred").and_then(Json::as_f64).unwrap();
            assert!((got - want).abs() < 1e-6, "row {qi}: {got} vs {want}");
        }
        // per-row served accounting
        writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let served = Json::parse(&line).unwrap().get("served").and_then(Json::as_usize).unwrap();
        assert_eq!(served, expected.len());
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn server_reports_errors() {
        let (model, _d, _, _) = small_model();
        let (addr, handle) = start(ModelRegistry::single(model), 1);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut expect_error = |req: &str| {
            writeln!(conn, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("error"), "{req} → {line}");
        };
        expect_error("{\"features\": [1.0]}"); // wrong arity
        expect_error("not json");
        expect_error("{\"batch\": []}");
        expect_error("{\"batch\": [[1.0], \"x\"]}");
        // a batch beyond max_batch is rejected whole, before any work
        let big: Vec<String> = (0..65).map(|_| "[1.0]".to_string()).collect();
        expect_error(&format!("{{\"batch\": [{}]}}", big.join(",")));
        expect_error("{\"features\": [1,2,3], \"model\": \"nope\"}"); // unknown model
        expect_error("{\"cmd\": \"reload\", \"path\": \"x\"}"); // no loader configured
        expect_error("{\"cmd\": \"nope\"}");
        // sparse request malformations — a negative or fractional index
        // must be an error, not a silently saturated huge/zero index
        expect_error("{\"sparse\": [[-1, 2.0]]}");
        expect_error("{\"sparse\": [[0.5, 2.0]]}");
        expect_error("{\"sparse\": [[99999, 2.0]]}"); // out of range
        expect_error("{\"sparse\": \"x\"}");
        expect_error("{\"sparse\": [[1.0]]}"); // not a pair
        expect_error("{\"sparse\": [[0, \"x\"]]}"); // non-numeric value
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line3 = String::new();
        reader.read_line(&mut line3).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn sparse_requests_roundtrip_bit_identically_to_dense() {
        let (model, d, queries, expected) = small_model();
        let (addr, handle) = start(ModelRegistry::single(model), 1);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            writeln!(conn, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line)
                .unwrap_or_else(|e| panic!("{req} → {line}: {e}"))
                .get("pred")
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("{req} → {line}"))
        };
        for (qi, want) in expected.iter().enumerate() {
            let row = &queries[qi * d..(qi + 1) * d];
            // full row as pairs — and again in reverse order with a stale
            // duplicate first (last value wins), exercising sort + dedupe
            let pairs: Vec<String> =
                row.iter().enumerate().map(|(j, v)| format!("[{j},{v}]")).collect();
            let mut rev = pairs.clone();
            rev.reverse();
            rev.insert(0, format!("[0,{}]", row[0] as f64 + 7.0));
            rev.push(format!("[0,{}]", row[0]));
            for req in
                [format!("{{\"sparse\": [{}]}}", pairs.join(",")),
                 format!("{{\"sparse\": [{}]}}", rev.join(","))]
            {
                let got = ask(&mut conn, &mut reader, &req);
                assert!((got - want).abs() < 1e-12, "query {qi}: {got} vs {want}");
            }
        }
        // an empty pair list is a valid all-zeros row
        let got = ask(&mut conn, &mut reader, "{\"sparse\": []}");
        assert!(got.is_finite());
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_latency_stays_bounded_after_idle() {
        // after a long quiet stretch every wait in the server sits at its
        // deepest backoff (IDLE_MAX for both the accept loop and this
        // connection's reads) — a shutdown must still complete promptly,
        // not wait out some accumulated poll schedule
        let (model, _d, _, _) = small_model();
        let (addr, handle) = start(ModelRegistry::single(model), 1);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        std::thread::sleep(IDLE_MAX * 3); // escalate everything to the cap
        let t = Instant::now();
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("ok"), "{line}");
        drop(reader);
        drop(conn);
        handle.join().unwrap();
        let elapsed = t.elapsed();
        // generous bound for slow CI machines; still far below what any
        // fixed multi-second poll schedule would allow
        assert!(
            elapsed < Duration::from_millis(1500),
            "shutdown after idle took {elapsed:?}"
        );
    }

    #[test]
    fn append_hot_swaps_and_var_lines_flow_on_a_live_connection() {
        let mut ds = synthetic_by_name("wine", Some(160), 1).unwrap();
        ds.standardize();
        let d = ds.d;
        // order-preserving cut: head trains, tail arrives over the wire
        let head = crate::data::Dataset::new(
            "head",
            ds.x[..120 * d].to_vec(),
            ds.y[..120].to_vec(),
            d,
        );
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 16,
            scale: 3.0,
            ..Default::default()
        };
        let online = crate::online::OnlineTrainer::fit(cfg, &head).unwrap();
        let registry = ModelRegistry::single(online.model());
        registry
            .attach_online(
                crate::coordinator::DEFAULT_MODEL,
                Arc::new(std::sync::Mutex::new(online)),
            )
            .unwrap();
        let (addr, handle) = start(registry, 2);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
            writeln!(conn, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap_or_else(|e| panic!("{req} → {line}: {e}"))
        };
        // uncertainty-aware serving: {"var": true} answers pred + var
        let feats: Vec<String> = ds.x[..d].iter().map(|v| format!("{v}")).collect();
        let resp = ask(
            &mut conn,
            &mut reader,
            &format!("{{\"features\": [{}], \"var\": true}}", feats.join(",")),
        );
        let pred = resp.get("pred").and_then(Json::as_f64).unwrap();
        let var = resp.get("var").and_then(Json::as_f64).unwrap();
        assert!(pred.is_finite());
        assert!(var.is_finite() && var >= 0.0, "var {var}");
        // generation starts at 1 and is surfaced in stats
        let stats = ask(&mut conn, &mut reader, "{\"cmd\": \"stats\"}");
        let generation = |stats: &Json| {
            stats
                .get("models")
                .and_then(|m| m.get(crate::coordinator::DEFAULT_MODEL))
                .and_then(|m| m.get("generation"))
                .and_then(Json::as_usize)
                .unwrap()
        };
        assert_eq!(generation(&stats), 1);
        // append the tail over the wire: sketch grows, model hot-swaps
        let rows: Vec<String> = (120..160)
            .map(|i| {
                let r: Vec<String> =
                    ds.x[i * d..(i + 1) * d].iter().map(|v| format!("{v}")).collect();
                format!("[{}]", r.join(","))
            })
            .collect();
        let targets: Vec<String> = ds.y[120..].iter().map(|v| format!("{v}")).collect();
        let resp = ask(
            &mut conn,
            &mut reader,
            &format!(
                "{{\"cmd\": \"append\", \"rows\": [{}], \"targets\": [{}]}}",
                rows.join(","),
                targets.join(",")
            ),
        );
        assert_eq!(resp.get("appended").and_then(Json::as_usize), Some(40), "{resp:?}");
        assert_eq!(resp.get("n").and_then(Json::as_usize), Some(160));
        assert_eq!(resp.get("generation").and_then(Json::as_usize), Some(2));
        assert!(resp.get("warm_iters").and_then(Json::as_usize).is_some());
        assert!(resp.get("cold_iters").and_then(Json::as_usize).is_some());
        // the same connection keeps serving through the swap — and the
        // swapped-in model answers with variance intact
        let resp = ask(
            &mut conn,
            &mut reader,
            &format!("{{\"features\": [{}], \"var\": true}}", feats.join(",")),
        );
        assert!(resp.get("pred").and_then(Json::as_f64).unwrap().is_finite());
        assert!(resp.get("var").and_then(Json::as_f64).unwrap() >= 0.0);
        // append to a slot without a trainer is a clean error
        let resp = ask(
            &mut conn,
            &mut reader,
            "{\"cmd\": \"append\", \"rows\": [[1.0]], \"targets\": [0.5], \"model\": \"nope\"}",
        );
        assert!(resp.get("error").is_some(), "{resp:?}");
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn routes_by_model_name_and_hot_reload_keeps_connection() {
        let (m1, d, queries, want1) = small_model();
        // a different budget gives a genuinely different predictor
        let mut ds = synthetic_by_name("wine", Some(150), 1).unwrap();
        ds.standardize();
        let (tr, _) = ds.split(120, 2);
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            ..Default::default()
        };
        let m2 = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
        let want2 = m2.predict(&queries);
        let registry = ModelRegistry::single(m1);
        registry.insert("alt", m2.clone());
        let reg2 = registry.clone();
        let (addr, handle) = start(registry, 2);
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let feats: Vec<String> = queries[..d].iter().map(|v| format!("{v}")).collect();
        let ask = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, model: &str| {
            writeln!(conn, "{{\"features\": [{}], \"model\": \"{model}\"}}", feats.join(","))
                .unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap().get("pred").and_then(Json::as_f64).unwrap()
        };
        assert!((ask(&mut conn, &mut reader, "default") - want1[0]).abs() < 1e-9);
        assert!((ask(&mut conn, &mut reader, "alt") - want2[0]).abs() < 1e-9);
        // hot-swap "default" → m2 while this connection stays open
        reg2.insert("default", m2);
        assert!((ask(&mut conn, &mut reader, "default") - want2[0]).abs() < 1e-9);
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }
}
