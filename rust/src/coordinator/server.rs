//! TCP JSON-lines prediction server (the request path).
//!
//! Protocol (one JSON object per line):
//!   → {"features": [f1, f2, ...]}
//!   ← {"pred": 1.234}           | {"error": "..."}
//!   → {"cmd": "stats"}          ← {"served": n, "p50_us": ..., ...}
//!   → {"cmd": "shutdown"}       ← {"ok": true}   (stops accepting)
//!
//! Every connection gets a reader thread; requests flow through the
//! [`DynamicBatcher`] so concurrent clients share batch hashing.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{DynamicBatcher, TrainedModel};
use crate::metrics::LatencyHistogram;
use crate::util::json::{Json, JsonWriter};

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub max_batch: usize,
    pub linger: Duration,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            max_batch: 64,
            linger: Duration::from_micros(500),
            workers: 1,
        }
    }
}

/// Shared serving metrics.
pub struct ServerStats {
    pub latency: LatencyHistogram,
}

/// Run the server until a `shutdown` command arrives. Returns the stats.
/// The feature arity comes from the model's
/// [`Predictor`](crate::sketch::Predictor) handle; `ready` (if given) is
/// signalled with the bound address once listening.
pub fn serve(
    model: Arc<TrainedModel>,
    cfg: ServerConfig,
    ready: Option<std::sync::mpsc::Sender<String>>,
) -> std::io::Result<Arc<ServerStats>> {
    let d = model.dim();
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_sock = listener.local_addr()?;
    let local = local_sock.to_string();
    if let Some(tx) = ready {
        let _ = tx.send(local.clone());
    }
    // Address the shutdown self-connect targets: a wildcard bind
    // (0.0.0.0 / ::) is not connectable on every platform, so poke the
    // loopback of the same family instead.
    let mut poke_sock = local_sock;
    if poke_sock.ip().is_unspecified() {
        poke_sock.set_ip(match poke_sock.ip() {
            std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
        });
    }
    let poke_addr = poke_sock.to_string();
    let stats = Arc::new(ServerStats { latency: LatencyHistogram::new(4096) });
    let stop = Arc::new(AtomicBool::new(false));
    let m = model.clone();
    let batcher = Arc::new(DynamicBatcher::spawn(
        d,
        cfg.max_batch,
        cfg.linger,
        move |rows, out| m.predict_into(rows, out),
    ));
    listener.set_nonblocking(false)?;
    let mut conn_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // reap connections that already hung up, so a long-lived server
        // doesn't accumulate one parked JoinHandle per past client
        conn_threads.retain(|t| !t.is_finished());
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let batcher = batcher.clone();
        let stats = stats.clone();
        let stop2 = stop.clone();
        let d2 = d;
        let listen_addr = poke_addr.clone();
        conn_threads.push(std::thread::spawn(move || {
            let _ = handle_conn(stream, d2, &batcher, &stats, &stop2, &listen_addr);
        }));
        // a shutdown handled inside a connection flips `stop`; poke the
        // accept loop by checking after each connection completes quickly
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    for t in conn_threads {
        let _ = t.join();
    }
    Ok(stats)
}

fn handle_conn(
    stream: TcpStream,
    d: usize,
    batcher: &DynamicBatcher,
    stats: &ServerStats,
    stop: &AtomicBool,
    listen_addr: &str,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "stats" => {
                            let (p50, p90, p99) = stats.latency.percentiles();
                            JsonWriter::object()
                                .field_usize("served", stats.latency.count.get() as usize)
                                .field_f64("mean_us", stats.latency.mean() * 1e6)
                                .field_f64("p50_us", p50 * 1e6)
                                .field_f64("p90_us", p90 * 1e6)
                                .field_f64("p99_us", p99 * 1e6)
                                .finish()
                        }
                        "shutdown" => {
                            stop.store(true, Ordering::SeqCst);
                            writeln!(writer, "{}", JsonWriter::object().field_str("ok", "true").finish())?;
                            // one deliberate self-connect to the listener's
                            // own address unblocks the blocking accept loop
                            let _ = TcpStream::connect(listen_addr);
                            return Ok(());
                        }
                        other => JsonWriter::object()
                            .field_str("error", &format!("unknown cmd {other:?}"))
                            .finish(),
                    }
                } else if let Some(f) = req.get("features").and_then(Json::as_f64_vec) {
                    if f.len() != d {
                        JsonWriter::object()
                            .field_str("error", &format!("expected {d} features, got {}", f.len()))
                            .finish()
                    } else {
                        let t = Instant::now();
                        let features: Vec<f32> = f.iter().map(|&v| v as f32).collect();
                        match batcher.predict(features) {
                            Some(pred) => {
                                stats.latency.record(t.elapsed().as_secs_f64());
                                JsonWriter::object().field_f64("pred", pred).finish()
                            }
                            None => JsonWriter::object()
                                .field_str("error", "batcher unavailable")
                                .finish(),
                        }
                    }
                } else {
                    JsonWriter::object()
                        .field_str("error", "need \"features\" or \"cmd\"")
                        .finish()
                }
            }
            Err(e) => JsonWriter::object().field_str("error", &e).finish(),
        };
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KrrConfig;
    use crate::coordinator::Trainer;
    use crate::data::synthetic_by_name;

    fn small_model() -> (Arc<TrainedModel>, usize, Vec<f32>, Vec<f64>) {
        let mut ds = synthetic_by_name("wine", Some(150), 1).unwrap();
        ds.standardize();
        let (tr, te) = ds.split(120, 2);
        let cfg = KrrConfig {
            method: crate::api::MethodSpec::Wlsh,
            budget: 16,
            scale: 3.0,
            ..Default::default()
        };
        let model = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
        let expected = model.predict(&te.x[..te.d * 3]);
        (model, tr.d, te.x[..te.d * 3].to_vec(), expected)
    }

    #[test]
    fn end_to_end_roundtrip() {
        let (model, d, queries, expected) = small_model();
        assert_eq!(model.dim(), d);
        let (tx, rx) = std::sync::mpsc::channel();
        let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
        let handle = std::thread::spawn(move || serve(model, cfg, Some(tx)).unwrap());
        let addr = rx.recv().unwrap();
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for (qi, want) in expected.iter().enumerate() {
            let feats: Vec<String> = queries[qi * d..(qi + 1) * d]
                .iter()
                .map(|v| format!("{v}"))
                .collect();
            writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            let got = resp.get("pred").and_then(Json::as_f64).unwrap();
            assert!((got - want).abs() < 1e-6, "query {qi}: {got} vs {want}");
        }
        // stats then shutdown
        writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        assert_eq!(resp.get("served").and_then(Json::as_usize).unwrap(), expected.len());
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn server_reports_errors() {
        let (model, _d, _, _) = small_model();
        let (tx, rx) = std::sync::mpsc::channel();
        let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
        let handle = std::thread::spawn(move || serve(model, cfg, Some(tx)).unwrap());
        let addr = rx.recv().unwrap();
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.set_nodelay(true).ok();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        writeln!(conn, "{{\"features\": [1.0]}}").unwrap(); // wrong arity
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        writeln!(conn, "not json").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("error"));
        writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut line3 = String::new();
        reader.read_line(&mut line3).unwrap();
        handle.join().unwrap();
    }
}
