//! L3 coordinator — the serving/training framework around the WLSH
//! estimator: a trainer that shards sketch construction across workers and
//! runs the CG solve, a router that fans prediction batches out over
//! worker threads, a worker-pool serving engine (bounded request queue →
//! batcher threads, with admission control), a named model registry with
//! atomic hot-reload, and a TCP JSON-lines prediction server. (std
//! threads + channels; tokio is unavailable in the offline registry —
//! DESIGN.md §5.)

mod batcher;
pub mod checkpoint;
pub mod proto;
mod registry;
mod router;
mod server;
pub mod shard;
mod trainer;

pub use batcher::{BatchItem, BatchPredict, PoolReply, RowBlock, SubmitError, WorkerPool};
pub use registry::{ModelLoader, ModelRegistry, ModelStats, DEFAULT_MODEL};
pub use router::PredictRouter;
pub use server::{serve, ServerConfig, ServerStats};
pub use shard::{run_worker, ShardClient, ShardGroup, ShardPlan, ShardedOperator};
pub use trainer::{TrainReport, TrainedModel, Trainer};
