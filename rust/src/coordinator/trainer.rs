//! Training orchestration: build the requested kernel operator (sharding
//! WLSH instance construction across worker threads), solve the ridge
//! system by CG — optionally preconditioned (Jacobi from the operator
//! diagonal, or rank-r Nyström of the method's target kernel) via the
//! `precond` config knob — and package a servable model.

use std::sync::Arc;
use std::time::Instant;

use crate::config::KrrConfig;
use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::lsh::{IdMode, LshFamily};
use crate::sketch::{
    ExactKernelOp, KrrOperator, NystromSketch, RffSketch, WlshSketch,
};
use crate::solver::{solve_krr, solve_krr_pcg, CgOptions, Preconditioner};
use crate::util::par;
use crate::util::rng::Pcg64;

/// A trained, servable KRR model.
pub struct TrainedModel {
    pub op: Arc<dyn KrrOperator>,
    pub beta: Vec<f64>,
    pub config: KrrConfig,
    pub report: TrainReport,
    /// β-dependent serving state (e.g. WLSH bucket loads, §4.2) —
    /// precomputed once so a prediction costs O(m·d), not O(n·m).
    pub prepared: crate::sketch::PreparedState,
}

impl TrainedModel {
    /// Assemble a model from parts, precomputing the serving state.
    pub fn assemble(
        op: Arc<dyn KrrOperator>,
        beta: Vec<f64>,
        config: KrrConfig,
        report: TrainReport,
    ) -> TrainedModel {
        let prepared = op.prepare(&beta);
        TrainedModel { op, beta, config, report, prepared }
    }
}

/// Timings and solve diagnostics from one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub build_secs: f64,
    pub solve_secs: f64,
    pub cg_iters: usize,
    pub cg_rel_residual: f64,
    pub converged: bool,
    pub operator: String,
    /// Preconditioner the solve actually used ("none" | "jacobi" |
    /// "nystrom") — may differ from the config when a fallback fired.
    pub precond: String,
    pub memory_bytes: usize,
}

impl TrainedModel {
    /// η̃(q) for each query row (uses the prepared serving state).
    pub fn predict(&self, queries: &[f32]) -> Vec<f64> {
        self.op.predict_prepared(queries, &self.beta, &self.prepared)
    }
}

/// Builds operators and runs the solve per a [`KrrConfig`].
pub struct Trainer {
    pub config: KrrConfig,
}

impl Trainer {
    pub fn new(config: KrrConfig) -> Trainer {
        Trainer { config }
    }

    /// Build the kernel operator for the configured method.
    pub fn build_operator(&self, ds: &Dataset) -> Arc<dyn KrrOperator> {
        let c = &self.config;
        match c.method.as_str() {
            "wlsh" => Arc::new(self.build_wlsh_sharded(ds)),
            "rff" => Arc::new(RffSketch::build(&ds.x, ds.n, ds.d, c.budget, c.scale, c.seed)),
            "exact-laplace" => {
                Arc::new(ExactKernelOp::new(&ds.x, ds.n, ds.d, Kernel::laplace(c.scale)))
            }
            "exact-se" => {
                Arc::new(ExactKernelOp::new(&ds.x, ds.n, ds.d, Kernel::squared_exp(c.scale)))
            }
            "exact-matern" => {
                Arc::new(ExactKernelOp::new(&ds.x, ds.n, ds.d, Kernel::matern52(c.scale)))
            }
            "exact-wlsh" => Arc::new(ExactKernelOp::new(
                &ds.x,
                ds.n,
                ds.d,
                Kernel::wlsh(&c.bucket, c.gamma_shape, c.scale),
            )),
            "nystrom" => Arc::new(NystromSketch::build(
                &ds.x,
                ds.n,
                ds.d,
                c.budget.min(ds.n),
                Kernel::squared_exp(c.scale),
                c.seed,
            )),
            other => panic!("unknown method {other:?}"),
        }
    }

    /// WLSH build with the m instances fanned out across `workers` threads
    /// (each instance hashes with its own forked RNG stream, preserving
    /// determinism regardless of worker count).
    fn build_wlsh_sharded(&self, ds: &Dataset) -> WlshSketch {
        let c = &self.config;
        if c.workers <= 1 {
            return WlshSketch::build(
                &ds.x, ds.n, ds.d, c.budget, &c.bucket, c.gamma_shape, c.scale, c.seed,
            );
        }
        // replicate WlshSketch::build's RNG discipline, but hash instances
        // in parallel
        let mut rng = Pcg64::new(c.seed, 0);
        let family = LshFamily::new(ds.d, c.gamma_shape, &c.bucket, &mut rng);
        let inv = (1.0 / c.scale) as f32;
        let x_scaled: Vec<f32> = ds.x.iter().map(|&v| v * inv).collect();
        let seeds: Vec<Pcg64> = (0..c.budget).map(|s| rng.fork(s as u64)).collect();
        let instances = par::fan_out(c.budget, c.workers, |s| {
            let mut r = seeds[s].clone();
            WlshSketch::build_instance(&x_scaled, &family, IdMode::U64, &mut r)
        });
        WlshSketch::from_parts(instances, family, IdMode::U64, x_scaled, ds.n, c.scale)
    }

    /// Kernel the configured method targets — used to build the Nyström
    /// preconditioner against the same kernel the operator approximates.
    fn target_kernel(&self) -> Kernel {
        let c = &self.config;
        match c.method.as_str() {
            "wlsh" | "exact-wlsh" => Kernel::wlsh(&c.bucket, c.gamma_shape, c.scale),
            "exact-laplace" => Kernel::laplace(c.scale),
            "exact-matern" => Kernel::matern52(c.scale),
            // exact-se, rff, nystrom, and anything new default to SE.
            _ => Kernel::squared_exp(c.scale),
        }
    }

    /// Build the configured preconditioner, falling back to `Identity`
    /// (with a stderr warning) when the operator can't support it.
    fn build_preconditioner(&self, ds: &Dataset, op: &dyn KrrOperator) -> Preconditioner {
        let c = &self.config;
        match c.precond.as_str() {
            "" | "none" => Preconditioner::Identity,
            "jacobi" => match op.diag() {
                Some(diag) => Preconditioner::jacobi(&diag, c.lambda),
                None => {
                    eprintln!(
                        "warning: {} exposes no cheap diagonal; solving unpreconditioned",
                        op.name()
                    );
                    Preconditioner::Identity
                }
            },
            "nystrom" => {
                let rank = c.precond_rank.clamp(1, ds.n);
                // decorrelate the landmark sample from the sketch seed
                let nys = NystromSketch::build(
                    &ds.x,
                    ds.n,
                    ds.d,
                    rank,
                    self.target_kernel(),
                    c.seed.wrapping_add(0x9e37_79b9_7f4a_7c15),
                );
                match nys.ridge_precond(c.lambda) {
                    Ok(p) => Preconditioner::Nystrom(p),
                    Err(e) => {
                        eprintln!(
                            "warning: nystrom preconditioner unavailable ({e}); solving unpreconditioned"
                        );
                        Preconditioner::Identity
                    }
                }
            }
            other => panic!("unknown preconditioner {other:?} (none|jacobi|nystrom)"),
        }
    }

    /// Full training run: operator build + (preconditioned) CG solve.
    pub fn train(&self, train: &Dataset) -> TrainedModel {
        let t0 = Instant::now();
        let op = self.build_operator(train);
        let build_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let opts = CgOptions {
            max_iters: self.config.cg_max_iters,
            tol: self.config.cg_tol,
            verbose: self.config.cg_verbose,
        };
        let precond = self.build_preconditioner(train, op.as_ref());
        let cg = match &precond {
            // keep the plain-CG code path (and its exact iterate sequence)
            // when no preconditioning was requested
            Preconditioner::Identity => {
                solve_krr(op.as_ref(), &train.y, self.config.lambda, &opts)
            }
            m => solve_krr_pcg(op.as_ref(), &train.y, self.config.lambda, &opts, m),
        };
        let solve_secs = t1.elapsed().as_secs_f64();
        let report = TrainReport {
            build_secs,
            solve_secs,
            cg_iters: cg.iters,
            cg_rel_residual: cg.rel_residual,
            converged: cg.converged,
            operator: op.name(),
            precond: precond.name().to_string(),
            memory_bytes: op.memory_bytes(),
        };
        TrainedModel::assemble(op, cg.beta, self.config.clone(), report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_by_name;

    fn small_ds() -> Dataset {
        let mut ds = synthetic_by_name("wine", Some(300), 1).unwrap();
        ds.standardize();
        ds
    }

    #[test]
    fn wlsh_training_beats_mean_predictor() {
        let ds = small_ds();
        let (tr, te) = ds.split(240, 2);
        let cfg = KrrConfig {
            method: "wlsh".into(),
            budget: 128,
            scale: 3.0,
            lambda: 0.2,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr);
        let pred = model.predict(&te.x);
        let rmse = crate::data::rmse(&pred, &te.y);
        let mean_rmse = crate::data::rmse(&vec![0.0; te.n], &te.y);
        assert!(rmse < mean_rmse, "rmse {rmse} vs mean {mean_rmse}");
        assert!(model.report.cg_iters > 0);
    }

    #[test]
    fn sharded_build_is_deterministic_across_worker_counts() {
        let ds = small_ds();
        let mk = |workers| {
            let cfg = KrrConfig { method: "wlsh".into(), budget: 12, workers, ..Default::default() };
            Trainer::new(cfg).build_operator(&ds)
        };
        let a = mk(1);
        let b = mk(3);
        let mut rng = Pcg64::new(5, 0);
        let beta: Vec<f64> = (0..ds.n).map(|_| rng.normal()).collect();
        let ya = a.matvec(&beta);
        let yb = b.matvec(&beta);
        for i in 0..ds.n {
            assert!((ya[i] - yb[i]).abs() < 1e-12, "row {i}: {} vs {}", ya[i], yb[i]);
        }
    }

    #[test]
    fn preconditioned_training_matches_plain_solution() {
        let ds = small_ds();
        let (tr, te) = ds.split(240, 8);
        let base = KrrConfig {
            method: "wlsh".into(),
            budget: 64,
            scale: 3.0,
            lambda: 0.2,
            cg_max_iters: 500,
            cg_tol: 1e-8,
            ..Default::default()
        };
        let plain = Trainer::new(base.clone()).train(&tr);
        assert_eq!(plain.report.precond, "none");
        let want = plain.predict(&te.x);
        for precond in ["jacobi", "nystrom"] {
            let cfg = KrrConfig { precond: precond.into(), precond_rank: 48, ..base.clone() };
            let model = Trainer::new(cfg).train(&tr);
            assert_eq!(model.report.precond, precond);
            assert!(model.report.converged, "{precond} did not converge");
            let got = model.predict(&te.x);
            for i in 0..te.n {
                assert!(
                    (got[i] - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()),
                    "{precond} query {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn jacobi_falls_back_when_operator_has_no_diagonal() {
        // rff exposes no cheap diagonal yet — the trainer must warn and
        // solve unpreconditioned rather than fail.
        let ds = small_ds();
        let cfg = KrrConfig {
            method: "rff".into(),
            budget: 128,
            scale: 3.0,
            precond: "jacobi".into(),
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&ds);
        assert_eq!(model.report.precond, "none");
        assert!(model.report.cg_iters > 0);
    }

    #[test]
    fn all_methods_train() {
        let ds = small_ds();
        let (tr, te) = ds.split(200, 3);
        for method in ["wlsh", "rff", "exact-laplace", "exact-se", "exact-matern", "nystrom"] {
            let cfg = KrrConfig {
                method: method.into(),
                budget: 32,
                scale: 3.0,
                lambda: 0.5,
                cg_max_iters: 50,
                ..Default::default()
            };
            let model = Trainer::new(cfg).train(&tr);
            let pred = model.predict(&te.x);
            assert_eq!(pred.len(), te.n);
            assert!(pred.iter().all(|p| p.is_finite()), "{method}");
        }
    }
}
