//! Training orchestration: build the requested kernel operator — from an
//! in-memory [`Dataset`] or from any chunked [`DataSource`] stream
//! ([`Trainer::train_source`]), sharding WLSH instance construction
//! across worker threads — solve the ridge system by CG, optionally
//! preconditioned (Jacobi from the operator diagonal, or rank-r Nyström
//! of the method's target kernel) via the typed `precond` spec, and
//! package a servable model. Streamed and in-memory training are
//! bit-identical on the same row stream (`tests/stream_equivalence.rs`);
//! all failure modes (bad parameters, malformed data files, non-PD
//! landmark matrices) surface as [`KrrError`].

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::{KernelFamily, KrrError, MethodSpec, PrecondSpec};
use crate::config::KrrConfig;
use crate::coordinator::shard::ShardedOperator;
use crate::data::{ChunkAnyFn, ChunkFn, DataSource, Dataset, SparseChunk};
use crate::kernels::Kernel;
use crate::online::{UncertainPredictor, VarianceEstimator};
use crate::sketch::{
    ExactKernelOp, KrrOperator, NystromSketch, Predictor, RffSketch, WlshBuildParams, WlshSketch,
};
use crate::solver::{solve_krr, solve_krr_pcg, CgOptions, Preconditioner};
use crate::util::mem;

/// A trained, servable KRR model. Holds the operator, the solved β, and a
/// frozen [`Predictor`] handle (the β-dependent serving state — WLSH
/// bucket loads (§4.2), RFF θ, Nyström core — precomputed once so a
/// prediction costs O(m·d), not O(n·m)).
pub struct TrainedModel {
    pub op: Arc<dyn KrrOperator>,
    pub beta: Vec<f64>,
    pub config: KrrConfig,
    pub report: TrainReport,
    predictor: Box<dyn Predictor>,
}

impl TrainedModel {
    /// Assemble a model from parts, freezing the serving handle. The
    /// handle is wrapped in an [`UncertainPredictor`] so every model can
    /// answer `predict_with_var` when its operator exposes a cross-kernel
    /// vector (point predictions delegate untouched — one vtable hop).
    pub fn assemble(
        op: Arc<dyn KrrOperator>,
        beta: Vec<f64>,
        config: KrrConfig,
        report: TrainReport,
    ) -> TrainedModel {
        let base = Arc::clone(&op).predictor(&beta);
        let var = VarianceEstimator::new(Arc::clone(&op), config.lambda);
        let predictor = Box::new(UncertainPredictor::new(base, var));
        TrainedModel { op, beta, config, report, predictor }
    }

    /// η̃(q) for each query row (through the frozen predictor handle).
    pub fn predict(&self, queries: &[f32]) -> Vec<f64> {
        self.predictor.predict(queries)
    }

    /// Allocation-free batch serving: one prediction per query row into
    /// `out`.
    pub fn predict_into(&self, queries: &[f32], out: &mut [f64]) {
        self.predictor.predict_into(queries, out)
    }

    /// Sparse batch serving: one prediction per CSR query row into `out`
    /// (WLSH/RFF handles hash/featurize the rows without densifying; other
    /// operators densify row by row).
    pub fn predict_sparse_into(&self, queries: &SparseChunk<'_>, out: &mut [f64]) {
        self.predictor.predict_sparse_into(queries, out)
    }

    /// Predictions plus sketched posterior variance per query row, or
    /// `None` when the operator exposes no cross-kernel vector.
    pub fn predict_with_var(
        &self,
        queries: &[f32],
        out: &mut [f64],
        var: &mut [f64],
    ) -> Option<()> {
        self.predictor.predict_with_var(queries, out, var)
    }

    /// The frozen serving handle itself.
    pub fn predictor(&self) -> &dyn Predictor {
        &*self.predictor
    }

    /// Feature count per query row.
    pub fn dim(&self) -> usize {
        self.predictor.dim()
    }
}

/// Timings and solve diagnostics from one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub build_secs: f64,
    pub solve_secs: f64,
    pub cg_iters: usize,
    pub cg_rel_residual: f64,
    pub converged: bool,
    pub operator: String,
    /// Preconditioner the solve actually used ("none" | "jacobi" |
    /// "nystrom") — may differ from the config when a fallback fired.
    pub precond: String,
    pub memory_bytes: usize,
    /// Operator-build ingestion throughput (training rows / build_secs) —
    /// the streaming pipeline's headline rate.
    pub rows_per_sec: f64,
    /// Peak resident-set estimate at packaging time
    /// ([`mem::peak_rss_bytes`]; 0 where the platform exposes none).
    pub peak_rss_bytes: usize,
}

/// Builds operators and runs the solve per a [`KrrConfig`].
pub struct Trainer {
    pub config: KrrConfig,
}

impl Trainer {
    pub fn new(config: KrrConfig) -> Trainer {
        Trainer { config }
    }

    /// Build the kernel operator for the configured method from an
    /// in-memory dataset. Everything except the exact methods funnels
    /// through the chunked
    /// [`build_operator_source`](Self::build_operator_source) path (the
    /// dataset is its own [`DataSource`]); exact operators keep the
    /// direct slice route to avoid a copy.
    pub fn build_operator(&self, ds: &Dataset) -> Result<Arc<dyn KrrOperator>, KrrError> {
        if let MethodSpec::Exact(family) = self.config.method {
            return Ok(Arc::new(ExactKernelOp::new(
                &ds.x,
                ds.n,
                ds.d,
                self.exact_kernel(family),
            )));
        }
        self.build_operator_source(ds)
    }

    /// Build the kernel operator by streaming a chunked source: peak
    /// memory is O(chunk + sketch) for wlsh/rff/nystrom. The exact
    /// methods have no streaming formulation (every pairwise distance is
    /// needed), so they materialize the source — documented fallback.
    pub fn build_operator_source(
        &self,
        src: &dyn DataSource,
    ) -> Result<Arc<dyn KrrOperator>, KrrError> {
        let c = &self.config;
        Ok(match c.method {
            MethodSpec::Wlsh => {
                let n = src.len_hint().unwrap_or(0);
                let params = WlshBuildParams::from_config(c, n, src.dim());
                Arc::new(WlshSketch::build(&params, src)?)
            }
            MethodSpec::Rff => Arc::new(RffSketch::build_source(
                src,
                c.budget,
                c.scale,
                c.seed,
                c.chunk_rows,
                c.workers,
            )?),
            MethodSpec::Nystrom => {
                let n = src.count_rows(c.chunk_rows)?;
                Arc::new(NystromSketch::build_source(
                    src,
                    c.budget.min(n),
                    Kernel::squared_exp(c.scale),
                    c.seed,
                    c.chunk_rows,
                    c.workers,
                )?)
            }
            MethodSpec::Exact(family) => {
                let ds = src.materialize(c.chunk_rows)?;
                Arc::new(ExactKernelOp::new(&ds.x, ds.n, ds.d, self.exact_kernel(family)))
            }
        })
    }

    /// The evaluable kernel for an exact-method family, parameterized from
    /// the config (scale; bucket + shape for the WLSH kernel).
    fn exact_kernel(&self, family: KernelFamily) -> Kernel {
        let c = &self.config;
        match family {
            KernelFamily::Laplace => Kernel::laplace(c.scale),
            KernelFamily::SquaredExp => Kernel::squared_exp(c.scale),
            KernelFamily::Matern52 => Kernel::matern52(c.scale),
            KernelFamily::Wlsh => Kernel::wlsh_spec(&c.bucket, c.gamma_shape, c.scale),
        }
    }

    /// Kernel the configured method targets — used to build the Nyström
    /// preconditioner against the same kernel the operator approximates.
    fn target_kernel(&self) -> Kernel {
        let c = &self.config;
        match c.method {
            MethodSpec::Wlsh => Kernel::wlsh_spec(&c.bucket, c.gamma_shape, c.scale),
            MethodSpec::Exact(family) => self.exact_kernel(family),
            // rff and nystrom target the SE kernel.
            MethodSpec::Rff | MethodSpec::Nystrom => Kernel::squared_exp(c.scale),
        }
    }

    /// Shared preconditioner assembly: the Jacobi/Identity cases need only
    /// the operator; the Nyström case builds its sketch through
    /// `build_nys` (slice-backed or streamed, supplied by the caller).
    /// Falls back to `Identity` (with a stderr warning) when the operator
    /// can't support the request.
    fn preconditioner_with<F>(
        &self,
        n: usize,
        op: &dyn KrrOperator,
        build_nys: F,
    ) -> Preconditioner
    where
        F: FnOnce(usize) -> Result<NystromSketch, KrrError>,
    {
        let c = &self.config;
        match c.precond {
            PrecondSpec::None => Preconditioner::Identity,
            PrecondSpec::Jacobi => match op.diag() {
                Some(diag) => Preconditioner::jacobi(&diag, c.lambda),
                None => {
                    eprintln!(
                        "warning: {} exposes no cheap diagonal; solving unpreconditioned",
                        op.name()
                    );
                    Preconditioner::Identity
                }
            },
            PrecondSpec::Nystrom { rank } => {
                let rank = rank.clamp(1, n);
                let precond = build_nys(rank).and_then(|nys| {
                    nys.ridge_precond(c.lambda).map_err(KrrError::SolveFailed)
                });
                match precond {
                    Ok(p) => Preconditioner::Nystrom(p),
                    Err(e) => {
                        eprintln!(
                            "warning: nystrom preconditioner unavailable ({e}); solving unpreconditioned"
                        );
                        Preconditioner::Identity
                    }
                }
            }
        }
    }

    /// Build the configured preconditioner against in-memory data.
    fn build_preconditioner(&self, ds: &Dataset, op: &dyn KrrOperator) -> Preconditioner {
        let c = &self.config;
        self.preconditioner_with(ds.n, op, |rank| {
            // decorrelate the landmark sample from the sketch seed
            NystromSketch::build(
                &ds.x,
                ds.n,
                ds.d,
                rank,
                self.target_kernel(),
                c.seed.wrapping_add(0x9e37_79b9_7f4a_7c15),
            )
        })
    }

    /// CG solve + packaging shared by the in-memory and streamed paths.
    fn solve_with(
        &self,
        op: Arc<dyn KrrOperator>,
        y: &[f64],
        build_secs: f64,
        precond: Preconditioner,
    ) -> Result<TrainedModel, KrrError> {
        let t1 = Instant::now();
        let opts = CgOptions {
            max_iters: self.config.cg_max_iters,
            tol: self.config.cg_tol,
            verbose: self.config.cg_verbose,
            x0: None,
        };
        let cg = match &precond {
            // keep the plain-CG code path (and its exact iterate sequence)
            // when no preconditioning was requested
            Preconditioner::Identity => {
                solve_krr(op.as_ref(), y, self.config.lambda, &opts)
            }
            m => solve_krr_pcg(op.as_ref(), y, self.config.lambda, &opts, m),
        };
        let solve_secs = t1.elapsed().as_secs_f64();
        let report = TrainReport {
            build_secs,
            solve_secs,
            cg_iters: cg.iters,
            cg_rel_residual: cg.rel_residual,
            converged: cg.converged,
            operator: op.name(),
            precond: precond.name().to_string(),
            memory_bytes: op.memory_bytes(),
            rows_per_sec: if build_secs > 0.0 { op.n() as f64 / build_secs } else { 0.0 },
            peak_rss_bytes: mem::peak_rss_bytes().unwrap_or(0),
        };
        Ok(TrainedModel::assemble(op, cg.beta, self.config.clone(), report))
    }

    /// Full training run: operator build + (preconditioned) CG solve.
    /// Validates the config first, so every entry point — builder, CLI,
    /// TOML — shares one range-check path.
    pub fn train(&self, train: &Dataset) -> Result<TrainedModel, KrrError> {
        self.config.validate()?;
        if self.config.topology.is_distributed() {
            return self.train_distributed(train);
        }
        let t0 = Instant::now();
        let op = self.build_operator(train)?;
        let build_secs = t0.elapsed().as_secs_f64();
        let precond = self.build_preconditioner(train, op.as_ref());
        self.solve_with(op, &train.y, build_secs, precond)
    }

    /// Sharded training run: stand up the configured topology (spawn
    /// local `shard-worker` processes or connect to remote addresses),
    /// distribute the WLSH instance build, and run the CG loop here with
    /// the fused mat-vec fanned out over the shards. The solved β is
    /// bit-identical to the single-process [`train`](Self::train) at
    /// every shard count (`tests/shard_equivalence.rs`). Any shard
    /// failure during the solve surfaces as [`KrrError::Shard`] — never a
    /// hang, never a partial model.
    fn train_distributed(&self, train: &Dataset) -> Result<TrainedModel, KrrError> {
        let t0 = Instant::now();
        let op = ShardedOperator::build(&self.config, &train.x, train.n, train.d)?;
        let build_secs = t0.elapsed().as_secs_f64();
        // Nyström preconditioning still assembles coordinator-side (it
        // needs the raw rows, which we have); Jacobi falls back with a
        // warning since the diagonal lives with the shard weights.
        let precond = self.build_preconditioner(train, op.as_ref());
        let dyn_op: Arc<dyn KrrOperator> = Arc::clone(&op);
        let model = self.solve_with(dyn_op, &train.y, build_secs, precond);
        // matvec is infallible by trait contract, so shard deaths latch
        // inside the operator; surface them as the hard error they are.
        if let Some(e) = op.failure() {
            return Err(e);
        }
        model
    }

    /// Streamed training run: the operator is built chunk by chunk from a
    /// re-iterable source (targets are collected during the same pass), so
    /// peak memory during training is O(chunk + sketch) instead of
    /// O(n·d). On the same row stream the solved coefficients are
    /// bit-identical to [`train`](Self::train) on the materialized
    /// dataset, at every chunk size and worker count.
    pub fn train_source(&self, src: &dyn DataSource) -> Result<TrainedModel, KrrError> {
        self.config.validate()?;
        if self.config.topology.is_distributed() {
            // Shard builds ship the standardized rows over the wire, so
            // the distributed path needs the materialized matrix anyway —
            // streaming buys nothing there. Documented fallback.
            let ds = src.materialize(self.config.chunk_rows)?;
            return self.train_distributed(&ds);
        }
        let collector = CollectTargets::new(src);
        let t0 = Instant::now();
        let op = self.build_operator_source(&collector)?;
        let build_secs = t0.elapsed().as_secs_f64();
        let y = collector.take();
        if y.is_empty() {
            return Err(KrrError::Dataset(format!("{}: no data rows", src.name())));
        }
        if y.len() != op.n() {
            return Err(KrrError::Dataset(format!(
                "{}: collected {} targets for {} operator rows",
                src.name(),
                y.len(),
                op.n()
            )));
        }
        let c = &self.config;
        let precond = self.preconditioner_with(y.len(), op.as_ref(), |rank| {
            // decorrelate the landmark sample from the sketch seed
            NystromSketch::build_source(
                src,
                rank,
                self.target_kernel(),
                c.seed.wrapping_add(0x9e37_79b9_7f4a_7c15),
                c.chunk_rows,
                c.workers,
            )
        });
        self.solve_with(op, &y, build_secs, precond)
    }
}

/// Source adapter recording the targets seen by the most recent complete
/// pass — so streamed training collects y during the operator build
/// instead of paying an extra pass over the stream.
struct CollectTargets<'a> {
    inner: &'a dyn DataSource,
    y: Mutex<Vec<f64>>,
}

impl<'a> CollectTargets<'a> {
    fn new(inner: &'a dyn DataSource) -> CollectTargets<'a> {
        CollectTargets { inner, y: Mutex::new(Vec::new()) }
    }

    fn take(self) -> Vec<f64> {
        self.y.into_inner().expect("collector lock poisoned")
    }
}

impl DataSource for CollectTargets<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn for_each_chunk(&self, chunk_rows: usize, f: ChunkFn) -> Result<(), KrrError> {
        let mut pass: Vec<f64> = Vec::new();
        self.inner.for_each_chunk(chunk_rows, &mut |rows, ys| {
            pass.extend_from_slice(ys);
            f(rows, ys)
        })?;
        *self.y.lock().expect("collector lock poisoned") = pass;
        Ok(())
    }

    fn is_sparse(&self) -> bool {
        self.inner.is_sparse()
    }

    fn for_each_chunk_any(&self, chunk_rows: usize, f: ChunkAnyFn) -> Result<(), KrrError> {
        // Pass sparse chunks through untouched (the default would densify
        // via `for_each_chunk`), still collecting the targets.
        let mut pass: Vec<f64> = Vec::new();
        self.inner.for_each_chunk_any(chunk_rows, &mut |chunk, ys| {
            pass.extend_from_slice(ys);
            f(chunk, ys)
        })?;
        *self.y.lock().expect("collector lock poisoned") = pass;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_by_name;
    use crate::util::rng::Pcg64;

    fn small_ds() -> Dataset {
        let mut ds = synthetic_by_name("wine", Some(300), 1).unwrap();
        ds.standardize();
        ds
    }

    #[test]
    fn wlsh_training_beats_mean_predictor() {
        let ds = small_ds();
        let (tr, te) = ds.split(240, 2);
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 128,
            scale: 3.0,
            lambda: 0.2,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let pred = model.predict(&te.x);
        let rmse = crate::data::rmse(&pred, &te.y);
        let mean_rmse = crate::data::rmse(&vec![0.0; te.n], &te.y);
        assert!(rmse < mean_rmse, "rmse {rmse} vs mean {mean_rmse}");
        assert!(model.report.cg_iters > 0);
    }

    #[test]
    fn sharded_build_is_deterministic_across_worker_counts() {
        let ds = small_ds();
        let mk = |workers| {
            let cfg = KrrConfig {
                method: MethodSpec::Wlsh,
                budget: 12,
                workers,
                ..Default::default()
            };
            Trainer::new(cfg).build_operator(&ds).unwrap()
        };
        let a = mk(1);
        let b = mk(3);
        let mut rng = Pcg64::new(5, 0);
        let beta: Vec<f64> = (0..ds.n).map(|_| rng.normal()).collect();
        let ya = a.matvec(&beta);
        let yb = b.matvec(&beta);
        for i in 0..ds.n {
            assert!((ya[i] - yb[i]).abs() < 1e-12, "row {i}: {} vs {}", ya[i], yb[i]);
        }
    }

    #[test]
    fn preconditioned_training_matches_plain_solution() {
        let ds = small_ds();
        let (tr, te) = ds.split(240, 8);
        let base = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 64,
            scale: 3.0,
            lambda: 0.2,
            cg_max_iters: 500,
            cg_tol: 1e-8,
            ..Default::default()
        };
        let plain = Trainer::new(base.clone()).train(&tr).unwrap();
        assert_eq!(plain.report.precond, "none");
        let want = plain.predict(&te.x);
        for precond in [PrecondSpec::Jacobi, PrecondSpec::Nystrom { rank: 48 }] {
            let cfg = KrrConfig { precond, ..base.clone() };
            let model = Trainer::new(cfg).train(&tr).unwrap();
            assert_eq!(model.report.precond, precond.to_string().split('(').next().unwrap());
            assert!(model.report.converged, "{precond} did not converge");
            let got = model.predict(&te.x);
            for i in 0..te.n {
                assert!(
                    (got[i] - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()),
                    "{precond} query {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    /// An operator with no cheap diagonal, for exercising the Jacobi
    /// fallback (every real operator now implements `diag`).
    struct DiaglessOp {
        n: usize,
    }

    struct ZeroPredictor {
        d: usize,
    }

    impl Predictor for ZeroPredictor {
        fn dim(&self) -> usize {
            self.d
        }

        fn predict_into(&self, _queries: &[f32], out: &mut [f64]) {
            out.fill(0.0);
        }
    }

    impl KrrOperator for DiaglessOp {
        fn n(&self) -> usize {
            self.n
        }

        fn matvec(&self, beta: &[f64]) -> Vec<f64> {
            beta.to_vec() // identity: SPD, so CG terminates
        }

        fn predict(&self, queries: &[f32], _beta: &[f64]) -> Vec<f64> {
            vec![0.0; queries.len()]
        }

        fn predictor(self: Arc<Self>, _beta: &[f64]) -> Box<dyn Predictor> {
            Box::new(ZeroPredictor { d: 1 })
        }

        fn name(&self) -> String {
            "diagless".into()
        }

        fn memory_bytes(&self) -> usize {
            0
        }
    }

    #[test]
    fn jacobi_falls_back_when_operator_has_no_diagonal() {
        // `KrrOperator::diag` defaults to None; the trainer must warn and
        // fall back to Identity rather than fail.
        let ds = small_ds();
        let cfg = KrrConfig { precond: PrecondSpec::Jacobi, ..Default::default() };
        let trainer = Trainer::new(cfg);
        let op = DiaglessOp { n: ds.n };
        assert!(op.diag().is_none());
        let pre = trainer.build_preconditioner(&ds, &op);
        assert_eq!(pre.name(), "none");
        // ...while an operator with a diagonal gets the real thing
        let rff = RffSketch::build(&ds.x, ds.n, ds.d, 64, 3.0, 7);
        let pre = trainer.build_preconditioner(&ds, &rff);
        assert_eq!(pre.name(), "jacobi");
    }

    #[test]
    fn rff_jacobi_training_uses_the_new_diagonal() {
        // rff now exposes diag(ZZᵀ) as cheap row norms, so requesting the
        // Jacobi preconditioner must actually engage it.
        let ds = small_ds();
        let cfg = KrrConfig {
            method: MethodSpec::Rff,
            budget: 128,
            scale: 3.0,
            precond: PrecondSpec::Jacobi,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&ds).unwrap();
        assert_eq!(model.report.precond, "jacobi");
        assert!(model.report.cg_iters > 0);
    }

    #[test]
    fn all_methods_train() {
        let ds = small_ds();
        let (tr, te) = ds.split(200, 3);
        for method in ["wlsh", "rff", "exact-laplace", "exact-se", "exact-matern", "nystrom"] {
            let cfg = KrrConfig {
                method: method.parse().unwrap(),
                budget: 32,
                scale: 3.0,
                lambda: 0.5,
                cg_max_iters: 50,
                ..Default::default()
            };
            let model = Trainer::new(cfg).train(&tr).unwrap();
            let pred = model.predict(&te.x);
            assert_eq!(pred.len(), te.n);
            assert!(pred.iter().all(|p| p.is_finite()), "{method}");
        }
    }

    #[test]
    fn streamed_training_matches_in_memory_training() {
        // Same rows through train() and train_source(): identical β, and
        // the streamed report carries the new throughput fields.
        let ds = small_ds();
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 16,
            scale: 3.0,
            lambda: 0.3,
            chunk_rows: 37,
            workers: 2,
            ..Default::default()
        };
        let a = Trainer::new(cfg.clone()).train(&ds).unwrap();
        let b = Trainer::new(cfg).train_source(&ds).unwrap();
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.report.operator, b.report.operator);
        assert!(b.report.rows_per_sec >= 0.0);
        let q = &ds.x[..5 * ds.d];
        assert_eq!(a.predict(q), b.predict(q));
    }

    #[test]
    fn invalid_config_is_rejected_before_building() {
        let ds = small_ds();
        let cfg = KrrConfig { scale: -1.0, ..Default::default() };
        assert!(matches!(
            Trainer::new(cfg).train(&ds),
            Err(KrrError::BadParam(_))
        ));
    }
}
