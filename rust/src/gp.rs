//! Gaussian-process sample-path generation (Table 1's data source).
//!
//! Exact sampler: Cholesky of the joint train+test kernel matrix (the
//! blocked factorization handles the paper's n = 4000 in seconds).
//! Approximate sampler: spectral (random-feature) synthesis for large n —
//! used by the synthetic dataset generators where exactness is not needed.

use crate::kernels::Kernel;
use crate::linalg::{CholeskyFactor, Matrix};
use crate::util::rng::Pcg64;

/// Sample η ~ GP(0, k) exactly at the given points (row-major n×d, f32).
/// Returns η(x_i) for every row. O(n³) via Cholesky with trace-scaled jitter.
pub fn sample_gp_exact(
    kernel: &Kernel,
    points: &[f32],
    d: usize,
    rng: &mut Pcg64,
) -> Result<Vec<f64>, String> {
    let n = points.len() / d;
    assert_eq!(points.len(), n * d);
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        let xi = &points[i * d..(i + 1) * d];
        k[(i, i)] = kernel.diag();
        for j in 0..i {
            let xj = &points[j * d..(j + 1) * d];
            let v = kernel.eval_f32(xi, xj);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    let jitter = 1e-8 * (n as f64);
    let chol = CholeskyFactor::new(&k, jitter / n as f64 * k.data[0].max(1.0) + 1e-10)?;
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    Ok(chol.l_mul(&z))
}

/// Spectral GP sampler: η(x) ≈ sqrt(2/D) Σ_j a_j cos(ω_jᵀx + b_j) with
/// a_j ~ N(0,1), b_j ~ U[0,2π), ω_j from the kernel's spectral density.
/// Exact in distribution as D → ∞; D ≈ 4096 gives ~1-2% covariance error.
pub struct SpectralGp {
    /// D×d frequency rows.
    omega: Vec<f64>,
    phase: Vec<f64>,
    amp: Vec<f64>,
    d: usize,
}

impl SpectralGp {
    pub fn new(kernel: &Kernel, d: usize, features: usize, rng: &mut Pcg64) -> SpectralGp {
        let mut omega = vec![0.0; features * d];
        match kernel {
            Kernel::SquaredExp { scale } => {
                // k(Δ)=exp(-‖Δ‖²/s²) ⇔ ω ~ N(0, 2/s² I)
                let sd = (2.0f64).sqrt() / scale;
                for v in omega.iter_mut() {
                    *v = rng.normal() * sd;
                }
            }
            Kernel::Laplace { scale } => {
                // product of 1-d Laplace e^{-|δ|/s}: spectral density per dim
                // is Cauchy with scale 1/(2π s)
                for v in omega.iter_mut() {
                    *v = rng.cauchy() / (2.0 * std::f64::consts::PI * scale)
                        * (2.0 * std::f64::consts::PI);
                }
            }
            Kernel::Matern52 { scale } => {
                // paper form (1+r+r²/3)e^{-r}, r=‖Δ‖/s is Matérn ν=5/2 with
                // √5/ℓ = 1/s ⇒ ℓ = √5 s. Spectral sampling: ω = g √(2ν/u),
                // u ~ χ²_{2ν} = Gamma(ν, 2), g ~ N(0, 1/ℓ² I)
                let nu = 2.5;
                let ell = 5.0f64.sqrt() * scale;
                for f in 0..features {
                    let u = 2.0 * rng.gamma(nu); // chi^2_{2ν}
                    let c = (2.0 * nu / u).sqrt() / ell;
                    for l in 0..d {
                        omega[f * d + l] = rng.normal() * c;
                    }
                }
            }
            Kernel::Wlsh { .. } => {
                panic!("spectral sampling of WLSH kernels is not supported; use sample_gp_exact")
            }
        }
        let phase = (0..features)
            .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        let amp = (0..features).map(|_| rng.normal()).collect();
        SpectralGp { omega, phase, amp, d }
    }

    /// Evaluate the sampled path at x (len d).
    pub fn eval(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), self.d);
        let features = self.phase.len();
        let norm = (2.0 / features as f64).sqrt();
        let mut acc = 0.0;
        for f in 0..features {
            let row = &self.omega[f * self.d..(f + 1) * self.d];
            let mut t = self.phase[f];
            for (wl, xl) in row.iter().zip(x) {
                t += wl * *xl as f64;
            }
            acc += self.amp[f] * t.cos();
        }
        acc * norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empirical covariance of GP samples must match the kernel.
    fn check_cov(kernel: &Kernel, tol: f64) {
        let d = 2;
        let pts: Vec<f32> = vec![0.0, 0.0, 0.3, 0.1, 0.8, 0.9];
        let n = 3;
        let trials = 3000;
        let mut rng = Pcg64::new(42, 0);
        let mut cov = vec![0.0; n * n];
        for _ in 0..trials {
            let s = sample_gp_exact(kernel, &pts, d, &mut rng).unwrap();
            for i in 0..n {
                for j in 0..n {
                    cov[i * n + j] += s[i] * s[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..n {
                let want = kernel.eval_f32(&pts[i * d..(i + 1) * d], &pts[j * d..(j + 1) * d]);
                let got = cov[i * n + j] / trials as f64;
                assert!(
                    (got - want).abs() < tol,
                    "{} cov[{i}{j}] {got} vs {want}",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn exact_sampler_covariances() {
        check_cov(&Kernel::laplace(1.0), 0.08);
        check_cov(&Kernel::squared_exp(1.0), 0.08);
        check_cov(&Kernel::matern52(1.0), 0.08);
    }

    #[test]
    fn spectral_sampler_covariance_se() {
        let kernel = Kernel::squared_exp(1.0);
        let d = 2;
        let xa = [0.0f32, 0.0];
        let xb = [0.5f32, 0.2];
        let trials = 600;
        let mut rng = Pcg64::new(7, 0);
        let (mut caa, mut cab) = (0.0, 0.0);
        for t in 0..trials {
            let mut r = rng.fork(t as u64);
            let gp = SpectralGp::new(&kernel, d, 2048, &mut r);
            let (a, b) = (gp.eval(&xa), gp.eval(&xb));
            caa += a * a;
            cab += a * b;
        }
        caa /= trials as f64;
        cab /= trials as f64;
        assert!((caa - 1.0).abs() < 0.15, "var {caa}");
        let want = kernel.eval_f32(&xa, &xb);
        assert!((cab - want).abs() < 0.15, "cov {cab} vs {want}");
    }

    #[test]
    fn spectral_sampler_covariance_laplace_and_matern() {
        for kernel in [Kernel::laplace(1.0), Kernel::matern52(1.0)] {
            let d = 1;
            let xa = [0.0f32];
            let xb = [0.6f32];
            let trials = 500;
            let mut rng = Pcg64::new(11, 0);
            let mut cab = 0.0;
            for t in 0..trials {
                let mut r = rng.fork(t as u64);
                let gp = SpectralGp::new(&kernel, d, 2048, &mut r);
                cab += gp.eval(&xa) * gp.eval(&xb);
            }
            cab /= trials as f64;
            let want = kernel.eval_f32(&xa, &xb);
            assert!(
                (cab - want).abs() < 0.15,
                "{}: {cab} vs {want}",
                kernel.name()
            );
        }
    }
}
