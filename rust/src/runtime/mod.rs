//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the CPU PJRT client (once,
//! cached), and exposes typed wrappers for each graph family with the
//! padding/chunking contract of DESIGN.md §6.
//!
//! Python never runs here — this is the request path. Every wrapper has a
//! native-Rust twin (lsh/sketch modules) and integration tests assert
//! parity between the two backends.

mod ops;

pub use ops::XlaExactKernelOp;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One artifact's signature from `manifest.json`.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<(Vec<usize>, String)>,
}

/// Parsed artifact manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub hash_chunk_n: usize,
    pub hash_chunk_m: usize,
    pub cross_chunk_q: usize,
    pub rff_chunk_n: usize,
    pub entries: HashMap<String, EntryInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut m = Manifest {
            hash_chunk_n: j.get("hash_chunk_n").and_then(Json::as_usize).unwrap_or(2048),
            hash_chunk_m: j.get("hash_chunk_m").and_then(Json::as_usize).unwrap_or(64),
            cross_chunk_q: j.get("cross_chunk_q").and_then(Json::as_usize).unwrap_or(1024),
            rff_chunk_n: j.get("rff_chunk_n").and_then(Json::as_usize).unwrap_or(2048),
            entries: HashMap::new(),
        };
        let shapes = |v: &Json, key: &str| -> Vec<(Vec<usize>, String)> {
            v.get(key)
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .map(|e| {
                            let shape = e
                                .get("shape")
                                .and_then(Json::as_f64_vec)
                                .unwrap_or_default()
                                .into_iter()
                                .map(|x| x as usize)
                                .collect();
                            let dtype = e
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string();
                            (shape, dtype)
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        for e in j.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry without name"))?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry without file"))?
                .to_string();
            m.entries.insert(
                name,
                EntryInfo { file, inputs: shapes(e, "inputs"), outputs: shapes(e, "outputs") },
            );
        }
        Ok(m)
    }
}

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (reads `manifest.json`, starts PJRT).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts location: `$WLSH_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("WLSH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn has(&self, name: &str) -> bool {
        self.manifest.entries.contains_key(name)
    }

    /// All artifact names with a given prefix (shape discovery).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .manifest
            .entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        v
    }

    /// Compile-on-first-use executable lookup.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name:?}"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literals; unwraps the 1-level output tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// f32 literal with shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims).map_err(|e| anyhow!("reshape f32 {dims:?}: {e:?}"))
}

/// i32 literal with shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims).map_err(|e| anyhow!("reshape i32 {dims:?}: {e:?}"))
}

/// Pad a row-major (n×d) f32 buffer to (n_pad×d_pad) with zeros.
pub fn pad_rows(x: &[f32], n: usize, d: usize, n_pad: usize, d_pad: usize) -> Vec<f32> {
    assert!(n_pad >= n && d_pad >= d);
    let mut out = vec![0.0f32; n_pad * d_pad];
    for i in 0..n {
        out[i * d_pad..i * d_pad + d].copy_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{"hash_chunk_n": 2048, "hash_chunk_m": 64, "cross_chunk_q": 1024,
                "rff_chunk_n": 2048,
                "entries": [{"name": "k", "file": "k.hlo.txt",
                             "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                             "outputs": [{"shape": [2], "dtype": "int32"}]}]}"#,
        )
        .unwrap();
        assert_eq!(m.hash_chunk_n, 2048);
        let e = &m.entries["k"];
        assert_eq!(e.file, "k.hlo.txt");
        assert_eq!(e.inputs[0].0, vec![2, 3]);
        assert_eq!(e.outputs[0].1, "int32");
    }

    #[test]
    fn pad_rows_layout() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let p = pad_rows(&x, 2, 2, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&p[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&p[8..12], &[0.0; 4]);
    }
}
