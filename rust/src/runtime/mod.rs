//! PJRT runtime shim: parses the AOT artifact manifests produced by
//! `python/compile/aot.py` and exposes the typed wrapper API for each
//! graph family (`hash_batch_xla`, `wlsh_matvec_xla`, ...).
//!
//! The offline vendored registry has no `xla`/PJRT crate (the `pjrt`
//! cargo feature is scaffolding for a future backend), so
//! [`Runtime::open`] validates the manifest and then reports the backend
//! as unavailable. Every caller — the CLI's `info` command, the XLA
//! sections of the benches, and `tests/xla_parity.rs` — treats that error
//! as a runtime skip, never a hard failure, so the native backend (the
//! production default, parity-tested against the HLO artifacts when a
//! PJRT build is available) carries all workloads.

mod ops;

pub use ops::XlaExactKernelOp;

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Runtime-layer error (a message; `anyhow` is unavailable offline).
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(RuntimeError(msg.into()))
}

/// One artifact's signature from `manifest.json`.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub file: String,
    pub inputs: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<(Vec<usize>, String)>,
}

/// Parsed artifact manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub hash_chunk_n: usize,
    pub hash_chunk_m: usize,
    pub cross_chunk_q: usize,
    pub rff_chunk_n: usize,
    pub entries: HashMap<String, EntryInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| RuntimeError(format!("manifest: {e}")))?;
        let mut m = Manifest {
            hash_chunk_n: j.get("hash_chunk_n").and_then(Json::as_usize).unwrap_or(2048),
            hash_chunk_m: j.get("hash_chunk_m").and_then(Json::as_usize).unwrap_or(64),
            cross_chunk_q: j.get("cross_chunk_q").and_then(Json::as_usize).unwrap_or(1024),
            rff_chunk_n: j.get("rff_chunk_n").and_then(Json::as_usize).unwrap_or(2048),
            entries: HashMap::new(),
        };
        let shapes = |v: &Json, key: &str| -> Vec<(Vec<usize>, String)> {
            v.get(key)
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .map(|e| {
                            let shape = e
                                .get("shape")
                                .and_then(Json::as_f64_vec)
                                .unwrap_or_default()
                                .into_iter()
                                .map(|x| x as usize)
                                .collect();
                            let dtype = e
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string();
                            (shape, dtype)
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        for e in j.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError("entry without name".into()))?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| RuntimeError("entry without file".into()))?
                .to_string();
            m.entries.insert(
                name,
                EntryInfo { file, inputs: shapes(e, "inputs"), outputs: shapes(e, "outputs") },
            );
        }
        Ok(m)
    }
}

/// The artifact runtime: manifest + (when the `pjrt` feature lands a real
/// backend) the compiled-executable cache.
pub struct Runtime {
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifacts directory: reads and validates `manifest.json`,
    /// then always fails with a "backend unavailable" error — no PJRT
    /// client is linked in any current build (the `pjrt` cargo feature is
    /// inert scaffolding). All callers treat the error as a skip.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError(format!(
                "reading {}: {e} (run `make artifacts`)",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        // No execution backend is linked yet — the `pjrt` cargo feature is
        // scaffolding only — so opening always reports unavailable (after
        // validating the manifest, so malformed artifacts still fail
        // loudly). Every caller treats this as a skip. When a real PJRT
        // client lands, this becomes `Ok(Runtime { dir, manifest })`.
        err(format!(
            "artifacts at {} ({} entries) but this build has no PJRT/XLA \
             execution backend (the `pjrt` feature is scaffolding only); \
             native backend only",
            dir.display(),
            manifest.entries.len()
        ))
    }

    /// Default artifacts location: `$WLSH_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("WLSH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn has(&self, name: &str) -> bool {
        self.manifest.entries.contains_key(name)
    }

    /// All artifact names with a given prefix (shape discovery).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .manifest
            .entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        v
    }

    pub fn platform(&self) -> String {
        "pjrt-unavailable (native backend only)".into()
    }

    pub(crate) fn unavailable<T>(&self, what: &str) -> Result<T> {
        err(format!(
            "{what}: PJRT execution backend not compiled into this build \
             (artifacts dir: {})",
            self.dir.display()
        ))
    }
}

/// Pad a row-major (n×d) f32 buffer to (n_pad×d_pad) with zeros.
pub fn pad_rows(x: &[f32], n: usize, d: usize, n_pad: usize, d_pad: usize) -> Vec<f32> {
    assert!(n_pad >= n && d_pad >= d);
    let mut out = vec![0.0f32; n_pad * d_pad];
    for i in 0..n {
        out[i * d_pad..i * d_pad + d].copy_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{"hash_chunk_n": 2048, "hash_chunk_m": 64, "cross_chunk_q": 1024,
                "rff_chunk_n": 2048,
                "entries": [{"name": "k", "file": "k.hlo.txt",
                             "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                             "outputs": [{"shape": [2], "dtype": "int32"}]}]}"#,
        )
        .unwrap();
        assert_eq!(m.hash_chunk_n, 2048);
        let e = &m.entries["k"];
        assert_eq!(e.file, "k.hlo.txt");
        assert_eq!(e.inputs[0].0, vec![2, 3]);
        assert_eq!(e.outputs[0].1, "int32");
    }

    #[test]
    fn manifest_rejects_incomplete_entries() {
        assert!(Manifest::parse(r#"{"entries": [{"file": "k.hlo.txt"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"entries": [{"name": "k"}]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn pad_rows_layout() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let p = pad_rows(&x, 2, 2, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&p[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&p[8..12], &[0.0; 4]);
    }

    #[test]
    fn open_is_a_clean_skip_without_backend_or_artifacts() {
        // No artifacts directory → error mentioning the manifest; callers
        // print it and skip. Either way, open never panics.
        let missing = Runtime::open("/definitely/not/a/real/artifacts/dir");
        assert!(missing.is_err());
        let msg = format!("{}", missing.err().unwrap());
        assert!(msg.contains("manifest.json"), "{msg}");
    }

    #[test]
    fn open_reports_backend_unavailable_even_with_valid_manifest() {
        // pid-suffixed so concurrent test runs never race on the dir
        let dir = std::env::temp_dir()
            .join(format!("wlsh_artifacts_open_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"entries": []}"#).unwrap();
        let r = Runtime::open(&dir);
        assert!(r.is_err());
        let msg = format!("{}", r.err().unwrap());
        assert!(msg.contains("backend"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
