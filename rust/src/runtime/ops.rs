//! Typed wrappers over the AOT artifacts: WLSH hashing, WLSH sketch
//! mat-vec, RFF features, exact kernel mat-vecs. The shapes/chunking
//! contract (DESIGN.md §6) is defined by the manifest; execution requires
//! the `pjrt` feature's backend, so in offline builds every wrapper
//! returns the runtime's "backend unavailable" error — which the parity
//! tests and benches treat as a skip.

use std::sync::Arc;

use super::{Result, Runtime};
use crate::lsh::LshFunction;
use crate::sketch::{KrrOperator, Predictor};

impl Runtime {
    /// Hash `x_scaled` (n×d) under the given LSH instances through the HLO
    /// artifact. Returns per-instance (ids-as-u64, weights), id arithmetic
    /// identical to the native `IdMode::I32` path.
    pub fn hash_batch_xla(
        &self,
        _x_scaled: &[f32],
        _n: usize,
        _d: usize,
        _funcs: &[LshFunction],
        _mix32: &[i32],
        _bucket: &str,
    ) -> Result<(Vec<Vec<u64>>, Vec<Vec<f32>>)> {
        self.unavailable("wlsh_hash")
    }

    /// WLSH sketch mat-vec through the `wlsh_matvec__n{n_pad}_m{chunk}`
    /// artifact: `ids` must be dense per-instance bucket indices < n.
    pub fn wlsh_matvec_xla(
        &self,
        _ids: &[Vec<u32>],
        _weights: &[Vec<f32>],
        _beta: &[f64],
    ) -> Result<Vec<f64>> {
        self.unavailable("wlsh_matvec")
    }

    /// RFF features through the `rff_features__n{chunk}_d{dp}_D{D}` artifact.
    pub fn rff_features_xla(
        &self,
        _rows: &[f32],
        _n: usize,
        _d: usize,
        _omega: &[f32],
        _b: &[f32],
        _dd: usize,
    ) -> Result<Vec<f32>> {
        self.unavailable("rff_features")
    }

    /// Exact kernel mat-vec `K(Xq, X)β` through the blockwise artifacts.
    /// `kind` ∈ {se, matern52, laplace}; `self_product` selects the n×n
    /// training artifact vs the chunked cross artifact.
    #[allow(clippy::too_many_arguments)]
    pub fn exact_matvec_xla(
        &self,
        kind: &str,
        _xq: &[f32],
        _q: usize,
        _x: &[f32],
        _n: usize,
        _d: usize,
        _beta: &[f64],
        _scale: f64,
        self_product: bool,
    ) -> Result<Vec<f64>> {
        let family = if self_product { "exact_matvec" } else { "exact_cross" };
        self.unavailable(&format!("{family}_{kind}"))
    }
}

/// Exact-kernel KRR operator backed by the HLO artifacts (the XLA twin of
/// `sketch::ExactKernelOp`). Only constructible alongside a [`Runtime`],
/// so in offline builds it is never instantiated.
pub struct XlaExactKernelOp<'rt> {
    rt: &'rt Runtime,
    kind: String,
    x: Vec<f32>,
    n: usize,
    d: usize,
    scale: f64,
}

impl<'rt> XlaExactKernelOp<'rt> {
    pub fn new(rt: &'rt Runtime, kind: &str, x: &[f32], n: usize, d: usize, scale: f64) -> Self {
        assert!(matches!(kind, "se" | "matern52" | "laplace"));
        XlaExactKernelOp { rt, kind: kind.to_string(), x: x.to_vec(), n, d, scale }
    }
}

impl KrrOperator for XlaExactKernelOp<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.rt
            .exact_matvec_xla(&self.kind, &self.x, self.n, &self.x, self.n, self.d, beta, self.scale, true)
            .expect("xla exact matvec")
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let q = queries.len() / self.d;
        self.rt
            .exact_matvec_xla(&self.kind, queries, q, &self.x, self.n, self.d, beta, self.scale, false)
            .expect("xla exact cross matvec")
    }

    fn predictor(self: Arc<Self>, _beta: &[f64]) -> Box<dyn Predictor> {
        // the runtime-borrowing operator cannot outlive its Runtime; models
        // served long-term go through the native operators
        unimplemented!("XLA operator has no frozen serving handle")
    }

    fn name(&self) -> String {
        format!("xla-exact({})", self.kind)
    }

    fn memory_bytes(&self) -> usize {
        self.x.len() * 4
    }
}
