//! Typed wrappers over the AOT artifacts: WLSH hashing, WLSH sketch
//! mat-vec, RFF features, exact kernel mat-vecs. Each picks the smallest
//! compatible padded shape from the manifest, chunks its inputs, and strips
//! the padding from the outputs.

use anyhow::{anyhow, Result};

use super::{lit_f32, lit_i32, pad_rows, Runtime};
use crate::lsh::LshFunction;
use crate::sketch::KrrOperator;

impl Runtime {
    /// Smallest artifact `prefix__n{..}_d{dp}..` with d_pad >= d; returns
    /// (name, d_pad) parsed back from the name.
    fn pick_hash_artifact(&self, d: usize, bucket: &str) -> Result<(String, usize)> {
        let n = self.manifest.hash_chunk_n;
        let m = self.manifest.hash_chunk_m;
        let mut best: Option<(usize, String)> = None;
        for dp in [8usize, 16, 32, 64, 96, 128, 384, 512] {
            if dp < d {
                continue;
            }
            let name = format!("wlsh_hash__n{n}_d{dp}_m{m}__{bucket}");
            if self.has(&name) && best.as_ref().map(|(b, _)| dp < *b).unwrap_or(true) {
                best = Some((dp, name));
            }
        }
        best.map(|(dp, name)| (name, dp))
            .ok_or_else(|| anyhow!("no wlsh_hash artifact for d={d}, bucket={bucket}"))
    }

    /// Hash `x_scaled` (n×d) under the given LSH instances through the HLO
    /// artifact. Returns per-instance (ids-as-u64, weights), id arithmetic
    /// identical to the native `IdMode::I32` path.
    pub fn hash_batch_xla(
        &self,
        x_scaled: &[f32],
        n: usize,
        d: usize,
        funcs: &[LshFunction],
        mix32: &[i32],
        bucket: &str,
    ) -> Result<(Vec<Vec<u64>>, Vec<Vec<f32>>)> {
        let (name, d_pad) = self.pick_hash_artifact(d, bucket)?;
        let chunk_n = self.manifest.hash_chunk_n;
        let chunk_m = self.manifest.hash_chunk_m;
        let m = funcs.len();
        let mut ids = vec![Vec::with_capacity(n); m];
        let mut weights = vec![Vec::with_capacity(n); m];
        let mut mix_pad = vec![1i32; d_pad];
        mix_pad[..d].copy_from_slice(mix32);
        let mut mask = vec![0.0f32; d_pad];
        mask[..d].fill(1.0);
        let mix_lit = lit_i32(&mix_pad, &[1, d_pad as i64])?;
        let mask_lit = lit_f32(&mask, &[1, d_pad as i64])?;
        for m0 in (0..m).step_by(chunk_m) {
            let m1 = (m0 + chunk_m).min(m);
            // pad instance params; padded instances get w=1,z=0 (harmless)
            let mut w_pad = vec![1.0f32; chunk_m * d_pad];
            let mut z_pad = vec![0.0f32; chunk_m * d_pad];
            for (s, f) in funcs[m0..m1].iter().enumerate() {
                w_pad[s * d_pad..s * d_pad + d].copy_from_slice(&f.w);
                z_pad[s * d_pad..s * d_pad + d].copy_from_slice(&f.z);
            }
            let w_lit = lit_f32(&w_pad, &[chunk_m as i64, d_pad as i64])?;
            let z_lit = lit_f32(&z_pad, &[chunk_m as i64, d_pad as i64])?;
            for n0 in (0..n).step_by(chunk_n) {
                let n1 = (n0 + chunk_n).min(n);
                let xp = pad_rows(&x_scaled[n0 * d..n1 * d], n1 - n0, d, chunk_n, d_pad);
                let x_lit = lit_f32(&xp, &[chunk_n as i64, d_pad as i64])?;
                let outs = self.execute(
                    &name,
                    &[
                        x_lit,
                        w_lit.reshape(&[chunk_m as i64, d_pad as i64])?,
                        z_lit.reshape(&[chunk_m as i64, d_pad as i64])?,
                        mix_lit.reshape(&[1, d_pad as i64])?,
                        mask_lit.reshape(&[1, d_pad as i64])?,
                    ],
                )?;
                let ids_out: Vec<i32> = outs[0]
                    .to_vec()
                    .map_err(|e| anyhow!("ids fetch: {e:?}"))?;
                let w_out: Vec<f32> = outs[1]
                    .to_vec()
                    .map_err(|e| anyhow!("weights fetch: {e:?}"))?;
                for s in 0..(m1 - m0) {
                    let row = &ids_out[s * chunk_n..s * chunk_n + (n1 - n0)];
                    ids[m0 + s].extend(row.iter().map(|&v| v as u32 as u64));
                    weights[m0 + s]
                        .extend_from_slice(&w_out[s * chunk_n..s * chunk_n + (n1 - n0)]);
                }
            }
        }
        Ok((ids, weights))
    }

    /// WLSH sketch mat-vec through the `wlsh_matvec__n{n_pad}_m{chunk}`
    /// artifact: `ids` must be dense per-instance bucket indices < n.
    pub fn wlsh_matvec_xla(
        &self,
        ids: &[Vec<u32>],
        weights: &[Vec<f32>],
        beta: &[f64],
    ) -> Result<Vec<f64>> {
        let n = beta.len();
        let chunk_m = self.manifest.hash_chunk_m;
        let n_pad = self
            .names_with_prefix("wlsh_matvec__n")
            .iter()
            .filter_map(|name| {
                let rest = name.strip_prefix("wlsh_matvec__n")?;
                let (np, _) = rest.split_once("_m")?;
                np.parse::<usize>().ok()
            })
            .filter(|&np| np >= n)
            .min()
            .ok_or_else(|| anyhow!("no wlsh_matvec artifact for n={n}"))?;
        let name = format!("wlsh_matvec__n{n_pad}_m{chunk_m}");
        let m = ids.len();
        let beta32: Vec<f32> = beta.iter().map(|&b| b as f32).collect();
        let mut beta_pad = vec![0.0f32; n_pad];
        beta_pad[..n].copy_from_slice(&beta32);
        let beta_lit = lit_f32(&beta_pad, &[1, n_pad as i64])?;
        let mut out = vec![0.0f64; n];
        for m0 in (0..m).step_by(chunk_m) {
            let m1 = (m0 + chunk_m).min(m);
            let mut ids_pad = vec![0i32; chunk_m * n_pad];
            let mut w_pad = vec![0.0f32; chunk_m * n_pad];
            for s in m0..m1 {
                debug_assert_eq!(ids[s].len(), n);
                for i in 0..n {
                    ids_pad[(s - m0) * n_pad + i] = ids[s][i] as i32;
                }
                w_pad[(s - m0) * n_pad..(s - m0) * n_pad + n]
                    .copy_from_slice(&weights[s]);
                // padded tail points: send them to bucket n-1 with weight 0
                for i in n..n_pad {
                    ids_pad[(s - m0) * n_pad + i] = (n_pad - 1) as i32;
                }
            }
            let ids_lit = lit_i32(&ids_pad, &[chunk_m as i64, n_pad as i64])?;
            let w_lit = lit_f32(&w_pad, &[chunk_m as i64, n_pad as i64])?;
            // inv_m = 1 here; we divide by the true m once at the end
            let inv_lit = lit_f32(&[1.0], &[1, 1])?;
            let outs = self.execute(
                &name,
                &[ids_lit, w_lit, beta_lit.reshape(&[1, n_pad as i64])?, inv_lit],
            )?;
            let y: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("y fetch: {e:?}"))?;
            for i in 0..n {
                out[i] += y[i] as f64;
            }
        }
        let inv_m = 1.0 / m as f64;
        for v in out.iter_mut() {
            *v *= inv_m;
        }
        Ok(out)
    }

    /// RFF features through the `rff_features__n{chunk}_d{dp}_D{D}` artifact.
    pub fn rff_features_xla(
        &self,
        rows: &[f32],
        n: usize,
        d: usize,
        omega: &[f32],
        b: &[f32],
        dd: usize,
    ) -> Result<Vec<f32>> {
        let chunk_n = self.manifest.rff_chunk_n;
        // find matching (d_pad, D) artifact
        let mut picked: Option<(usize, String)> = None;
        for name in self.names_with_prefix("rff_features__n") {
            let rest = name
                .strip_prefix(&format!("rff_features__n{chunk_n}_d"))
                .unwrap_or("");
            if let Some((dp, dd_s)) = rest.split_once("_D") {
                if let (Ok(dp), Ok(dd_a)) = (dp.parse::<usize>(), dd_s.parse::<usize>()) {
                    if dp >= d && dd_a == dd
                        && picked.as_ref().map(|(p, _)| dp < *p).unwrap_or(true)
                    {
                        picked = Some((dp, name.clone()));
                    }
                }
            }
        }
        let (d_pad, name) =
            picked.ok_or_else(|| anyhow!("no rff_features artifact for d={d}, D={dd}"))?;
        let omega_pad = pad_rows(omega, d, dd, d_pad, dd); // (d_pad × D)
        let omega_lit = lit_f32(&omega_pad, &[d_pad as i64, dd as i64])?;
        let b_lit = lit_f32(b, &[1, dd as i64])?;
        let scale = (2.0 / dd as f64).sqrt() as f32;
        let scale_lit = lit_f32(&[scale], &[1, 1])?;
        let mut out = vec![0.0f32; n * dd];
        for n0 in (0..n).step_by(chunk_n) {
            let n1 = (n0 + chunk_n).min(n);
            let xp = pad_rows(&rows[n0 * d..n1 * d], n1 - n0, d, chunk_n, d_pad);
            let x_lit = lit_f32(&xp, &[chunk_n as i64, d_pad as i64])?;
            let outs = self.execute(
                &name,
                &[
                    x_lit,
                    omega_lit.reshape(&[d_pad as i64, dd as i64])?,
                    b_lit.reshape(&[1, dd as i64])?,
                    scale_lit.reshape(&[1, 1])?,
                ],
            )?;
            let z: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("z fetch: {e:?}"))?;
            out[n0 * dd..n1 * dd].copy_from_slice(&z[..(n1 - n0) * dd]);
        }
        Ok(out)
    }

    /// Exact kernel mat-vec `K(Xq, X)β` through the blockwise artifacts.
    /// `kind` ∈ {se, matern52, laplace}; `self_product` selects the n×n
    /// training artifact vs the chunked cross artifact.
    #[allow(clippy::too_many_arguments)]
    pub fn exact_matvec_xla(
        &self,
        kind: &str,
        xq: &[f32],
        q: usize,
        x: &[f32],
        n: usize,
        d: usize,
        beta: &[f64],
        scale: f64,
        self_product: bool,
    ) -> Result<Vec<f64>> {
        let beta32: Vec<f32> = beta.iter().map(|&v| v as f32).collect();
        let pick = |prefix: &str| -> Option<(usize, usize, String)> {
            let mut best: Option<(usize, usize, String)> = None;
            for name in self.names_with_prefix(prefix) {
                let rest = name.strip_prefix(prefix).unwrap_or("");
                // rest like "{n}_d{d}" or "{q}_n{n}_d{d}"
                let parts: Vec<&str> = rest.split(['_']).collect();
                let mut np = None;
                let mut dp = None;
                for p in &parts {
                    if let Some(v) = p.strip_prefix('d') {
                        dp = v.parse::<usize>().ok();
                    } else if let Some(v) = p.strip_prefix('n') {
                        np = v.parse::<usize>().ok();
                    } else if np.is_none() && dp.is_none() {
                        np = p.parse::<usize>().ok(); // leading {n} for self
                    }
                }
                if let (Some(np), Some(dp)) = (np, dp) {
                    if np >= n && dp >= d && best.as_ref().map(|(bn, bd, _)| np < *bn || (np == *bn && dp < *bd)).unwrap_or(true)
                    {
                        best = Some((np, dp, name.clone()));
                    }
                }
            }
            best
        };
        if self_product {
            let (n_pad, d_pad, name) = pick(&format!("exact_matvec_{kind}__n"))
                .ok_or_else(|| anyhow!("no exact_matvec_{kind} artifact for n={n}, d={d}"))?;
            let xp = pad_rows(x, n, d, n_pad, d_pad);
            let mut bp = vec![0.0f32; n_pad];
            bp[..n].copy_from_slice(&beta32);
            let outs = self.execute(
                &name,
                &[
                    lit_f32(&xp, &[n_pad as i64, d_pad as i64])?,
                    lit_f32(&xp, &[n_pad as i64, d_pad as i64])?,
                    lit_f32(&bp, &[1, n_pad as i64])?,
                    lit_f32(&[scale as f32], &[1, 1])?,
                ],
            )?;
            let y: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("y fetch: {e:?}"))?;
            Ok(y[..n].iter().map(|&v| v as f64).collect())
        } else {
            let chunk_q = self.manifest.cross_chunk_q;
            let (n_pad, d_pad, name) = pick(&format!("exact_cross_{kind}__q{chunk_q}_n"))
                .ok_or_else(|| anyhow!("no exact_cross_{kind} artifact for n={n}, d={d}"))?;
            let xp = pad_rows(x, n, d, n_pad, d_pad);
            let x_lit = lit_f32(&xp, &[n_pad as i64, d_pad as i64])?;
            let mut bp = vec![0.0f32; n_pad];
            bp[..n].copy_from_slice(&beta32);
            let b_lit = lit_f32(&bp, &[1, n_pad as i64])?;
            let s_lit = lit_f32(&[scale as f32], &[1, 1])?;
            let mut out = vec![0.0f64; q];
            for q0 in (0..q).step_by(chunk_q) {
                let q1 = (q0 + chunk_q).min(q);
                let qp = pad_rows(&xq[q0 * d..q1 * d], q1 - q0, d, chunk_q, d_pad);
                let outs = self.execute(
                    &name,
                    &[
                        lit_f32(&qp, &[chunk_q as i64, d_pad as i64])?,
                        x_lit.reshape(&[n_pad as i64, d_pad as i64])?,
                        b_lit.reshape(&[1, n_pad as i64])?,
                        s_lit.reshape(&[1, 1])?,
                    ],
                )?;
                let y: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("y fetch: {e:?}"))?;
                for (i, v) in y[..q1 - q0].iter().enumerate() {
                    out[q0 + i] = *v as f64;
                }
            }
            Ok(out)
        }
    }
}

/// Exact-kernel KRR operator backed by the HLO artifacts (the XLA twin of
/// `sketch::ExactKernelOp`).
pub struct XlaExactKernelOp<'rt> {
    rt: &'rt Runtime,
    kind: String,
    x: Vec<f32>,
    n: usize,
    d: usize,
    scale: f64,
}

impl<'rt> XlaExactKernelOp<'rt> {
    pub fn new(rt: &'rt Runtime, kind: &str, x: &[f32], n: usize, d: usize, scale: f64) -> Self {
        assert!(matches!(kind, "se" | "matern52" | "laplace"));
        XlaExactKernelOp { rt, kind: kind.to_string(), x: x.to_vec(), n, d, scale }
    }
}

impl KrrOperator for XlaExactKernelOp<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.rt
            .exact_matvec_xla(&self.kind, &self.x, self.n, &self.x, self.n, self.d, beta, self.scale, true)
            .expect("xla exact matvec")
    }

    fn predict(&self, queries: &[f32], beta: &[f64]) -> Vec<f64> {
        let q = queries.len() / self.d;
        self.rt
            .exact_matvec_xla(&self.kind, queries, q, &self.x, self.n, self.d, beta, self.scale, false)
            .expect("xla exact cross matvec")
    }

    fn name(&self) -> String {
        format!("xla-exact({})", self.kind)
    }

    fn memory_bytes(&self) -> usize {
        self.x.len() * 4
    }
}

// Safety: XlaExactKernelOp is used single-threaded in benches; the xla crate
// wrappers are not Sync, so we do NOT implement Send/Sync manually — the
// KrrOperator supertraits require them, hence the unsafe impls below are
// scoped to this read-only wrapper whose mutations all happen inside the
// PJRT C API (which serializes internally for the CPU client).
unsafe impl Send for XlaExactKernelOp<'_> {}
unsafe impl Sync for XlaExactKernelOp<'_> {}
