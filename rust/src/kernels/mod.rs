//! Exact kernel functions (the paper's baselines plus the WLSH kernel
//! family itself, Def. 8) with a uniform evaluation interface.

use crate::api::BucketSpec;
use crate::quadrature::KernelProfile;

/// A shift-invariant kernel k(x, y) = k(x - y).
#[derive(Clone, Debug)]
pub enum Kernel {
    /// exp(-‖x-y‖₁ / s)
    Laplace { scale: f64 },
    /// exp(-‖x-y‖₂² / s²)
    SquaredExp { scale: f64 },
    /// (1 + r + r²/3) e^{-r}, r = ‖x-y‖₂ / s (the paper's Matérn-5/2 form)
    Matern52 { scale: f64 },
    /// WLSH kernel k_{f,p}(Δ) = ∏_l E_{w~Gamma(shape,1)}[(f*f)(Δ_l/w)]
    /// evaluated via a tabulated 1-d profile (Def. 8).
    Wlsh { profile: KernelProfile, scale: f64 },
}

impl Kernel {
    pub fn laplace(scale: f64) -> Kernel {
        Kernel::Laplace { scale }
    }

    pub fn squared_exp(scale: f64) -> Kernel {
        Kernel::SquaredExp { scale }
    }

    pub fn matern52(scale: f64) -> Kernel {
        Kernel::Matern52 { scale }
    }

    /// Build the WLSH kernel for a typed bucket spec and Gamma shape.
    /// `scale` divides the input difference (bandwidth), matching how the
    /// estimator scales data before hashing.
    pub fn wlsh_spec(bucket: &BucketSpec, gamma_shape: f64, scale: f64) -> Kernel {
        let ff = bucket.poly().autocorrelation();
        // delta_max: Gamma(shape) has negligible mass past shape+10√shape;
        // (f*f) support ≤ 1 ⇒ k_1d(δ) ≈ 0 beyond that times the support.
        let delta_max = (gamma_shape + 12.0 * gamma_shape.sqrt()).max(16.0);
        let profile = KernelProfile::build(&ff, gamma_shape, delta_max, 4096);
        Kernel::Wlsh { profile, scale }
    }

    /// String-name convenience over [`Kernel::wlsh_spec`] for tests and
    /// benches. Panics on a name that does not parse as a [`BucketSpec`] —
    /// fallible callers should parse the spec themselves.
    pub fn wlsh(bucket: &str, gamma_shape: f64, scale: f64) -> Kernel {
        let spec: BucketSpec = match bucket.parse() {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        };
        Kernel::wlsh_spec(&spec, gamma_shape, scale)
    }

    /// The paper's Table-1 smooth WLSH kernel: f = smooth2, p = Gamma(7,1).
    pub fn wlsh_paper_smooth(scale: f64) -> Kernel {
        Kernel::wlsh_spec(&BucketSpec::Smooth(2), 7.0, scale)
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Laplace { .. } => "laplace",
            Kernel::SquaredExp { .. } => "se",
            Kernel::Matern52 { .. } => "matern52",
            Kernel::Wlsh { .. } => "wlsh",
        }
    }

    /// Evaluate k(x, y).
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            Kernel::Laplace { scale } => {
                let d1: f64 = x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum();
                (-d1 / scale).exp()
            }
            Kernel::SquaredExp { scale } => {
                let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
                (-d2 / (scale * scale)).exp()
            }
            Kernel::Matern52 { scale } => {
                let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
                let r = d2.sqrt() / scale;
                (1.0 + r + r * r / 3.0) * (-r).exp()
            }
            Kernel::Wlsh { profile, scale } => x
                .iter()
                .zip(y)
                .map(|(a, b)| profile.eval((a - b) / scale))
                .product(),
        }
    }

    /// Evaluate over f32 rows (dataset storage format).
    pub fn eval_f32(&self, x: &[f32], y: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match self {
            Kernel::Laplace { scale } => {
                let d1: f64 = x
                    .iter()
                    .zip(y)
                    .map(|(a, b)| (*a as f64 - *b as f64).abs())
                    .sum();
                (-d1 / scale).exp()
            }
            Kernel::SquaredExp { scale } => {
                let d2: f64 = x
                    .iter()
                    .zip(y)
                    .map(|(a, b)| {
                        let d = *a as f64 - *b as f64;
                        d * d
                    })
                    .sum();
                (-d2 / (scale * scale)).exp()
            }
            Kernel::Matern52 { scale } => {
                let d2: f64 = x
                    .iter()
                    .zip(y)
                    .map(|(a, b)| {
                        let d = *a as f64 - *b as f64;
                        d * d
                    })
                    .sum();
                let r = d2.sqrt() / scale;
                (1.0 + r + r * r / 3.0) * (-r).exp()
            }
            Kernel::Wlsh { profile, scale } => x
                .iter()
                .zip(y)
                .map(|(a, b)| profile.eval((*a as f64 - *b as f64) / scale))
                .product(),
        }
    }

    /// k(x, x) — always 1 for these normalized kernels.
    pub fn diag(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_diagonal() {
        let x = vec![0.3, -1.2, 4.0];
        for k in [
            Kernel::laplace(1.0),
            Kernel::squared_exp(1.0),
            Kernel::matern52(1.0),
            Kernel::wlsh("rect", 2.0, 1.0),
        ] {
            assert!((k.eval(&x, &x) - 1.0).abs() < 1e-6, "{}", k.name());
        }
    }

    #[test]
    fn laplace_matches_formula() {
        let k = Kernel::laplace(1.0);
        let v = k.eval(&[0.0, 0.0], &[0.3, -0.4]);
        assert!((v - (-0.7f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn se_matches_formula() {
        let k = Kernel::squared_exp(2.0);
        let v = k.eval(&[0.0], &[1.0]);
        assert!((v - (-0.25f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matern_matches_paper_form() {
        let k = Kernel::matern52(1.0);
        let r: f64 = 1.3;
        let v = k.eval(&[0.0], &[r]);
        let want = (1.0 + r + r * r / 3.0) * (-r).exp();
        assert!((v - want).abs() < 1e-12);
    }

    #[test]
    fn wlsh_rect_gamma2_is_laplace() {
        // Def. 8 with f = rect, p = Gamma(2,1) gives the Laplace kernel.
        let kw = Kernel::wlsh("rect", 2.0, 1.0);
        let kl = Kernel::laplace(1.0);
        for delta in [0.0, 0.2, 0.7, 1.5, 3.0] {
            let x = vec![0.0, 0.1];
            let y = vec![delta, 0.1 - delta * 0.5];
            assert!(
                (kw.eval(&x, &y) - kl.eval(&x, &y)).abs() < 5e-4,
                "delta {delta}"
            );
        }
    }

    #[test]
    fn kernels_decay_monotonically() {
        for k in [
            Kernel::laplace(1.0),
            Kernel::squared_exp(1.0),
            Kernel::matern52(1.0),
            Kernel::wlsh_paper_smooth(1.0),
        ] {
            let mut prev = 1.0 + 1e-12;
            for i in 1..30 {
                let v = k.eval(&[0.0], &[0.2 * i as f64]);
                assert!(v <= prev + 1e-9, "{} at {}", k.name(), 0.2 * i as f64);
                assert!(v >= -1e-9);
                prev = v;
            }
        }
    }

    #[test]
    fn f32_path_matches_f64() {
        let x64 = vec![0.25, -0.5, 1.0];
        let y64 = vec![0.0, 0.5, 0.75];
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let y32: Vec<f32> = y64.iter().map(|&v| v as f32).collect();
        for k in [
            Kernel::laplace(1.3),
            Kernel::squared_exp(0.8),
            Kernel::matern52(2.0),
            Kernel::wlsh("rect", 2.0, 1.0),
        ] {
            assert!((k.eval(&x64, &y64) - k.eval_f32(&x32, &y32)).abs() < 1e-6);
        }
    }
}
