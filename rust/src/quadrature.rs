//! Numerical quadrature for the WLSH kernel profile (Def. 8):
//!
//!   k_1d(δ) = E_{w ~ p}[(f*f)(δ/w)] = ∫_0^∞ p(w) (f*f)(δ/w) dw
//!
//! with p = Gamma(shape, 1). Adaptive Simpson on a log-ish split of the
//! positive axis; the autocorrelation (f*f) is an exact piecewise
//! polynomial, so the only error is the quadrature's own.

use crate::bucketfn::PiecewisePoly;

/// Adaptive Simpson integration of `f` on [a, b].
///
/// The interval is first split into 32 uniform panels (a single Simpson
/// estimate on a wide interval can read a sharply-peaked integrand as ≈0
/// and accept it); each panel then adapts independently.
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    const PANELS: usize = 32;
    let h = (b - a) / PANELS as f64;
    (0..PANELS)
        .map(|i| {
            let lo = a + i as f64 * h;
            adaptive_simpson_raw(f, lo, lo + h, tol / PANELS as f64)
        })
        .sum()
}

fn adaptive_simpson_raw<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    fn simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64) -> (f64, f64, f64, f64) {
        let m = 0.5 * (a + b);
        let fa = f(a);
        let fm = f(m);
        let fb = f(b);
        ((b - a) / 6.0 * (fa + 4.0 * fm + fb), fa, fm, fb)
    }
    fn rec<F: Fn(f64) -> f64>(
        f: &F,
        a: f64,
        b: f64,
        fa: f64,
        fm: f64,
        fb: f64,
        whole: f64,
        tol: f64,
        depth: usize,
    ) -> f64 {
        let m = 0.5 * (a + b);
        let lm = 0.5 * (a + m);
        let rm = 0.5 * (m + b);
        let flm = f(lm);
        let frm = f(rm);
        let left = (m - a) / 6.0 * (fa + 4.0 * flm + fm);
        let right = (b - m) / 6.0 * (fm + 4.0 * frm + fb);
        let delta = left + right - whole;
        if depth == 0 || delta.abs() <= 15.0 * tol {
            left + right + delta / 15.0
        } else {
            rec(f, a, m, fa, flm, fm, left, tol * 0.5, depth - 1)
                + rec(f, m, b, fm, frm, fb, right, tol * 0.5, depth - 1)
        }
    }
    let (whole, fa, fm, fb) = simpson(f, a, b);
    rec(f, a, b, fa, fm, fb, whole, tol, 24)
}

/// ln Γ(x) (Lanczos approximation, |err| < 2e-10 for x > 0).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for g in G {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Gamma(shape, 1) PDF.
pub fn gamma_pdf(shape: f64, w: f64) -> f64 {
    if w <= 0.0 {
        return 0.0;
    }
    ((shape - 1.0) * w.ln() - w - ln_gamma(shape)).exp()
}

/// Tabulated 1-d WLSH kernel profile with linear interpolation — the fast
/// evaluation path for exact-WLSH-kernel KRR (Table 1) and GP sampling.
#[derive(Clone, Debug)]
pub struct KernelProfile {
    /// values[i] = k_1d(i * step), i in 0..len
    values: Vec<f64>,
    step: f64,
    /// (f*f) support half-width × w upper cutoff ⇒ δ beyond which k ≈ tail
    pub delta_max: f64,
}

impl KernelProfile {
    /// Build the profile for bucket autocorrelation `ff` and Gamma(shape,1)
    /// width law, tabulated on [0, delta_max] at `samples` points.
    pub fn build(ff: &PiecewisePoly, shape: f64, delta_max: f64, samples: usize) -> Self {
        let (_, sup_hi) = ff.support();
        let step = delta_max / (samples - 1) as f64;
        let values = (0..samples)
            .map(|i| {
                let delta = i as f64 * step;
                if delta == 0.0 {
                    // ∫ p(w) (f*f)(0) dw = (f*f)(0) = ||f||² = 1 for our f
                    return ff.eval(0.0);
                }
                // (f*f)(δ/w) is nonzero only for w >= δ / sup_hi
                let w_lo = delta / sup_hi;
                let w_hi = (w_lo + 40.0 + 8.0 * shape).max(80.0);
                adaptive_simpson(
                    &|w: f64| gamma_pdf(shape, w) * ff.eval(delta / w),
                    w_lo,
                    w_hi,
                    1e-11,
                )
            })
            .collect();
        KernelProfile { values, step, delta_max }
    }

    /// k_1d(|δ|) by linear interpolation (clamped to the table tail).
    #[inline]
    pub fn eval(&self, delta: f64) -> f64 {
        let d = delta.abs();
        let t = d / self.step;
        let i = t as usize;
        if i + 1 >= self.values.len() {
            return *self.values.last().unwrap();
        }
        let frac = t - i as f64;
        self.values[i] * (1.0 - frac) + self.values[i + 1] * frac
    }

    /// Product over coordinates: k(x - y) = ∏_l k_1d(x_l - y_l).
    pub fn eval_vec(&self, x: &[f64], y: &[f64]) -> f64 {
        x.iter()
            .zip(y)
            .map(|(a, b)| self.eval(a - b))
            .product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bucketfn::{rect_bucket, smooth_bucket};

    #[test]
    fn simpson_integrates_polynomial_exactly() {
        // ∫_0^1 (3x² + 1) = 2
        let v = adaptive_simpson(&|x| 3.0 * x * x + 1.0, 0.0, 1.0, 1e-12);
        assert!((v - 2.0).abs() < 1e-10);
    }

    #[test]
    fn simpson_handles_peaked_integrand() {
        // ∫_0^10 e^{-x} = 1 - e^{-10}
        let v = adaptive_simpson(&|x| (-x).exp(), 0.0, 10.0, 1e-12);
        assert!((v - (1.0 - (-10.0f64).exp())).abs() < 1e-9);
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-9);
        assert!((ln_gamma(2.0)).abs() < 1e-9);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(7.0) - (720.0f64).ln()).abs() < 1e-8);
    }

    #[test]
    fn gamma_pdf_normalizes() {
        for shape in [2.0, 7.0] {
            let v = adaptive_simpson(&|w| gamma_pdf(shape, w), 1e-12, 120.0, 1e-11);
            assert!((v - 1.0).abs() < 1e-7, "shape {shape}: {v}");
        }
    }

    #[test]
    fn rect_gamma2_profile_is_laplace() {
        // Rahimi-Recht: E_{w~Gamma(2,1)}[tri(δ/w)] = e^{-|δ|}
        let ff = rect_bucket().autocorrelation();
        let prof = KernelProfile::build(&ff, 2.0, 8.0, 2048);
        for delta in [0.0, 0.1, 0.5, 1.0, 2.0, 4.0] {
            let want = (-delta as f64).exp();
            let got = prof.eval(delta);
            assert!(
                (got - want).abs() < 2e-4,
                "delta {delta}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn smooth_profile_is_valid_kernel_shape() {
        let ff = smooth_bucket(2).autocorrelation();
        let prof = KernelProfile::build(&ff, 7.0, 10.0, 1024);
        assert!((prof.eval(0.0) - 1.0).abs() < 1e-8);
        // monotone decreasing and positive over the table
        let mut prev = prof.eval(0.0);
        for i in 1..100 {
            let v = prof.eval(0.1 * i as f64);
            assert!(v <= prev + 1e-9);
            assert!(v >= -1e-12);
            prev = v;
        }
    }

    #[test]
    fn eval_vec_is_product() {
        let ff = rect_bucket().autocorrelation();
        let prof = KernelProfile::build(&ff, 2.0, 8.0, 2048);
        let x = [0.0, 0.0];
        let y = [0.5, 0.25];
        let want = prof.eval(0.5) * prof.eval(0.25);
        assert!((prof.eval_vec(&x, &y) - want).abs() < 1e-12);
    }

    /// Posterior variance under the quadrature-tabulated exact kernel:
    /// non-negative, full-rank Lanczos matches the dense direct solve, and
    /// observing the query point itself shrinks the variance there (the
    /// kernel matrix grows by a PSD Schur complement — GP conditioning
    /// never increases posterior variance).
    #[test]
    fn profile_kernel_posterior_variance_properties() {
        use std::sync::Arc;

        use crate::kernels::Kernel;
        use crate::online::VarianceEstimator;
        use crate::sketch::ExactKernelOp;
        use crate::util::prop::{gens, prop_check};

        // one profile-backed kernel shared across cases (each build runs
        // the adaptive quadrature over 2048 table points)
        let kernel = Kernel::wlsh("rect", 2.0, 1.0);
        prop_check(
            23,
            6,
            |r| {
                let n = gens::size(r, 12, 22);
                let d = 2usize;
                let x = gens::matrix_f32(r, n, d);
                let q = gens::vec_normal_f32(r, d);
                let lambda = r.uniform_in(0.5, 2.0);
                (n, d, x, q, lambda)
            },
            |(n, d, x, q, lambda)| {
                let op = ExactKernelOp::new(x, *n, *d, kernel.clone());
                let est = VarianceEstimator::new(Arc::new(op), *lambda).with_rank(*n);
                let fast = est.variance(q).ok_or("exact op must expose cross_vector")?;
                let exact = est.variance_exact(q).map_err(|e| e.to_string())?;
                if !(fast.is_finite() && fast >= 0.0) {
                    return Err(format!("variance {fast} not finite non-negative"));
                }
                if (fast - exact).abs() > 1e-6 * (1.0 + exact.abs()) {
                    return Err(format!("lanczos {fast} vs exact {exact}"));
                }
                // grow the training set by the query row (the exact
                // operator has no incremental path; rebuild)
                let mut grown = x.clone();
                grown.extend_from_slice(q);
                let op2 = ExactKernelOp::new(&grown, *n + 1, *d, kernel.clone());
                let shrunk = VarianceEstimator::new(Arc::new(op2), *lambda)
                    .variance_exact(q)
                    .map_err(|e| e.to_string())?;
                if shrunk > exact + 1e-9 * (1.0 + exact.abs()) {
                    return Err(format!("variance grew on conditioning: {exact} -> {shrunk}"));
                }
                if exact > 1e-9 && shrunk >= exact {
                    return Err(format!("variance never shrank: {exact} -> {shrunk}"));
                }
                Ok(())
            },
        );
    }
}
