//! Spectral (OSE) verification — the machinery behind Theorem 11/12 checks.
//!
//! Definition 1 asks for (1-ε)(K+λI) ⪯ K̃+λI ⪯ (1+ε)(K+λI). Writing
//! M = (K+λI)^{-1/2} (K̃+λI) (K+λI)^{-1/2}, the condition is
//! spec(M) ⊆ [1-ε, 1+ε]; we report ε̂ = max(λ_max(M)-1, 1-λ_min(M)).
//!
//! Two evaluators: a dense one (exact, O(n³), for n ≲ 2000) and a Lanczos
//! one driven only by mat-vecs (for larger n).

use crate::linalg::{lanczos_extreme, sym_eig, Matrix};
use crate::sketch::KrrOperator;

/// Result of a spectral sandwich check.
#[derive(Clone, Debug)]
pub struct OseReport {
    pub eps: f64,
    pub lambda_min: f64,
    pub lambda_max: f64,
}

/// Dense evaluation of ε̂ for exact K (n×n) and sketch operator K̃.
pub fn ose_epsilon_dense(k_exact: &Matrix, sketch: &dyn KrrOperator, lambda: f64) -> OseReport {
    let n = k_exact.rows;
    assert_eq!(sketch.n(), n);
    // eigendecompose K = U diag(d) Uᵀ
    let eig = sym_eig(k_exact);
    // columns of B = U diag(1/sqrt(d+λ))
    let mut b = eig.vectors.clone();
    for j in 0..n {
        let s = 1.0 / (eig.values[j].max(0.0) + lambda).sqrt();
        for i in 0..n {
            b[(i, j)] *= s;
        }
    }
    // M = Bᵀ (K̃ + λI) B, built column by column through the operator
    let mut m = Matrix::zeros(n, n);
    for j in 0..n {
        let bj: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
        let mut kb = sketch.matvec(&bj);
        for (v, bv) in kb.iter_mut().zip(&bj) {
            *v += lambda * bv;
        }
        // column j of M = Bᵀ kb
        for r in 0..n {
            let mut acc = 0.0;
            for i in 0..n {
                acc += b[(i, r)] * kb[i];
            }
            m[(r, j)] = acc;
        }
    }
    m.symmetrize();
    let me = sym_eig(&m);
    let lo = *me.values.first().unwrap();
    let hi = *me.values.last().unwrap();
    OseReport { eps: (hi - 1.0).max(1.0 - lo), lambda_min: lo, lambda_max: hi }
}

/// Lanczos evaluation of ε̂ using only mat-vecs with K and K̃.
///
/// `exact_matvec` must apply the exact kernel matrix. We factor
/// (K+λI)^{-1/2} through a few CG solves inside the operator: each Lanczos
/// step applies v ↦ (K+λI)^{-1/2}(K̃+λI)(K+λI)^{-1/2} v via an eigendecomp-
/// free route — we instead check the *generalized* problem
/// (K̃+λI) v = μ (K+λI) v through the equivalent operator
/// (K+λI)^{-1}(K̃+λI) symmetrized by similarity; for reporting extremes the
/// spectrum is identical.
pub fn ose_epsilon_lanczos<F>(
    n: usize,
    exact_matvec: F,
    sketch: &dyn KrrOperator,
    lambda: f64,
    steps: usize,
    seed: u64,
) -> OseReport
where
    F: Fn(&[f64]) -> Vec<f64>,
{
    let exact_matvec = &exact_matvec;
    // inner CG for (K+λI)^{-1} w (exact operator is SPD)
    let solve = move |w: &[f64]| -> Vec<f64> {
        let mut x = vec![0.0f64; n];
        let mut r = w.to_vec();
        let mut p = r.clone();
        let mut rs = crate::linalg::dot(&r, &r);
        let tol = 1e-10 * rs.sqrt().max(1e-300);
        for _ in 0..400 {
            if rs.sqrt() <= tol {
                break;
            }
            let mut ap = exact_matvec(&p);
            for (v, pv) in ap.iter_mut().zip(&p) {
                *v += lambda * pv;
            }
            let alpha = rs / crate::linalg::dot(&p, &ap);
            crate::linalg::axpy(alpha, &p, &mut x);
            crate::linalg::axpy(-alpha, &ap, &mut r);
            let rs2 = crate::linalg::dot(&r, &r);
            let ratio = rs2 / rs;
            for (pv, rv) in p.iter_mut().zip(&r) {
                *pv = rv + ratio * *pv;
            }
            rs = rs2;
        }
        x
    };
    // Operator A v = (K+λI)^{-1} (K̃+λI) v is similar to M (same spectrum)
    // but not symmetric; symmetrize via the split A' = S (K̃+λI) S with
    // S = (K+λI)^{-1/2} is unavailable without an eigendecomp, so run
    // Lanczos on the symmetric pencil form: w = (K̃+λI)v, then solve.
    // Using the (K+λI)-inner-product Lanczos keeps this symmetric; for the
    // extremes, plain Lanczos on the similar operator is adequate and we
    // guard with the dense path in tests.
    let res = lanczos_extreme(n, steps, seed, move |v| {
        let mut w = sketch.matvec(v);
        for (wv, vv) in w.iter_mut().zip(v) {
            *wv += lambda * vv;
        }
        solve(&w)
    });
    OseReport {
        eps: (res.max - 1.0).max(1.0 - res.min),
        lambda_min: res.min,
        lambda_max: res.max,
    }
}

/// Empirical risk R(η) = (1/n) Σ (η(x_i) - η*(x_i))² (Appendix E).
pub fn empirical_risk(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::MatrixSource;
    use crate::kernels::Kernel;
    use crate::online::VarianceEstimator;
    use crate::sketch::{ExactKernelOp, WlshBuildParams, WlshSketch};
    use crate::solver::materialize;
    use crate::util::prop::{gens, prop_check};
    use crate::util::rng::Pcg64;

    fn rect_sketch(x: &[f32], n: usize, d: usize, m: usize, seed: u64) -> WlshSketch {
        WlshSketch::build_mem(x, &WlshBuildParams::new(n, d, m).seed(seed))
    }

    #[test]
    fn exact_sketch_has_zero_eps() {
        let mut rng = Pcg64::new(1, 0);
        let (n, d) = (24, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let op = ExactKernelOp::new(&x, n, d, Kernel::laplace(1.0));
        let k = materialize(&op);
        let rep = ose_epsilon_dense(&k, &op, 0.5);
        assert!(rep.eps < 1e-7, "eps {}", rep.eps);
    }

    #[test]
    fn wlsh_eps_shrinks_with_m() {
        let mut rng = Pcg64::new(2, 0);
        let (n, d) = (48, 2);
        let x: Vec<f32> = (0..n * d).map(|_| (rng.normal() * 0.7) as f32).collect();
        let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh("rect", 2.0, 1.0));
        let k = materialize(&exact);
        let lambda = 2.0;
        let small = rect_sketch(&x, n, d, 4, 5);
        let large = rect_sketch(&x, n, d, 256, 5);
        let e_small = ose_epsilon_dense(&k, &small, lambda).eps;
        let e_large = ose_epsilon_dense(&k, &large, lambda).eps;
        assert!(
            e_large < e_small,
            "eps(m=256)={e_large} !< eps(m=4)={e_small}"
        );
        // Theorem 11 rate: quadrupling m should roughly halve eps; allow 3x slack
        assert!(e_large < 0.75 * e_small);
    }

    #[test]
    fn lanczos_matches_dense_on_small_problem() {
        let mut rng = Pcg64::new(3, 0);
        let (n, d) = (32, 2);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh("rect", 2.0, 1.0));
        let k = materialize(&exact);
        let sk = rect_sketch(&x, n, d, 32, 7);
        let lambda = 1.0;
        let dense = ose_epsilon_dense(&k, &sk, lambda);
        let kk = k.clone();
        let lan = ose_epsilon_lanczos(n, move |v| kk.matvec(v), &sk, lambda, 32, 9);
        assert!(
            (dense.eps - lan.eps).abs() < 0.05 * (1.0 + dense.eps),
            "dense {} vs lanczos {}",
            dense.eps,
            lan.eps
        );
    }

    #[test]
    fn empirical_risk_basics() {
        assert_eq!(empirical_risk(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((empirical_risk(&[1.0, 3.0], &[1.0, 1.0]) - 2.0).abs() < 1e-12);
    }

    /// The posterior variance the OSE guarantees underwrite: for random
    /// data, queries, and ridges, the full-rank Lanczos estimate is
    /// non-negative and agrees with the exact dense solve at small n.
    #[test]
    fn posterior_variance_nonnegative_and_matches_exact_at_small_n() {
        prop_check(
            11,
            10,
            |r| {
                let n = gens::size(r, 18, 36);
                let d = gens::size(r, 2, 3);
                let x = gens::matrix_f32(r, n, d);
                let q = gens::vec_normal_f32(r, d);
                let lambda = r.uniform_in(0.3, 2.0);
                (n, d, x, q, lambda)
            },
            |(n, d, x, q, lambda)| {
                let sk = rect_sketch(x, *n, *d, 32, 13);
                let est = VarianceEstimator::new(Arc::new(sk), *lambda).with_rank(*n);
                let fast = est.variance(q).ok_or("wlsh must expose cross_vector")?;
                let exact = est.variance_exact(q).map_err(|e| e.to_string())?;
                if !(fast.is_finite() && fast >= 0.0) {
                    return Err(format!("variance {fast} not finite non-negative"));
                }
                if (fast - exact).abs() > 1e-6 * (1.0 + exact.abs()) {
                    return Err(format!("lanczos {fast} vs exact {exact}"));
                }
                Ok(())
            },
        );
    }

    /// σ² = λ z_qᵀ(ZᵀZ+λI)⁻¹z_q in the sketch's feature space: appending
    /// rows adds a PSD increment to ZᵀZ, so the posterior variance at any
    /// query is monotonically non-increasing — and strictly shrinks when
    /// the appended rows include the query itself.
    #[test]
    fn posterior_variance_shrinks_monotonically_as_rows_arrive_near_the_query() {
        prop_check(
            17,
            8,
            |r| {
                let n = gens::size(r, 16, 30);
                let d = gens::size(r, 2, 3);
                let x = gens::matrix_f32(r, n, d);
                let q = gens::vec_normal_f32(r, d);
                let lambda = r.uniform_in(0.3, 2.0);
                // three batches of rows at / jittered around the query
                let batches: Vec<Vec<f32>> = (0..3)
                    .map(|b| {
                        (0..2 * d)
                            .map(|i| {
                                let jitter = if b == 0 && i < d {
                                    0.0 // first batch leads with q itself
                                } else {
                                    (r.normal() * 0.05) as f32
                                };
                                q[i % d] + jitter
                            })
                            .collect()
                    })
                    .collect();
                (n, d, x, q, lambda, batches)
            },
            |(n, d, x, q, lambda, batches)| {
                let mut sk = rect_sketch(x, *n, *d, 32, 29);
                let var_of = |sk: &WlshSketch| -> Result<f64, String> {
                    VarianceEstimator::new(Arc::new(sk.clone()), *lambda)
                        .variance_exact(q)
                        .map_err(|e| e.to_string())
                };
                let first = var_of(&sk)?;
                let mut prev = first;
                for batch in batches {
                    sk.append_source(
                        &MatrixSource::new("near-query", batch, *d),
                        8,
                        1,
                    )
                    .map_err(|e| e.to_string())?;
                    let next = var_of(&sk)?;
                    if next > prev + 1e-9 * (1.0 + prev.abs()) {
                        return Err(format!("variance grew: {prev} -> {next}"));
                    }
                    prev = next;
                }
                // observing the query itself must genuinely reduce
                // uncertainty there (unless it was already ≈ certain)
                if first > 1e-9 && prev >= first {
                    return Err(format!("variance never shrank: {first} -> {prev}"));
                }
                Ok(())
            },
        );
    }
}
