//! Typed specifications for every method/kernel/bucket/preconditioner
//! choice the system exposes.
//!
//! Each spec enum carries its own parameters and round-trips through
//! `FromStr`/`Display` (`parse(display(spec)) == spec`, property-tested in
//! `tests/spec_api.rs`). CLI flags, the TOML subset, checkpoint headers,
//! and train-JSON all parse and print through these four types — there is
//! exactly one string grammar per concept, and an unrecognized string is a
//! [`KrrError`], never a panic.

use std::fmt;
use std::str::FromStr;

use super::KrrError;
use crate::bucketfn::{rect_bucket, smooth_bucket, BucketEval, PiecewisePoly};

/// Bucket-shaping function f (paper Def. 6/8).
///
/// Strings: `rect`, `smooth` (= `smooth2`), `smooth<q>` with q ≥ 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BucketSpec {
    /// f = rect — unweighted buckets (with Gamma(2,1) widths this is the
    /// Laplace kernel).
    Rect,
    /// C^{q-1} smooth bucket `(rect * rect_{1/(2q)}^{*q})(2x)`; q = 2 is the
    /// paper's Table-1 function.
    Smooth(usize),
}

impl BucketSpec {
    /// The exact piecewise polynomial for this bucket function.
    pub fn poly(&self) -> PiecewisePoly {
        match self {
            BucketSpec::Rect => rect_bucket(),
            BucketSpec::Smooth(q) => smooth_bucket(*q),
        }
    }

    /// Compiled f32 evaluator for the hashing hot loop.
    pub fn eval(&self) -> BucketEval {
        BucketEval::from_poly(&self.poly(), matches!(self, BucketSpec::Rect))
    }
}

impl fmt::Display for BucketSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BucketSpec::Rect => write!(f, "rect"),
            BucketSpec::Smooth(q) => write!(f, "smooth{q}"),
        }
    }
}

impl FromStr for BucketSpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        if s == "rect" {
            return Ok(BucketSpec::Rect);
        }
        if let Some(qs) = s.strip_prefix("smooth") {
            let q = if qs.is_empty() { Some(2) } else { qs.parse().ok() };
            if let Some(q) = q {
                if q >= 1 {
                    return Ok(BucketSpec::Smooth(q));
                }
            }
        }
        Err(KrrError::UnknownBucket(s.to_string()))
    }
}

/// Exact kernel family selector — the parameter-free tag used inside
/// [`MethodSpec::Exact`] (the fully parameterized form is [`KernelSpec`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelFamily {
    Laplace,
    SquaredExp,
    Matern52,
    Wlsh,
}

/// Which estimator family to train (paper §4 vs. the §1.1 baselines).
///
/// Strings are the historical method names: `wlsh`, `rff`,
/// `exact-laplace`, `exact-se`, `exact-matern`, `exact-wlsh`, `nystrom` —
/// so checkpoint headers and configs written before the typed API still
/// parse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodSpec {
    /// The paper's WLSH random-binning estimator (budget = m instances).
    Wlsh,
    /// Random Fourier features baseline (budget = D features).
    Rff,
    /// Exact kernel operator (O(n²d) mat-vec) for a kernel family; the
    /// family's parameters (scale, bucket, shape) come from the config.
    Exact(KernelFamily),
    /// Nyström landmark baseline (budget = landmark count).
    Nystrom,
}

impl MethodSpec {
    /// True for the exact (non-sketched) operators, which ignore `budget`.
    pub fn is_exact(&self) -> bool {
        matches!(self, MethodSpec::Exact(_))
    }
}

impl FromStr for MethodSpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        match s {
            "wlsh" => Ok(MethodSpec::Wlsh),
            "rff" => Ok(MethodSpec::Rff),
            "exact-laplace" => Ok(MethodSpec::Exact(KernelFamily::Laplace)),
            "exact-se" => Ok(MethodSpec::Exact(KernelFamily::SquaredExp)),
            "exact-matern" => Ok(MethodSpec::Exact(KernelFamily::Matern52)),
            "exact-wlsh" => Ok(MethodSpec::Exact(KernelFamily::Wlsh)),
            "nystrom" => Ok(MethodSpec::Nystrom),
            other => Err(KrrError::UnknownMethod(other.to_string())),
        }
    }
}

/// CG preconditioner choice, carrying its own parameters.
///
/// Strings: `none`, `jacobi`, `nystrom` (rank = 64), `nystrom(rank=R)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondSpec {
    /// Plain CG.
    None,
    /// Rescale by `diag(K̃) + λ` — needs
    /// [`KrrOperator::diag`](crate::sketch::KrrOperator::diag).
    Jacobi,
    /// Rank-`rank` Nyström approximation of the target kernel, applied via
    /// the Woodbury identity.
    Nystrom {
        /// Landmark count of the preconditioner (clamped to n at train time).
        rank: usize,
    },
}

/// Default landmark count when `nystrom` is given without an explicit rank.
pub const DEFAULT_PRECOND_RANK: usize = 64;

impl FromStr for PrecondSpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        match s {
            "" | "none" => return Ok(PrecondSpec::None),
            "jacobi" => return Ok(PrecondSpec::Jacobi),
            "nystrom" => return Ok(PrecondSpec::Nystrom { rank: DEFAULT_PRECOND_RANK }),
            _ => {}
        }
        let (name, params) = split_params(s)
            .map_err(|_| KrrError::UnknownPrecond(s.to_string()))?;
        if name == "nystrom" {
            let mut rank = DEFAULT_PRECOND_RANK;
            for (k, v) in params {
                match k {
                    "rank" => {
                        rank = v.parse().map_err(|_| {
                            KrrError::BadParam(format!("nystrom rank {v:?} is not an integer"))
                        })?;
                        if rank == 0 {
                            return Err(KrrError::BadParam("nystrom rank must be ≥ 1".into()));
                        }
                    }
                    other => {
                        return Err(KrrError::BadParam(format!(
                            "nystrom preconditioner has no parameter {other:?}"
                        )))
                    }
                }
            }
            return Ok(PrecondSpec::Nystrom { rank });
        }
        Err(KrrError::UnknownPrecond(s.to_string()))
    }
}

/// A fully parameterized shift-invariant kernel — the typed form of
/// [`crate::kernels::Kernel`], used where a kernel is named by a string
/// (the `gp` CLI, GP examples).
///
/// Strings: a family name (`laplace`, `se`, `matern52`, `wlsh`; aliases
/// `squared-exp` and `matern` accepted) with optional `(key=value, ...)`
/// parameters, e.g. `laplace(scale=3)`,
/// `wlsh(bucket=smooth2,shape=7,scale=1.5)`. Omitted parameters default to
/// scale = 1, bucket = rect, shape = 2.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelSpec {
    /// exp(-‖x-y‖₁ / scale)
    Laplace { scale: f64 },
    /// exp(-‖x-y‖₂² / scale²)
    SquaredExp { scale: f64 },
    /// (1 + r + r²/3) e^{-r}, r = ‖x-y‖₂ / scale
    Matern52 { scale: f64 },
    /// The WLSH kernel k_{f,p} of Def. 8.
    Wlsh { bucket: BucketSpec, gamma_shape: f64, scale: f64 },
}

impl KernelSpec {
    /// Instantiate the evaluable kernel.
    pub fn build(&self) -> crate::kernels::Kernel {
        use crate::kernels::Kernel;
        match self {
            KernelSpec::Laplace { scale } => Kernel::laplace(*scale),
            KernelSpec::SquaredExp { scale } => Kernel::squared_exp(*scale),
            KernelSpec::Matern52 { scale } => Kernel::matern52(*scale),
            KernelSpec::Wlsh { bucket, gamma_shape, scale } => {
                Kernel::wlsh_spec(bucket, *gamma_shape, *scale)
            }
        }
    }
}

impl FromStr for KernelSpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        let (name, params) =
            split_params(s).map_err(|_| KrrError::UnknownKernel(s.to_string()))?;
        let mut scale = 1.0f64;
        let mut bucket = BucketSpec::Rect;
        let mut gamma_shape = 2.0f64;
        let is_wlsh = name == "wlsh";
        for (k, v) in params {
            match k {
                "scale" => {
                    scale = parse_f64_param("scale", v)?;
                }
                "bucket" if is_wlsh => bucket = v.parse()?,
                "shape" if is_wlsh => {
                    gamma_shape = parse_f64_param("shape", v)?;
                }
                other => {
                    return Err(KrrError::BadParam(format!(
                        "kernel {name:?} has no parameter {other:?}"
                    )))
                }
            }
        }
        match name {
            "laplace" => Ok(KernelSpec::Laplace { scale }),
            "se" | "squared-exp" => Ok(KernelSpec::SquaredExp { scale }),
            "matern52" | "matern" => Ok(KernelSpec::Matern52 { scale }),
            "wlsh" => Ok(KernelSpec::Wlsh { bucket, gamma_shape, scale }),
            other => Err(KrrError::UnknownKernel(other.to_string())),
        }
    }
}

/// Where the m WLSH instances live during solve and serving.
///
/// Strings: `local`, `shards(n=N)` with N ≥ 1 locally spawned worker
/// processes, `remote(addr=host:port,addr=host:port,...)` with one
/// `addr=` pair per already-running `shard-worker` process. The shard
/// order is the listed order — it fixes the reduction order, so it is
/// part of the spec, not an implementation detail.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TopologySpec {
    /// Everything in this address space (the default).
    Local,
    /// Spawn `n` local `shard-worker` child processes on ephemeral ports.
    Shards {
        /// Worker-process count (≥ 1; `shards(n=1)` is the distributed
        /// path with a single remote operator, bit-identical to `local`).
        n: usize,
    },
    /// Connect to externally managed shard workers at these addresses,
    /// in this order.
    Remote {
        /// `host:port` of each worker, in reduction order.
        addrs: Vec<String>,
    },
}

impl TopologySpec {
    /// True for the distributed topologies (anything but [`Local`](Self::Local)).
    pub fn is_distributed(&self) -> bool {
        !matches!(self, TopologySpec::Local)
    }
}

impl FromStr for TopologySpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        if s.trim() == "local" {
            return Ok(TopologySpec::Local);
        }
        let bad = || {
            KrrError::BadParam(format!(
                "unknown topology {s:?} (local|shards(n=N)|remote(addr=host:port,...))"
            ))
        };
        let (name, params) = split_params(s).map_err(|_| bad())?;
        match name {
            "shards" => {
                let mut n = None;
                for (k, v) in params {
                    match k {
                        "n" => {
                            let parsed: usize = v.parse().map_err(|_| {
                                KrrError::BadParam(format!(
                                    "shards n {v:?} is not an integer"
                                ))
                            })?;
                            if parsed == 0 {
                                return Err(KrrError::BadParam(
                                    "shards n must be ≥ 1".into(),
                                ));
                            }
                            n = Some(parsed);
                        }
                        other => {
                            return Err(KrrError::BadParam(format!(
                                "shards topology has no parameter {other:?}"
                            )))
                        }
                    }
                }
                let n = n.ok_or_else(|| {
                    KrrError::BadParam("shards topology requires n, e.g. shards(n=4)".into())
                })?;
                Ok(TopologySpec::Shards { n })
            }
            "remote" => {
                let mut addrs = Vec::new();
                for (k, v) in params {
                    match k {
                        "addr" if !v.is_empty() => addrs.push(v.to_string()),
                        "addr" => {
                            return Err(KrrError::BadParam(
                                "remote topology addr must be non-empty".into(),
                            ))
                        }
                        other => {
                            return Err(KrrError::BadParam(format!(
                                "remote topology has no parameter {other:?}"
                            )))
                        }
                    }
                }
                if addrs.is_empty() {
                    return Err(KrrError::BadParam(
                        "remote topology requires at least one addr=host:port".into(),
                    ));
                }
                Ok(TopologySpec::Remote { addrs })
            }
            _ => Err(bad()),
        }
    }
}

/// How the sketch's m instances are selected and weighted (paper §4 plus
/// the importance-sampling refinements of Avron et al., 1804.09893).
///
/// Strings: `uniform`, `leverage(pilot=P,keep=K)`, `stein`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingSpec {
    /// Keep all m instances with unit weight — the paper's estimator.
    Uniform,
    /// Build the full m-instance pool, estimate each instance's ridge
    /// leverage against a `pilot`-instance pilot operator via Lanczos
    /// quadrature, keep the top-`keep` instances, and reweight them so the
    /// kept sub-estimator is trace-preserving.
    Leverage {
        /// Pilot-operator size (≥ 1, ≤ budget): instances scored against
        /// the first `pilot` instances of the pool.
        pilot: usize,
        /// Instances retained (≥ 1, ≤ budget).
        keep: usize,
    },
    /// Keep all m instances but carry mean-1 leverage-proportional
    /// importance weights (data-driven Stein-effect shrinkage,
    /// 1705.08525). Experimental.
    Stein,
}

impl SamplingSpec {
    /// True when every instance keeps unit weight (the legacy behavior).
    pub fn is_uniform(&self) -> bool {
        matches!(self, SamplingSpec::Uniform)
    }
}

impl FromStr for SamplingSpec {
    type Err = KrrError;

    fn from_str(s: &str) -> Result<Self, KrrError> {
        match s.trim() {
            "" | "uniform" => return Ok(SamplingSpec::Uniform),
            "stein" => return Ok(SamplingSpec::Stein),
            _ => {}
        }
        let bad = || {
            KrrError::BadParam(format!(
                "unknown sampling {s:?} (uniform|leverage(pilot=P,keep=K)|stein)"
            ))
        };
        let (name, params) = split_params(s).map_err(|_| bad())?;
        if name != "leverage" {
            return Err(bad());
        }
        let mut pilot = None;
        let mut keep = None;
        for (k, v) in params {
            let parsed: usize = v.parse().map_err(|_| {
                KrrError::BadParam(format!("leverage {k} {v:?} is not an integer"))
            })?;
            match k {
                "pilot" => pilot = Some(parsed),
                "keep" => keep = Some(parsed),
                other => {
                    return Err(KrrError::BadParam(format!(
                        "leverage sampling has no parameter {other:?}"
                    )))
                }
            }
        }
        let pilot = pilot.ok_or_else(|| {
            KrrError::BadParam("leverage sampling requires pilot, e.g. leverage(pilot=16,keep=48)".into())
        })?;
        let keep = keep.ok_or_else(|| {
            KrrError::BadParam("leverage sampling requires keep, e.g. leverage(pilot=16,keep=48)".into())
        })?;
        if pilot == 0 {
            return Err(KrrError::BadParam("leverage pilot must be ≥ 1".into()));
        }
        if keep == 0 {
            return Err(KrrError::BadParam("leverage keep must be ≥ 1".into()));
        }
        Ok(SamplingSpec::Leverage { pilot, keep })
    }
}

fn parse_f64_param(key: &str, v: &str) -> Result<f64, KrrError> {
    let x: f64 = v
        .parse()
        .map_err(|_| KrrError::BadParam(format!("{key} {v:?} is not a number")))?;
    if !x.is_finite() || x <= 0.0 {
        return Err(KrrError::BadParam(format!("{key} must be a positive finite number, got {v}")));
    }
    Ok(x)
}

/// Split `name(k=v,k2=v2)` into the name and its key/value pairs; a bare
/// `name` yields no pairs. Whitespace around tokens is tolerated.
fn split_params(s: &str) -> Result<(&str, Vec<(&str, &str)>), ()> {
    let s = s.trim();
    let Some(open) = s.find('(') else {
        if s.is_empty() || s.contains(')') {
            return Err(());
        }
        return Ok((s, Vec::new()));
    };
    let name = s[..open].trim();
    let rest = &s[open + 1..];
    let Some(body) = rest.strip_suffix(')') else { return Err(()) };
    if name.is_empty() || body.contains('(') || body.contains(')') {
        return Err(());
    }
    let mut pairs = Vec::new();
    for part in body.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((k, v)) = part.split_once('=') else { return Err(()) };
        pairs.push((k.trim(), v.trim()));
    }
    Ok((name, pairs))
}

// ---- Display: the single place each spec's canonical string is defined ----

impl fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            MethodSpec::Wlsh => "wlsh",
            MethodSpec::Rff => "rff",
            MethodSpec::Exact(KernelFamily::Laplace) => "exact-laplace",
            MethodSpec::Exact(KernelFamily::SquaredExp) => "exact-se",
            MethodSpec::Exact(KernelFamily::Matern52) => "exact-matern",
            MethodSpec::Exact(KernelFamily::Wlsh) => "exact-wlsh",
            MethodSpec::Nystrom => "nystrom",
        };
        write!(f, "{s}")
    }
}

impl fmt::Display for PrecondSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PrecondSpec::None => write!(f, "none"),
            PrecondSpec::Jacobi => write!(f, "jacobi"),
            PrecondSpec::Nystrom { rank } => write!(f, "nystrom(rank={rank})"),
        }
    }
}

impl fmt::Display for TopologySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TopologySpec::Local => write!(f, "local"),
            TopologySpec::Shards { n } => write!(f, "shards(n={n})"),
            TopologySpec::Remote { addrs } => {
                write!(f, "remote(")?;
                for (i, a) in addrs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "addr={a}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for SamplingSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SamplingSpec::Uniform => write!(f, "uniform"),
            SamplingSpec::Leverage { pilot, keep } => {
                write!(f, "leverage(pilot={pilot},keep={keep})")
            }
            SamplingSpec::Stein => write!(f, "stein"),
        }
    }
}

impl fmt::Display for KernelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelSpec::Laplace { scale } => write!(f, "laplace(scale={scale})"),
            KernelSpec::SquaredExp { scale } => write!(f, "se(scale={scale})"),
            KernelSpec::Matern52 { scale } => write!(f, "matern52(scale={scale})"),
            KernelSpec::Wlsh { bucket, gamma_shape, scale } => {
                write!(f, "wlsh(bucket={bucket},shape={gamma_shape},scale={scale})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_strings_are_the_legacy_names() {
        for (s, m) in [
            ("wlsh", MethodSpec::Wlsh),
            ("rff", MethodSpec::Rff),
            ("exact-laplace", MethodSpec::Exact(KernelFamily::Laplace)),
            ("exact-se", MethodSpec::Exact(KernelFamily::SquaredExp)),
            ("exact-matern", MethodSpec::Exact(KernelFamily::Matern52)),
            ("exact-wlsh", MethodSpec::Exact(KernelFamily::Wlsh)),
            ("nystrom", MethodSpec::Nystrom),
        ] {
            assert_eq!(s.parse::<MethodSpec>().unwrap(), m);
            assert_eq!(m.to_string(), s);
        }
        assert_eq!(
            "wlshh".parse::<MethodSpec>(),
            Err(KrrError::UnknownMethod("wlshh".into()))
        );
    }

    #[test]
    fn bucket_parses_shorthand_and_rejects_degenerate() {
        assert_eq!("smooth".parse::<BucketSpec>().unwrap(), BucketSpec::Smooth(2));
        assert_eq!("smooth3".parse::<BucketSpec>().unwrap(), BucketSpec::Smooth(3));
        assert!(matches!(
            "smooth0".parse::<BucketSpec>(),
            Err(KrrError::UnknownBucket(_))
        ));
        assert!(matches!("bogus".parse::<BucketSpec>(), Err(KrrError::UnknownBucket(_))));
    }

    #[test]
    fn precond_accepts_bare_and_parameterized_nystrom() {
        assert_eq!(
            "nystrom".parse::<PrecondSpec>().unwrap(),
            PrecondSpec::Nystrom { rank: DEFAULT_PRECOND_RANK }
        );
        assert_eq!(
            "nystrom(rank=17)".parse::<PrecondSpec>().unwrap(),
            PrecondSpec::Nystrom { rank: 17 }
        );
        assert_eq!("".parse::<PrecondSpec>().unwrap(), PrecondSpec::None);
        assert!(matches!(
            "nystrom(rank=0)".parse::<PrecondSpec>(),
            Err(KrrError::BadParam(_))
        ));
        assert!(matches!("ssor".parse::<PrecondSpec>(), Err(KrrError::UnknownPrecond(_))));
    }

    #[test]
    fn kernel_aliases_and_defaults() {
        assert_eq!(
            "matern".parse::<KernelSpec>().unwrap(),
            KernelSpec::Matern52 { scale: 1.0 }
        );
        assert_eq!(
            "se(scale=2.5)".parse::<KernelSpec>().unwrap(),
            KernelSpec::SquaredExp { scale: 2.5 }
        );
        assert_eq!(
            "wlsh".parse::<KernelSpec>().unwrap(),
            KernelSpec::Wlsh { bucket: BucketSpec::Rect, gamma_shape: 2.0, scale: 1.0 }
        );
        assert!(matches!(
            "se(scale=-1)".parse::<KernelSpec>(),
            Err(KrrError::BadParam(_))
        ));
        assert!(matches!(
            "laplace(shape=2)".parse::<KernelSpec>(),
            Err(KrrError::BadParam(_))
        ));
        assert!(matches!("cosine".parse::<KernelSpec>(), Err(KrrError::UnknownKernel(_))));
    }

    #[test]
    fn topology_round_trips_and_rejects_degenerate() {
        for (s, t) in [
            ("local", TopologySpec::Local),
            ("shards(n=4)", TopologySpec::Shards { n: 4 }),
            (
                "remote(addr=127.0.0.1:9001,addr=127.0.0.1:9002)",
                TopologySpec::Remote {
                    addrs: vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()],
                },
            ),
        ] {
            assert_eq!(s.parse::<TopologySpec>().unwrap(), t);
            assert_eq!(t.to_string(), s);
        }
        for bad in ["", "shards", "shards(n=0)", "shards(m=2)", "remote", "remote()", "ring(n=3)"]
        {
            assert!(
                matches!(bad.parse::<TopologySpec>(), Err(KrrError::BadParam(_))),
                "{bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn sampling_round_trips_and_rejects_degenerate() {
        for (s, v) in [
            ("uniform", SamplingSpec::Uniform),
            ("leverage(pilot=16,keep=48)", SamplingSpec::Leverage { pilot: 16, keep: 48 }),
            ("stein", SamplingSpec::Stein),
        ] {
            assert_eq!(s.parse::<SamplingSpec>().unwrap(), v);
            assert_eq!(v.to_string(), s);
        }
        assert!(SamplingSpec::Uniform.is_uniform());
        assert!(!SamplingSpec::Stein.is_uniform());
        for bad in [
            "lev",
            "leverage",
            "leverage(pilot=16)",
            "leverage(keep=48)",
            "leverage(pilot=0,keep=4)",
            "leverage(pilot=4,keep=0)",
            "leverage(pilot=x,keep=4)",
            "leverage(pilot=4,keep=4,extra=1)",
            "stein(n=2)",
        ] {
            assert!(
                matches!(bad.parse::<SamplingSpec>(), Err(KrrError::BadParam(_))),
                "{bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn split_params_grammar() {
        assert_eq!(split_params("abc"), Ok(("abc", vec![])));
        assert_eq!(
            split_params("n(a=1, b=x)"),
            Ok(("n", vec![("a", "1"), ("b", "x")]))
        );
        assert!(split_params("n(a=1").is_err());
        assert!(split_params("n(a)").is_err());
        assert!(split_params("(a=1)").is_err());
    }
}
