//! The crate-wide error type. Every fallible entry point — the
//! [`KrrModel`](crate::api::KrrModel) builder,
//! [`Trainer::train`](crate::coordinator::Trainer::train), TOML configs,
//! CLI parsing, and checkpoint I/O — surfaces misconfiguration and
//! runtime failures as a [`KrrError`] instead of panicking, so callers
//! (and the CLI's exit-code mapping) can tell a typo from a crash.

use std::fmt;

/// Everything that can go wrong between "spec string" and "trained model".
#[derive(Clone, Debug, PartialEq)]
pub enum KrrError {
    /// The method string matched no estimator family (see
    /// [`MethodSpec`](crate::api::MethodSpec) for the accepted names).
    UnknownMethod(String),
    /// The bucket-function string matched no [`BucketSpec`](crate::api::BucketSpec).
    UnknownBucket(String),
    /// The preconditioner string matched no [`PrecondSpec`](crate::api::PrecondSpec).
    UnknownPrecond(String),
    /// The kernel string matched no [`KernelSpec`](crate::api::KernelSpec).
    UnknownKernel(String),
    /// The dataset name matched no synthetic spec and is not a CSV path.
    UnknownDataset(String),
    /// A parameter parsed but is out of range (λ < 0, scale ≤ 0, ...).
    BadParam(String),
    /// A dataset file or stream is malformed (ragged CSV rows, bad floats,
    /// invalid LIBSVM index/value pairs, no data rows, target column out
    /// of range). Every loader — in-memory and streaming — reports content
    /// problems through this one variant; [`KrrError::Io`] stays reserved
    /// for filesystem failures.
    Dataset(String),
    /// The linear-algebra stage failed (e.g. a landmark matrix that is not
    /// positive definite).
    SolveFailed(String),
    /// Filesystem / network I/O failure (checkpoints, CSV loads).
    Io(String),
    /// A shard worker failed (connect refused after retries, mid-solve
    /// disconnect, malformed reply). Names the shard address so the
    /// runbook's "which process died" question has a one-line answer.
    Shard(String),
}

impl fmt::Display for KrrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KrrError::UnknownMethod(s) => write!(
                f,
                "unknown method {s:?} (wlsh|rff|exact-laplace|exact-se|exact-matern|exact-wlsh|nystrom)"
            ),
            KrrError::UnknownBucket(s) => {
                write!(f, "unknown bucket {s:?} (rect|smooth|smooth<q>)")
            }
            KrrError::UnknownPrecond(s) => {
                write!(f, "unknown preconditioner {s:?} (none|jacobi|nystrom|nystrom(rank=R))")
            }
            KrrError::UnknownKernel(s) => {
                write!(f, "unknown kernel {s:?} (laplace|se|matern52|wlsh)")
            }
            KrrError::UnknownDataset(s) => {
                write!(f, "unknown dataset {s:?} (and not a .csv path)")
            }
            KrrError::BadParam(s) => write!(f, "bad parameter: {s}"),
            KrrError::Dataset(s) => write!(f, "bad dataset: {s}"),
            KrrError::SolveFailed(s) => write!(f, "solve failed: {s}"),
            KrrError::Io(s) => write!(f, "io error: {s}"),
            KrrError::Shard(s) => write!(f, "shard failure: {s}"),
        }
    }
}

impl std::error::Error for KrrError {}

impl From<std::io::Error> for KrrError {
    fn from(e: std::io::Error) -> Self {
        KrrError::Io(e.to_string())
    }
}

impl KrrError {
    /// Process exit code for the CLI: 2 for usage/config mistakes (matching
    /// the unknown-subcommand convention), 1 for runtime failures.
    pub fn exit_code(&self) -> i32 {
        match self {
            KrrError::UnknownMethod(_)
            | KrrError::UnknownBucket(_)
            | KrrError::UnknownPrecond(_)
            | KrrError::UnknownKernel(_)
            | KrrError::UnknownDataset(_)
            | KrrError::BadParam(_) => 2,
            KrrError::Dataset(_)
            | KrrError::SolveFailed(_)
            | KrrError::Io(_)
            | KrrError::Shard(_) => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_offending_string() {
        let e = KrrError::UnknownMethod("wlshh".into());
        assert!(e.to_string().contains("wlshh"));
        assert!(e.to_string().contains("nystrom"));
    }

    #[test]
    fn exit_codes_split_usage_from_runtime() {
        assert_eq!(KrrError::UnknownMethod("x".into()).exit_code(), 2);
        assert_eq!(KrrError::BadParam("x".into()).exit_code(), 2);
        // a malformed data *file* is a runtime failure, not CLI misuse
        assert_eq!(KrrError::Dataset("x".into()).exit_code(), 1);
        assert_eq!(KrrError::SolveFailed("x".into()).exit_code(), 1);
        assert_eq!(KrrError::Io("x".into()).exit_code(), 1);
        // a shard dying mid-solve is a runtime failure too
        assert_eq!(KrrError::Shard("x".into()).exit_code(), 1);
    }

    #[test]
    fn io_error_converts() {
        let e: KrrError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, KrrError::Io(_)));
    }
}
