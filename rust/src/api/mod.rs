//! The typed public API: spec enums, the crate error type, and the
//! fallible model builder.
//!
//! The builder is the front door for training:
//!
//! ```no_run
//! use wlsh_krr::api::{KrrModel, MethodSpec};
//! # let train = wlsh_krr::data::synthetic_by_name("wine", Some(200), 1).unwrap();
//! let model = KrrModel::builder()
//!     .method(MethodSpec::Wlsh) // or .method("wlsh") — typos become Err
//!     .budget(450)
//!     .scale(3.0)
//!     .lambda(0.5)
//!     .fit(&train)?;
//! let preds = model.predict(&train.x);
//! # Ok::<(), wlsh_krr::api::KrrError>(())
//! ```
//!
//! Every misconfiguration — an unknown method string, a non-positive
//! bandwidth, a landmark matrix that fails to factor — surfaces as a
//! [`KrrError`] from [`KrrBuilder::fit`], never as a panic.

mod error;
mod spec;

pub use error::KrrError;
pub use spec::{
    BucketSpec, KernelFamily, KernelSpec, MethodSpec, PrecondSpec, SamplingSpec,
    TopologySpec, DEFAULT_PRECOND_RANK,
};

pub use crate::coordinator::TrainedModel;
// Re-exported so `Predictor::predict_sparse_into` is usable from the api
// module alone — CSR queries need the chunk type to be nameable here.
pub use crate::data::SparseChunk;
pub use crate::sketch::Predictor;

use crate::config::KrrConfig;
use crate::coordinator::Trainer;
use crate::data::{DataSource, Dataset};

/// Conversion into a spec, either from the typed value itself or from its
/// string form — lets builder setters accept both `MethodSpec::Wlsh` and
/// `"wlsh"` while keeping string typos fallible (surfaced at
/// [`KrrBuilder::fit`], not as a panic).
pub trait IntoSpec<T> {
    fn into_spec(self) -> Result<T, KrrError>;
}

macro_rules! impl_into_spec {
    ($t:ty) => {
        impl IntoSpec<$t> for $t {
            fn into_spec(self) -> Result<$t, KrrError> {
                Ok(self)
            }
        }

        impl IntoSpec<$t> for &str {
            fn into_spec(self) -> Result<$t, KrrError> {
                self.parse()
            }
        }

        impl IntoSpec<$t> for &String {
            fn into_spec(self) -> Result<$t, KrrError> {
                self.parse()
            }
        }
    };
}

impl_into_spec!(MethodSpec);
impl_into_spec!(BucketSpec);
impl_into_spec!(PrecondSpec);
impl_into_spec!(KernelSpec);
impl_into_spec!(TopologySpec);
impl_into_spec!(SamplingSpec);

/// Entry point for the builder API. `KrrModel` is a namespace: the trained
/// artifact itself is a [`TrainedModel`].
pub struct KrrModel;

impl KrrModel {
    /// Start a model spec from [`KrrConfig::default`].
    pub fn builder() -> KrrBuilder {
        KrrBuilder { config: KrrConfig::default(), err: None }
    }
}

/// Fallible builder for a KRR training run.
///
/// Setters never panic: a bad string spec or out-of-range parameter is
/// remembered and returned from [`fit`](Self::fit) /
/// [`build_config`](Self::build_config) (first error wins).
#[derive(Clone, Debug)]
pub struct KrrBuilder {
    config: KrrConfig,
    err: Option<KrrError>,
}

impl Default for KrrBuilder {
    fn default() -> Self {
        KrrModel::builder()
    }
}

impl KrrBuilder {
    fn record<T>(&mut self, r: Result<T, KrrError>, apply: impl FnOnce(&mut KrrConfig, T)) {
        match r {
            Ok(v) => apply(&mut self.config, v),
            Err(e) => {
                self.err.get_or_insert(e);
            }
        }
    }

    /// Start from an existing config (e.g. one parsed from TOML).
    pub fn config(mut self, config: KrrConfig) -> Self {
        self.config = config;
        self
    }

    /// Estimator family: a [`MethodSpec`] or its string form.
    pub fn method(mut self, m: impl IntoSpec<MethodSpec>) -> Self {
        self.record(m.into_spec(), |c, v| c.method = v);
        self
    }

    /// WLSH bucket function: a [`BucketSpec`] or its string form.
    pub fn bucket(mut self, b: impl IntoSpec<BucketSpec>) -> Self {
        self.record(b.into_spec(), |c, v| c.bucket = v);
        self
    }

    /// CG preconditioner: a [`PrecondSpec`] or its string form.
    pub fn precond(mut self, p: impl IntoSpec<PrecondSpec>) -> Self {
        self.record(p.into_spec(), |c, v| c.precond = v);
        self
    }

    /// Solve/serving topology: a [`TopologySpec`] or its string form
    /// (`local`, `shards(n=N)`, `remote(addr=host:port,...)`).
    pub fn topology(mut self, t: impl IntoSpec<TopologySpec>) -> Self {
        self.record(t.into_spec(), |c, v| c.topology = v);
        self
    }

    /// Instance sampling strategy: a [`SamplingSpec`] or its string form
    /// (`uniform`, `leverage(pilot=P,keep=K)`, `stein`).
    pub fn sampling(mut self, s: impl IntoSpec<SamplingSpec>) -> Self {
        self.record(s.into_spec(), |c, v| c.sampling = v);
        self
    }

    /// Sketch budget: WLSH instances m / RFF features D / Nyström landmarks.
    pub fn budget(mut self, budget: usize) -> Self {
        self.config.budget = budget;
        self
    }

    /// Gamma shape of the LSH width law (2 ⇒ Laplace, 7 ⇒ paper's smooth).
    pub fn gamma_shape(mut self, shape: f64) -> Self {
        self.config.gamma_shape = shape;
        self
    }

    /// Kernel bandwidth (> 0).
    pub fn scale(mut self, scale: f64) -> Self {
        self.config.scale = scale;
        self
    }

    /// Ridge λ (≥ 0).
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.config.lambda = lambda;
        self
    }

    /// CG iteration cap.
    pub fn cg_max_iters(mut self, iters: usize) -> Self {
        self.config.cg_max_iters = iters;
        self
    }

    /// CG relative-residual tolerance (> 0).
    pub fn cg_tol(mut self, tol: f64) -> Self {
        self.config.cg_tol = tol;
        self
    }

    /// Per-iteration CG progress lines on stderr.
    pub fn cg_verbose(mut self, verbose: bool) -> Self {
        self.config.cg_verbose = verbose;
        self
    }

    /// Worker threads for the sketch build.
    pub fn workers(mut self, workers: usize) -> Self {
        self.config.workers = workers;
        self
    }

    /// Rows per block when streaming data through the chunked sketch
    /// builds (≥ 1; results are bit-identical at every chunk size).
    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.config.chunk_rows = rows;
        self
    }

    /// RNG seed (sketch + data splits derive from it deterministically).
    pub fn seed(mut self, seed: u64) -> Self {
        self.config.seed = seed;
        self
    }

    /// Validate and return the assembled [`KrrConfig`].
    pub fn build_config(self) -> Result<KrrConfig, KrrError> {
        if let Some(e) = self.err {
            return Err(e);
        }
        self.config.validate()?;
        Ok(self.config)
    }

    /// Train on `ds`: build the operator, run (preconditioned) CG, and
    /// freeze the serving-time [`Predictor`] state.
    pub fn fit(self, ds: &Dataset) -> Result<TrainedModel, KrrError> {
        let config = self.build_config()?;
        Trainer::new(config).train(ds)
    }

    /// Train from a chunked [`DataSource`] stream — out-of-core when the
    /// source is file- or generator-backed, with peak memory
    /// O(chunk + sketch) instead of O(n·d). Bit-identical to
    /// [`fit`](Self::fit) on the materialized rows at every
    /// [`chunk_rows`](Self::chunk_rows) / [`workers`](Self::workers)
    /// setting.
    ///
    /// Sources whose [`DataSource::is_sparse`] is true (e.g. a
    /// [`LibsvmSource`](crate::data::LibsvmSource)) stream native CSR
    /// chunks end to end: the sketch builds consume stored coordinates
    /// only, so peak memory scales with nnz rather than n·d, and the
    /// result stays bit-identical to training on the densified rows.
    /// Wrap the source in
    /// [`DensifySource`](crate::data::DensifySource) to force the dense
    /// path.
    pub fn fit_source(self, src: &dyn DataSource) -> Result<TrainedModel, KrrError> {
        let config = self.build_config()?;
        Trainer::new(config).train_source(src)
    }

    /// Train an incrementally updatable model on `ds`: the online
    /// counterpart of [`fit`](Self::fit), going through the same validated
    /// config and spec grammar (so
    /// `KrrModel::builder()...fit_online(&ds)` replaces the asymmetric
    /// `OnlineTrainer::fit(config, &ds)` call).
    pub fn fit_online(self, ds: &Dataset) -> Result<crate::online::OnlineTrainer, KrrError> {
        let config = self.build_config()?;
        crate::online::OnlineTrainer::fit(config, ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_by_name;

    fn small_ds() -> Dataset {
        let mut ds = synthetic_by_name("wine", Some(200), 1).unwrap();
        ds.standardize();
        ds
    }

    #[test]
    fn builder_trains_and_predicts() {
        let ds = small_ds();
        let (tr, te) = ds.split(160, 2);
        let model = KrrModel::builder()
            .method(MethodSpec::Wlsh)
            .budget(32)
            .scale(3.0)
            .lambda(0.5)
            .fit(&tr)
            .unwrap();
        let pred = model.predict(&te.x);
        assert_eq!(pred.len(), te.n);
        assert!(pred.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn string_setters_parse_through_the_specs() {
        let cfg = KrrModel::builder()
            .method("rff")
            .bucket("smooth2")
            .precond("nystrom(rank=7)")
            .build_config()
            .unwrap();
        assert_eq!(cfg.method, MethodSpec::Rff);
        assert_eq!(cfg.bucket, BucketSpec::Smooth(2));
        assert_eq!(cfg.precond, PrecondSpec::Nystrom { rank: 7 });
    }

    #[test]
    fn fit_source_streams_and_matches_fit() {
        let ds = small_ds();
        let spec = |b: KrrBuilder| {
            b.method(MethodSpec::Wlsh).budget(12).scale(3.0).lambda(0.5).chunk_rows(29)
        };
        let a = spec(KrrModel::builder()).fit(&ds).unwrap();
        let b = spec(KrrModel::builder()).fit_source(&ds).unwrap();
        assert_eq!(a.beta, b.beta);
        assert!(matches!(
            KrrModel::builder().chunk_rows(0).build_config(),
            Err(KrrError::BadParam(_))
        ));
    }

    #[test]
    fn fit_online_goes_through_the_builder() {
        let ds = small_ds();
        let (tr, te) = ds.split(160, 2);
        let spec = |b: KrrBuilder| {
            b.method(MethodSpec::Wlsh).budget(16).scale(3.0).lambda(0.5).sampling("uniform")
        };
        let offline = spec(KrrModel::builder()).fit(&tr).unwrap();
        let online = spec(KrrModel::builder()).fit_online(&tr).unwrap();
        assert_eq!(offline.beta, online.model().beta);
        assert_eq!(offline.predict(&te.x), online.model().predict(&te.x));
        // spec errors surface from fit_online exactly as from fit
        let err = KrrModel::builder().sampling("bogus").fit_online(&tr).unwrap_err();
        assert!(matches!(err, KrrError::BadParam(_)));
    }

    #[test]
    fn first_error_wins_and_surfaces_at_fit() {
        let ds = small_ds();
        let err = KrrModel::builder()
            .method("wlshh")
            .bucket("also-bogus")
            .fit(&ds)
            .unwrap_err();
        assert_eq!(err, KrrError::UnknownMethod("wlshh".into()));
    }

    #[test]
    fn bad_params_are_rejected_at_build() {
        assert!(matches!(
            KrrModel::builder().scale(-2.0).build_config(),
            Err(KrrError::BadParam(_))
        ));
        assert!(matches!(
            KrrModel::builder().lambda(f64::NAN).build_config(),
            Err(KrrError::BadParam(_))
        ));
        assert!(matches!(
            KrrModel::builder().method(MethodSpec::Wlsh).budget(0).build_config(),
            Err(KrrError::BadParam(_))
        ));
    }
}
