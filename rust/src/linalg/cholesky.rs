//! Cholesky factorization A = L Lᵀ with a cache-blocked right-looking
//! update — fast enough on one core for the paper's exact baselines and
//! GP sampling (n ≈ 4000 in ~10 s at a few GFLOP/s).

use super::Matrix;

/// Lower-triangular Cholesky factor.
pub struct CholeskyFactor {
    pub l: Matrix,
}

const BLOCK: usize = 64;

impl CholeskyFactor {
    /// Factor a symmetric positive-definite matrix; `jitter` is added to
    /// the diagonal (GP sampling uses ~1e-8 · tr(A)/n).
    pub fn new(a: &Matrix, jitter: f64) -> Result<CholeskyFactor, String> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = a.clone();
        l.add_diag(jitter);
        // Right-looking blocked factorization over the lower triangle.
        let mut kb = 0;
        while kb < n {
            let ke = (kb + BLOCK).min(n);
            // factor diagonal block in place (unblocked)
            for k in kb..ke {
                let mut d = l[(k, k)];
                for p in kb..k {
                    d -= l[(k, p)] * l[(k, p)];
                }
                if d <= 0.0 {
                    return Err(format!("not PD at pivot {k} (d = {d:.3e})"));
                }
                let dk = d.sqrt();
                l[(k, k)] = dk;
                for i in k + 1..ke {
                    let mut s = l[(i, k)];
                    for p in kb..k {
                        s -= l[(i, p)] * l[(k, p)];
                    }
                    l[(i, k)] = s / dk;
                }
            }
            // panel solve: rows below the block, columns kb..ke
            for i in ke..n {
                for k in kb..ke {
                    let mut s = l[(i, k)];
                    for p in kb..k {
                        s -= l[(i, p)] * l[(k, p)];
                    }
                    l[(i, k)] = s / l[(k, k)];
                }
            }
            // trailing update: A22 -= L21 L21ᵀ (lower triangle only).
            // Copy the panel L21 (rows ke..n, cols kb..ke) to avoid aliasing
            // and keep the dot loops contiguous.
            let bw = ke - kb;
            if ke < n {
                let tail = n - ke;
                let mut panel = vec![0.0; tail * bw];
                for i in ke..n {
                    let src = &l.data[i * l.cols + kb..i * l.cols + ke];
                    panel[(i - ke) * bw..(i - ke + 1) * bw].copy_from_slice(src);
                }
                for i in ke..n {
                    let pi = &panel[(i - ke) * bw..(i - ke + 1) * bw];
                    for j in ke..=i {
                        let pj = &panel[(j - ke) * bw..(j - ke + 1) * bw];
                        let mut s = 0.0;
                        for p in 0..bw {
                            s += pi[p] * pj[p];
                        }
                        l[(i, j)] -= s;
                    }
                }
            }
            kb = ke;
        }
        // zero the strict upper triangle for cleanliness
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = 0.0;
            }
        }
        Ok(CholeskyFactor { l })
    }

    /// Solve A x = b via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = self.forward(b);
        self.backward(&y)
    }

    /// Solve L y = b.
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = b[i];
            for (j, item) in y.iter().enumerate().take(i) {
                s -= row[j] * item;
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve Lᵀ x = y.
    pub fn backward(&self, y: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(y.len(), n);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= self.l[(j, i)] * x[j];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// x = L z — transforms iid standard normals z into samples with
    /// covariance A (the GP sampler's core operation).
    pub fn l_mul(&self, z: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(z.len(), n);
        (0..n)
            .map(|i| {
                let row = self.l.row(i);
                let mut s = 0.0;
                for j in 0..=i {
                    s += row[j] * z[j];
                }
                s
            })
            .collect()
    }

    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed, 0);
        let b = Matrix::random_normal(&mut rng, n, n);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * 0.1);
        a
    }

    #[test]
    fn factor_reconstructs() {
        for n in [1, 2, 5, 63, 64, 65, 130] {
            let a = random_spd(n, n as u64);
            let ch = CholeskyFactor::new(&a, 0.0).unwrap();
            let rec = ch.l.matmul(&ch.l.transpose());
            let err = a
                .data
                .iter()
                .zip(&rec.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8 * (n as f64), "n={n} err={err}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let a = random_spd(40, 7);
        let ch = CholeskyFactor::new(&a, 0.0).unwrap();
        let x_true: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(CholeskyFactor::new(&a, 0.0).is_err());
    }

    #[test]
    fn l_mul_covariance() {
        // E[(Lz)(Lz)ᵀ] = A — spot-check the variance of one coordinate.
        let a = random_spd(8, 3);
        let ch = CholeskyFactor::new(&a, 0.0).unwrap();
        let mut rng = Pcg64::new(9, 0);
        let trials = 20_000;
        let mut var0 = 0.0;
        for _ in 0..trials {
            let z: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
            let x = ch.l_mul(&z);
            var0 += x[0] * x[0];
        }
        var0 /= trials as f64;
        assert!((var0 - a[(0, 0)]).abs() < 0.1 * a[(0, 0)], "var {var0} vs {}", a[(0, 0)]);
    }

    #[test]
    fn log_det_matches_small() {
        let a = Matrix::from_rows(vec![vec![4.0, 0.0], vec![0.0, 9.0]]);
        let ch = CholeskyFactor::new(&a, 0.0).unwrap();
        assert!((ch.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }
}
