//! Row-major dense f64 matrix with the operations the repo needs.

use crate::util::rng::Pcg64;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c));
        Matrix { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn random_normal(rng: &mut Pcg64, rows: usize, cols: usize) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| super::dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            super::axpy(x[i], self.row(i), &mut y);
        }
        y
    }

    /// C = A B (ikj loop order: streams B rows, autovectorizes).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = c.row_mut(i);
                super::axpy(aik, brow, crow);
            }
        }
        c
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// A += alpha I (in place; square only).
    pub fn add_diag(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
    }

    /// Symmetrize in place: A = (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in i + 1..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![4.0, 6.0]);
        let b = Matrix::identity(2);
        assert_eq!(a.matmul(&b), a);
        let c = a.matmul(&a);
        assert_eq!(c.data, vec![7.0, 10.0, 15.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1, 0);
        let a = Matrix::random_normal(&mut rng, 5, 3);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_diag_and_symmetrize() {
        let mut a = Matrix::from_rows(vec![vec![0.0, 2.0], vec![4.0, 0.0]]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
        a.add_diag(1.0);
        assert_eq!(a[(0, 0)], 1.0);
    }
}
