//! Symmetric eigendecomposition: Householder tridiagonalization followed by
//! implicit-shift QL iteration (the classical tred2/tqli pair). Used for
//! the OSE spectral-sandwich verification (Thm 11) at moderate n and for
//! cross-checking Lanczos.

use super::Matrix;

/// Full symmetric eigendecomposition A = V diag(λ) Vᵀ.
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Column j of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Matrix,
}

/// Compute the full eigendecomposition of a symmetric matrix.
pub fn sym_eig(a: &Matrix) -> SymEig {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut v = a.clone();
    v.symmetrize();
    let mut d = vec![0.0; n]; // diagonal
    let mut e = vec![0.0; n]; // off-diagonal
    tred2(&mut v, &mut d, &mut e);
    tqli(&mut d, &mut e, &mut v);
    // sort ascending, permuting columns of v
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (new_j, &old_j) in idx.iter().enumerate() {
        for i in 0..n {
            vectors[(i, new_j)] = v[(i, old_j)];
        }
    }
    SymEig { values, vectors }
}

/// Householder reduction to tridiagonal form (Numerical Recipes tred2).
fn tred2(a: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = a.rows;
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| a[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = a[(i, l)];
            } else {
                for k in 0..=l {
                    a[(i, k)] /= scale;
                    h += a[(i, k)] * a[(i, k)];
                }
                let mut f = a[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    a[(j, i)] = a[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[(j, k)] * a[(i, k)];
                    }
                    for k in j + 1..=l {
                        g += a[(k, j)] * a[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * a[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = a[(i, j)];
                    e[j] -= hh * f;
                    let g = e[j];
                    for k in 0..=j {
                        a[(j, k)] -= f * e[k] + g * a[(i, k)];
                    }
                }
            }
        } else {
            e[i] = a[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += a[(i, k)] * a[(k, j)];
                }
                for k in 0..i {
                    a[(k, j)] -= g * a[(k, i)];
                }
            }
        }
        d[i] = a[(i, i)];
        a[(i, i)] = 1.0;
        for j in 0..i {
            a[(j, i)] = 0.0;
            a[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration on the tridiagonal form (tqli), accumulating
/// the transformations into `z` so its columns become eigenvectors.
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut Matrix) {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(vec![
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let eig = sym_eig(&a);
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 2.0).abs() < 1e-12);
        assert!((eig.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let a = Matrix::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let eig = sym_eig(&a);
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_random_symmetric() {
        let mut rng = Pcg64::new(2, 0);
        for n in [1, 2, 3, 10, 40] {
            let b = Matrix::random_normal(&mut rng, n, n);
            let mut a = b.matmul(&b.transpose());
            a.symmetrize();
            let eig = sym_eig(&a);
            // A v_j = λ_j v_j for each eigenpair
            for j in 0..n {
                let vj: Vec<f64> = (0..n).map(|i| eig.vectors[(i, j)]).collect();
                let av = a.matvec(&vj);
                for i in 0..n {
                    assert!(
                        (av[i] - eig.values[j] * vj[i]).abs() < 1e-7 * (1.0 + eig.values[j].abs()),
                        "n={n} pair {j}"
                    );
                }
            }
            // eigenvalues ascending
            assert!(eig.values.windows(2).all(|w| w[0] <= w[1] + 1e-12));
        }
    }

    #[test]
    fn orthonormal_vectors() {
        let mut rng = Pcg64::new(5, 0);
        let b = Matrix::random_normal(&mut rng, 20, 20);
        let mut a = b.matmul(&b.transpose());
        a.symmetrize();
        let eig = sym_eig(&a);
        let vtv = eig.vectors.transpose().matmul(&eig.vectors);
        for i in 0..20 {
            for j in 0..20 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn psd_eigenvalues_nonnegative() {
        let mut rng = Pcg64::new(8, 0);
        let b = Matrix::random_normal(&mut rng, 15, 5);
        let mut a = b.matmul(&b.transpose()); // rank 5 PSD
        a.symmetrize();
        let eig = sym_eig(&a);
        assert!(eig.values.iter().all(|&v| v > -1e-8));
        // 10 near-zero eigenvalues
        assert!(eig.values[..10].iter().all(|&v| v.abs() < 1e-8));
    }
}
