//! Dense linear-algebra substrate (no BLAS/LAPACK offline): row-major f64
//! matrices, blocked Cholesky, symmetric eigendecomposition (Householder
//! tridiagonalization + implicit-shift QL), Lanczos extreme eigenvalues,
//! and triangular solves. Sized for the paper's exact baselines
//! (n ≤ ~8000) and the OSE spectral checks.

mod cholesky;
mod dense;
mod eig;
mod lanczos;

pub use cholesky::CholeskyFactor;
pub use dense::Matrix;
pub use eig::{sym_eig, SymEig};
pub use lanczos::{lanczos_extreme, lanczos_quadform_inv, LanczosResult, QuadformResult};

/// y += alpha * x
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Dot product over f32 slices with f64 accumulation (hot path helper).
///
/// Delegates to the runtime-dispatched `util::simd` kernel; the reduction
/// order is the same 4-lane-strided scheme this function always used, so
/// the AVX2 path is bit-identical to the historical scalar loop.
pub fn dot_f32(x: &[f32], y: &[f32]) -> f64 {
    crate::util::simd::dot_f32(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas1_helpers() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn dot_f32_matches_f64() {
        let x: Vec<f32> = (0..1003).map(|i| (i as f32) * 0.01).collect();
        let y: Vec<f32> = (0..1003).map(|i| 1.0 - (i as f32) * 0.002).collect();
        let want: f64 = x
            .iter()
            .zip(&y)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum();
        assert!((dot_f32(&x, &y) - want).abs() < 1e-9 * want.abs().max(1.0));
    }
}
